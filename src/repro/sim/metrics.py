"""Queueing / SLO / carbon metrics for the serving simulator (DESIGN.md §2).

The driver appends one :class:`TaskRecord` per completed task and one
timeline sample per ``INTENSITY_TICK``; :class:`MetricsCollector.summary`
reduces them to the report the benchmarks and CI smoke assert on:
per-task queueing delay, p50/p95/p99 end-to-end latency, SLO-violation
rate, deferral counts, and the carbon-vs-latency timeline.

Determinism contract: :meth:`MetricsCollector.to_text` renders every float
through one fixed ``%.9g`` format, so two same-seed runs produce
byte-identical reports (regression-tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.sim.clock import hours_to_s

# Fixed queueing-delay histogram edges (seconds, log-spaced); stable bins
# keep same-seed reports byte-comparable and cross-scenario comparable.
WAIT_HIST_EDGES_S = (0.0, 0.001, 0.01, 0.1, 1.0, 10.0, 60.0, 600.0, 3600.0,
                     float("inf"))


@dataclass(frozen=True)
class TaskRecord:
    uid: int
    submit_hour: float
    start_hour: float            # when its batch began executing
    finish_hour: float           # start + its serial position's service time
    node: str
    carbon_g: float
    energy_kwh: float
    deferred_hours: float = 0.0  # planned wake delay (0 = ran immediately)
    tenant: str = ""             # "" = untenanted (single-workload sims)

    @property
    def wait_s(self) -> float:
        return hours_to_s(self.start_hour - self.submit_hour)

    @property
    def service_s(self) -> float:
        return hours_to_s(self.finish_hour - self.start_hour)

    @property
    def latency_s(self) -> float:
        return hours_to_s(self.finish_hour - self.submit_hour)


@dataclass(frozen=True)
class TimelineSample:
    hour: float
    completed: int
    carbon_g_cum: float
    mean_intensity: float        # fleet-mean grid signal at this instant


def _pct(xs: np.ndarray, q: float) -> float:
    return float(np.percentile(xs, q)) if xs.size else 0.0


@dataclass
class MetricsCollector:
    slo_latency_s: Optional[float] = None
    records: List[TaskRecord] = field(default_factory=list)
    timeline: List[TimelineSample] = field(default_factory=list)
    deferred_tasks: int = 0
    # Per-tenant SLO classes (DESIGN.md §7): a tenant's violations are
    # counted against its own class's latency target when present, the
    # collector-wide slo_latency_s otherwise, and its objective is *met*
    # while the violation rate stays within the class's miss tolerance.
    # The *global* SLO metrics always use slo_latency_s only, so
    # untenanted reports are byte-identical to pre-tenancy ones.
    tenant_slo_s: Dict[str, float] = field(default_factory=dict)
    tenant_miss_tolerance: Dict[str, float] = field(default_factory=dict)
    # Closed-loop / admission counters, keyed by tenant ("" = untenanted).
    rejected: Dict[str, int] = field(default_factory=dict)
    abandoned: Dict[str, int] = field(default_factory=dict)
    retries: Dict[str, int] = field(default_factory=dict)
    # Dead-lettered tasks (resilience attempt cap, DESIGN.md §10). Kept
    # out of summary() and rendered only when non-empty, so zero-fault
    # reports stay byte-identical to pre-resilience ones.
    dead: Dict[str, int] = field(default_factory=dict)

    def add(self, rec: TaskRecord) -> None:
        self.records.append(rec)
        if rec.deferred_hours > 0:
            self.deferred_tasks += 1

    def add_sample(self, s: TimelineSample) -> None:
        self.timeline.append(s)

    def count_rejected(self, tenant: str = "") -> None:
        self.rejected[tenant] = self.rejected.get(tenant, 0) + 1

    def count_abandoned(self, tenant: str = "") -> None:
        self.abandoned[tenant] = self.abandoned.get(tenant, 0) + 1

    def count_retry(self, tenant: str = "") -> None:
        self.retries[tenant] = self.retries.get(tenant, 0) + 1

    def count_dead(self, tenant: str = "") -> None:
        self.dead[tenant] = self.dead.get(tenant, 0) + 1

    # -- reductions ---------------------------------------------------------
    def wait_histogram(self) -> List[int]:
        waits = [r.wait_s for r in self.records]
        hist, _ = np.histogram(waits, bins=np.array(WAIT_HIST_EDGES_S))
        return [int(c) for c in hist]

    def summary(self) -> Dict:
        waits = np.array([r.wait_s for r in self.records])
        lats = np.array([r.latency_s for r in self.records])
        n = len(self.records)
        viol = (int(np.sum(lats > self.slo_latency_s))
                if self.slo_latency_s is not None else 0)
        carbon = float(sum(r.carbon_g for r in self.records))
        return {
            "tasks": n,
            "carbon_g_total": carbon,
            "carbon_g_per_task": carbon / n if n else 0.0,
            "energy_kwh_total": float(sum(r.energy_kwh for r in self.records)),
            "wait_s_mean": float(np.mean(waits)) if n else 0.0,
            "wait_s_p50": _pct(waits, 50), "wait_s_p95": _pct(waits, 95),
            "wait_s_p99": _pct(waits, 99),
            "latency_s_p50": _pct(lats, 50), "latency_s_p95": _pct(lats, 95),
            "latency_s_p99": _pct(lats, 99),
            "slo_latency_s": self.slo_latency_s,
            "slo_violations": viol,
            "slo_violation_rate": viol / n if n else 0.0,
            "deferred_tasks": self.deferred_tasks,
            "wait_histogram": self.wait_histogram(),
        }

    # -- per-tenant reductions (DESIGN.md §7) -------------------------------
    def _tenant_groups(self) -> Dict[str, List[TaskRecord]]:
        """Records grouped per tenant in one pass (names with only
        counter activity get an empty group)."""
        groups: Dict[str, List[TaskRecord]] = {}
        for r in self.records:
            if r.tenant:
                groups.setdefault(r.tenant, []).append(r)
        for name in (set(self.rejected) | set(self.abandoned)
                     | set(self.retries) | set(self.dead)):
            if name:
                groups.setdefault(name, [])
        return groups

    def tenant_names(self) -> List[str]:
        return sorted(self._tenant_groups())

    def tenant_summary(self) -> Dict[str, Dict]:
        """Per-tenant SLO attainment (vs the tenant's own SLO class,
        including its miss tolerance), admission/abandon rates and carbon
        breakdown. Empty for untenanted sims (so their reports stay
        byte-identical to the pre-tenancy format)."""
        out: Dict[str, Dict] = {}
        for name, recs in sorted(self._tenant_groups().items()):
            lats = np.array([r.latency_s for r in recs])
            slo = self.tenant_slo_s.get(name, self.slo_latency_s)
            viol = int(np.sum(lats > slo)) if slo is not None else 0
            n = len(recs)
            rej = self.rejected.get(name, 0)
            attain = 1.0 - viol / n if n else 1.0
            tol = self.tenant_miss_tolerance.get(name, 0.0)
            out[name] = {
                "completed": n,
                "carbon_g": float(sum(r.carbon_g for r in recs)),
                "energy_kwh": float(sum(r.energy_kwh for r in recs)),
                "latency_s_p95": _pct(lats, 95),
                "slo_latency_s": slo,
                "slo_violations": viol,
                "slo_attainment": attain,
                "slo_miss_tolerance": tol,
                "slo_met": (1.0 - attain) <= tol + 1e-12,
                "rejected": rej,
                "admission_rate": n / (n + rej) if (n + rej) else 1.0,
                "abandoned": self.abandoned.get(name, 0),
                "retries": self.retries.get(name, 0),
                "deferred": sum(1 for r in recs if r.deferred_hours > 0),
            }
        return out

    # -- obs bridge (DESIGN.md §9) ------------------------------------------
    def export_obs(self, registry) -> None:
        """Fold this collector into an obs :class:`MetricsRegistry`:
        summary scalars as ``sim_*`` gauges, per-node completion/carbon
        counters, per-tenant admission counters. Purely additive — the
        ``to_text`` byte-identity surface never reads the registry."""
        s = self.summary()
        g = registry.gauge("sim_summary", "Sim summary scalars",
                           labels=("key",))
        for k in sorted(s):
            v = s[k]
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and v is not None:
                g.set(float(v), (k,))
        if self.records:
            nodes = np.array([r.node for r in self.records])
            carbon = np.array([r.carbon_g for r in self.records])
            uniq, inverse = np.unique(nodes, return_inverse=True)
            done = registry.counter("sim_tasks_total",
                                    "Tasks completed per node",
                                    labels=("node",))
            cg = registry.counter("sim_carbon_g_total",
                                  "Carbon billed per node (gCO2)",
                                  labels=("node",))
            rows = done.rows([(str(n),) for n in uniq])
            done.inc_at(rows, np.bincount(inverse, minlength=uniq.size))
            rows = cg.rows([(str(n),) for n in uniq])
            cg.inc_at(rows, np.bincount(inverse, weights=carbon,
                                        minlength=uniq.size))
        adm = registry.counter("sim_admission_total",
                               "Admission-loop outcomes per tenant",
                               labels=("tenant", "outcome"))
        for name, counts in (("rejected", self.rejected),
                             ("abandoned", self.abandoned),
                             ("retry", self.retries),
                             ("dead", self.dead)):
            for tenant in sorted(counts):
                adm.inc(counts[tenant], (tenant or "-", name))

    # -- deterministic rendering --------------------------------------------
    def to_text(self) -> str:
        """Canonical report: one ``%.9g``-formatted line per metric, per
        timeline sample and per task — the byte-identity surface for the
        seed-determinism regression test and the CI sim smoke."""
        s = self.summary()
        lines = []
        for k in sorted(s):
            v = s[k]
            if isinstance(v, float):
                lines.append(f"{k}={v:.9g}")
            elif isinstance(v, list):
                lines.append(f"{k}=[{','.join(str(x) for x in v)}]")
            else:
                lines.append(f"{k}={v}")
        for name, t in sorted(self.tenant_summary().items()):
            lines.append(
                f"tenant {name} completed={t['completed']} "
                f"carbon_g={t['carbon_g']:.9g} "
                f"slo_attainment={t['slo_attainment']:.9g} "
                f"slo_met={t['slo_met']} "
                f"rejected={t['rejected']} abandoned={t['abandoned']} "
                f"retries={t['retries']} deferred={t['deferred']}")
        # dead-letter lines appear only when something dead-lettered, so
        # zero-fault renderings stay byte-identical (DESIGN.md §10)
        for name in sorted(self.dead):
            lines.append(f"dead tenant={name or '-'} "
                         f"count={self.dead[name]}")
        for t in self.timeline:
            lines.append(f"tick hour={t.hour:.9g} completed={t.completed} "
                         f"carbon_g={t.carbon_g_cum:.9g} "
                         f"intensity={t.mean_intensity:.9g}")
        for r in self.records:
            tenant = f" tenant={r.tenant}" if r.tenant else ""
            lines.append(
                f"task uid={r.uid} node={r.node} submit={r.submit_hour:.9g} "
                f"start={r.start_hour:.9g} finish={r.finish_hour:.9g} "
                f"carbon_g={r.carbon_g:.9g} "
                f"deferred_h={r.deferred_hours:.9g}{tenant}")
        return "\n".join(lines) + "\n"
