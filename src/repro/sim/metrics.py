"""Queueing / SLO / carbon metrics for the serving simulator (DESIGN.md §2).

The driver appends one :class:`TaskRecord` per completed task (or, on the
calendar fast path, one column batch per drained engine step — DESIGN.md
§11) and one timeline sample per ``INTENSITY_TICK``;
:class:`MetricsCollector.summary` reduces them to the report the
benchmarks and CI smoke assert on: per-task queueing delay, p50/p95/p99
end-to-end latency, SLO-violation rate, deferral counts, and the
carbon-vs-latency timeline.

Storage is columnar: records live in parallel numpy arrays (uid, submit,
start, finish, node code, carbon, energy, deferred, tenant code) with
node/tenant names interned once, so a 10^7-task replay costs array
appends rather than 10^7 ``TaskRecord`` objects. The ``records`` property
materializes the familiar object view on demand for callers that want it.

Determinism contract: :meth:`MetricsCollector.to_text` renders every float
through one fixed ``%.9g`` format, so two same-seed runs produce
byte-identical reports (regression-tested). All totals reduce through
``np.add.accumulate``'s sequential fold — bit-identical to the Python
``sum()`` loops they replaced (pairwise ``np.sum`` would not be).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.sim.clock import hours_to_s

# Fixed queueing-delay histogram edges (seconds, log-spaced); stable bins
# keep same-seed reports byte-comparable and cross-scenario comparable.
WAIT_HIST_EDGES_S = (0.0, 0.001, 0.01, 0.1, 1.0, 10.0, 60.0, 600.0, 3600.0,
                     float("inf"))


@dataclass(frozen=True)
class TaskRecord:
    uid: int
    submit_hour: float
    start_hour: float            # when its batch began executing
    finish_hour: float           # start + its serial position's service time
    node: str
    carbon_g: float
    energy_kwh: float
    deferred_hours: float = 0.0  # planned wake delay (0 = ran immediately)
    tenant: str = ""             # "" = untenanted (single-workload sims)

    @property
    def wait_s(self) -> float:
        return hours_to_s(self.start_hour - self.submit_hour)

    @property
    def service_s(self) -> float:
        return hours_to_s(self.finish_hour - self.start_hour)

    @property
    def latency_s(self) -> float:
        return hours_to_s(self.finish_hour - self.submit_hour)


@dataclass(frozen=True)
class TimelineSample:
    hour: float
    completed: int
    carbon_g_cum: float
    mean_intensity: float        # fleet-mean grid signal at this instant


def _pct(xs: np.ndarray, q: float) -> float:
    return float(np.percentile(xs, q)) if xs.size else 0.0


def _seq_sum(x: np.ndarray) -> float:
    """Strict left-fold sum: bit-identical to ``0.0 + x0 + x1 + ...``
    (``np.add.accumulate`` is sequential; ``np.sum`` is pairwise and
    would change the ninth significant digit of ``to_text``)."""
    return float(np.add.accumulate(x)[-1]) if x.size else 0.0


# Column order inside each chunk (parallel arrays).
_UID, _SUB, _START, _FIN, _NODE, _CARBON, _ENERGY, _DEF, _TEN = range(9)


@dataclass
class MetricsCollector:
    slo_latency_s: Optional[float] = None
    timeline: List[TimelineSample] = field(default_factory=list)
    deferred_tasks: int = 0
    # Per-tenant SLO classes (DESIGN.md §7): a tenant's violations are
    # counted against its own class's latency target when present, the
    # collector-wide slo_latency_s otherwise, and its objective is *met*
    # while the violation rate stays within the class's miss tolerance.
    # The *global* SLO metrics always use slo_latency_s only, so
    # untenanted reports are byte-identical to pre-tenancy ones.
    tenant_slo_s: Dict[str, float] = field(default_factory=dict)
    tenant_miss_tolerance: Dict[str, float] = field(default_factory=dict)
    # Closed-loop / admission counters, keyed by tenant ("" = untenanted).
    rejected: Dict[str, int] = field(default_factory=dict)
    abandoned: Dict[str, int] = field(default_factory=dict)
    retries: Dict[str, int] = field(default_factory=dict)
    # Dead-lettered tasks (resilience attempt cap, DESIGN.md §10). Kept
    # out of summary() and rendered only when non-empty, so zero-fault
    # reports stay byte-identical to pre-resilience ones.
    dead: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        self._names: List[str] = [""]         # interned node/tenant names
        self._name_idx: Dict[str, int] = {"": 0}
        self._chunks: List[tuple] = []        # consolidated column batches
        self._buf: List[list] = [[] for _ in range(9)]   # scalar appends
        self._n = 0
        self._cat: Optional[tuple] = None     # cached concatenated columns
        self._recs: Optional[List[TaskRecord]] = None

    # -- interning ----------------------------------------------------------
    def intern(self, name: str) -> int:
        code = self._name_idx.get(name)
        if code is None:
            code = self._name_idx[name] = len(self._names)
            self._names.append(name)
        return code

    def intern_array(self, names) -> np.ndarray:
        """Codes for an array/sequence of names (O(distinct) dict work
        when callers pass ``np.unique``'s uniq array)."""
        return np.array([self.intern(str(n)) for n in names],
                        dtype=np.int64)

    # -- ingestion ----------------------------------------------------------
    @property
    def n_records(self) -> int:
        """Completed-task count without materializing ``records``."""
        return self._n

    def add(self, rec: TaskRecord) -> None:
        b = self._buf
        b[_UID].append(rec.uid)
        b[_SUB].append(rec.submit_hour)
        b[_START].append(rec.start_hour)
        b[_FIN].append(rec.finish_hour)
        b[_NODE].append(self.intern(rec.node))
        b[_CARBON].append(rec.carbon_g)
        b[_ENERGY].append(rec.energy_kwh)
        b[_DEF].append(rec.deferred_hours)
        b[_TEN].append(self.intern(rec.tenant))
        self._n += 1
        self._cat = None
        if rec.deferred_hours > 0:
            self.deferred_tasks += 1

    def add_batch(self, uids: np.ndarray, submit_hours: np.ndarray,
                  start_hour: float, finish_hours: np.ndarray,
                  node_codes: np.ndarray, carbon_g: np.ndarray,
                  energy_kwh: np.ndarray, deferred_hours: np.ndarray,
                  tenant_codes: np.ndarray) -> None:
        """One engine step's completions as columns (DESIGN.md §11):
        ``start_hour`` is the shared batch execution instant; node/tenant
        codes come from :meth:`intern` / :meth:`intern_array`."""
        n = len(uids)
        if n == 0:
            return
        self._flush_buf()
        self._chunks.append((
            np.asarray(uids, dtype=np.int64),
            np.asarray(submit_hours, dtype=float),
            np.full(n, float(start_hour)),
            np.asarray(finish_hours, dtype=float),
            np.asarray(node_codes, dtype=np.int64),
            np.asarray(carbon_g, dtype=float),
            np.asarray(energy_kwh, dtype=float),
            np.asarray(deferred_hours, dtype=float),
            np.asarray(tenant_codes, dtype=np.int64)))
        self._n += n
        self._cat = None
        self.deferred_tasks += int(np.count_nonzero(
            np.asarray(deferred_hours) > 0))

    def _flush_buf(self) -> None:
        if not self._buf[_UID]:
            return
        b = self._buf
        self._chunks.append((
            np.asarray(b[_UID], dtype=np.int64),
            np.asarray(b[_SUB], dtype=float),
            np.asarray(b[_START], dtype=float),
            np.asarray(b[_FIN], dtype=float),
            np.asarray(b[_NODE], dtype=np.int64),
            np.asarray(b[_CARBON], dtype=float),
            np.asarray(b[_ENERGY], dtype=float),
            np.asarray(b[_DEF], dtype=float),
            np.asarray(b[_TEN], dtype=np.int64)))
        self._buf = [[] for _ in range(9)]

    def _data(self) -> tuple:
        """The nine concatenated record columns, cached until the next
        append."""
        if self._cat is None:
            self._flush_buf()
            if not self._chunks:
                self._cat = (np.empty(0, dtype=np.int64),) + \
                    tuple(np.empty(0) for _ in range(3)) + \
                    (np.empty(0, dtype=np.int64),) + \
                    tuple(np.empty(0) for _ in range(3)) + \
                    (np.empty(0, dtype=np.int64),)
            elif len(self._chunks) == 1:
                self._cat = self._chunks[0]
            else:
                self._cat = tuple(
                    np.concatenate([c[j] for c in self._chunks])
                    for j in range(9))
                self._chunks = [self._cat]
        return self._cat

    @property
    def records(self) -> List[TaskRecord]:
        """Object view of the columns, materialized on demand (reports,
        tests, examples — not the hot path)."""
        if self._recs is not None and len(self._recs) == self._n:
            return self._recs
        uid, sub, st, fin, nc, cg, en, df, tc = self._data()
        names = self._names
        self._recs = [
            TaskRecord(u, s, a, f, names[m], c, e, d, names[t])
            for u, s, a, f, m, c, e, d, t in zip(
                uid.tolist(), sub.tolist(), st.tolist(), fin.tolist(),
                nc.tolist(), cg.tolist(), en.tolist(), df.tolist(),
                tc.tolist())]
        return self._recs

    def add_sample(self, s: TimelineSample) -> None:
        self.timeline.append(s)

    def count_rejected(self, tenant: str = "") -> None:
        self.rejected[tenant] = self.rejected.get(tenant, 0) + 1

    def count_abandoned(self, tenant: str = "") -> None:
        self.abandoned[tenant] = self.abandoned.get(tenant, 0) + 1

    def count_retry(self, tenant: str = "") -> None:
        self.retries[tenant] = self.retries.get(tenant, 0) + 1

    def count_dead(self, tenant: str = "") -> None:
        self.dead[tenant] = self.dead.get(tenant, 0) + 1

    # -- reductions ---------------------------------------------------------
    def _waits_lats(self):
        _, sub, st, fin, *_ = self._data()
        return (st - sub) * 3600.0, (fin - sub) * 3600.0

    def carbon_g_total(self) -> float:
        return _seq_sum(self._data()[_CARBON])

    def wait_histogram(self) -> List[int]:
        waits, _ = self._waits_lats()
        hist, _ = np.histogram(waits, bins=np.array(WAIT_HIST_EDGES_S))
        return [int(c) for c in hist]

    def summary(self) -> Dict:
        waits, lats = self._waits_lats()
        n = self._n
        viol = (int(np.sum(lats > self.slo_latency_s))
                if self.slo_latency_s is not None else 0)
        carbon = self.carbon_g_total()
        return {
            "tasks": n,
            "carbon_g_total": carbon,
            "carbon_g_per_task": carbon / n if n else 0.0,
            "energy_kwh_total": _seq_sum(self._data()[_ENERGY]),
            "wait_s_mean": float(np.mean(waits)) if n else 0.0,
            "wait_s_p50": _pct(waits, 50), "wait_s_p95": _pct(waits, 95),
            "wait_s_p99": _pct(waits, 99),
            "latency_s_p50": _pct(lats, 50), "latency_s_p95": _pct(lats, 95),
            "latency_s_p99": _pct(lats, 99),
            "slo_latency_s": self.slo_latency_s,
            "slo_violations": viol,
            "slo_violation_rate": viol / n if n else 0.0,
            "deferred_tasks": self.deferred_tasks,
            "wait_histogram": self.wait_histogram(),
        }

    # -- per-tenant reductions (DESIGN.md §7) -------------------------------
    def _tenant_masks(self) -> Dict[str, np.ndarray]:
        """Record mask per tenant in column form (names with only counter
        activity get an all-False mask)."""
        tc = self._data()[_TEN]
        masks: Dict[str, np.ndarray] = {}
        for code, name in enumerate(self._names):
            if not name:
                continue
            m = tc == code
            if m.any():
                masks[name] = m
        empty = None
        for name in (set(self.rejected) | set(self.abandoned)
                     | set(self.retries) | set(self.dead)):
            if name and name not in masks:
                if empty is None:
                    empty = np.zeros(self._n, dtype=bool)
                masks[name] = empty
        return masks

    def tenant_names(self) -> List[str]:
        return sorted(self._tenant_masks())

    def tenant_summary(self) -> Dict[str, Dict]:
        """Per-tenant SLO attainment (vs the tenant's own SLO class,
        including its miss tolerance), admission/abandon rates and carbon
        breakdown. Empty for untenanted sims (so their reports stay
        byte-identical to the pre-tenancy format)."""
        cols = self._data()
        _, lats_all = self._waits_lats()
        out: Dict[str, Dict] = {}
        for name, mask in sorted(self._tenant_masks().items()):
            lats = lats_all[mask]
            slo = self.tenant_slo_s.get(name, self.slo_latency_s)
            viol = int(np.sum(lats > slo)) if slo is not None else 0
            n = int(lats.size)
            rej = self.rejected.get(name, 0)
            attain = 1.0 - viol / n if n else 1.0
            tol = self.tenant_miss_tolerance.get(name, 0.0)
            out[name] = {
                "completed": n,
                "carbon_g": _seq_sum(cols[_CARBON][mask]),
                "energy_kwh": _seq_sum(cols[_ENERGY][mask]),
                "latency_s_p95": _pct(lats, 95),
                "slo_latency_s": slo,
                "slo_violations": viol,
                "slo_attainment": attain,
                "slo_miss_tolerance": tol,
                "slo_met": (1.0 - attain) <= tol + 1e-12,
                "rejected": rej,
                "admission_rate": n / (n + rej) if (n + rej) else 1.0,
                "abandoned": self.abandoned.get(name, 0),
                "retries": self.retries.get(name, 0),
                "deferred": int(np.count_nonzero(cols[_DEF][mask] > 0)),
            }
        return out

    def slo_for_codes(self) -> np.ndarray:
        """Per interned-name-code SLO latency threshold (seconds): a
        tenant's own class target when declared, the collector-wide
        ``slo_latency_s`` otherwise, ``inf`` with no SLO at all. The
        driver indexes this with a batch's tenant codes to scatter
        windowed miss counts into a RollupStore without per-task dict
        lookups (DESIGN.md §12). Cached until the intern table grows."""
        n = len(self._names)
        cached = getattr(self, "_slo_cache", None)
        if cached is not None and cached[0] == n:
            return cached[1]
        base = (self.slo_latency_s if self.slo_latency_s is not None
                else float("inf"))
        out = np.full(n, base)
        for name, s in self.tenant_slo_s.items():
            code = self._name_idx.get(name)
            if code is not None:
                out[code] = s
        self._slo_cache = (n, out)
        return out

    # -- obs bridge (DESIGN.md §9) ------------------------------------------
    def export_obs(self, registry) -> None:
        """Fold this collector into an obs :class:`MetricsRegistry`:
        summary scalars as ``sim_*`` gauges, per-node completion/carbon
        counters, per-tenant admission counters. Purely additive — the
        ``to_text`` byte-identity surface never reads the registry."""
        s = self.summary()
        g = registry.gauge("sim_summary", "Sim summary scalars",
                           labels=("key",))
        for k in sorted(s):
            v = s[k]
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and v is not None:
                g.set(float(v), (k,))
        if self._n:
            cols = self._data()
            names_arr = np.array(self._names, dtype=object)
            nodes = names_arr[cols[_NODE]]
            carbon = cols[_CARBON]
            uniq, inverse = np.unique(nodes, return_inverse=True)
            done = registry.counter("sim_tasks_total",
                                    "Tasks completed per node",
                                    labels=("node",))
            cg = registry.counter("sim_carbon_g_total",
                                  "Carbon billed per node (gCO2)",
                                  labels=("node",))
            rows = done.rows([(str(n),) for n in uniq])
            done.inc_at(rows, np.bincount(inverse, minlength=uniq.size))
            rows = cg.rows([(str(n),) for n in uniq])
            cg.inc_at(rows, np.bincount(inverse, weights=carbon,
                                        minlength=uniq.size))
        adm = registry.counter("sim_admission_total",
                               "Admission-loop outcomes per tenant",
                               labels=("tenant", "outcome"))
        for name, counts in (("rejected", self.rejected),
                             ("abandoned", self.abandoned),
                             ("retry", self.retries),
                             ("dead", self.dead)):
            for tenant in sorted(counts):
                adm.inc(counts[tenant], (tenant or "-", name))

    # -- deterministic rendering --------------------------------------------
    def to_text(self) -> str:
        """Canonical report: one ``%.9g``-formatted line per metric, per
        timeline sample and per task — the byte-identity surface for the
        seed-determinism regression test and the CI sim smoke."""
        s = self.summary()
        lines = []
        for k in sorted(s):
            v = s[k]
            if isinstance(v, float):
                lines.append(f"{k}={v:.9g}")
            elif isinstance(v, list):
                lines.append(f"{k}=[{','.join(str(x) for x in v)}]")
            else:
                lines.append(f"{k}={v}")
        for name, t in sorted(self.tenant_summary().items()):
            lines.append(
                f"tenant {name} completed={t['completed']} "
                f"carbon_g={t['carbon_g']:.9g} "
                f"slo_attainment={t['slo_attainment']:.9g} "
                f"slo_met={t['slo_met']} "
                f"rejected={t['rejected']} abandoned={t['abandoned']} "
                f"retries={t['retries']} deferred={t['deferred']}")
        # dead-letter lines appear only when something dead-lettered, so
        # zero-fault renderings stay byte-identical (DESIGN.md §10)
        for name in sorted(self.dead):
            lines.append(f"dead tenant={name or '-'} "
                         f"count={self.dead[name]}")
        for t in self.timeline:
            lines.append(f"tick hour={t.hour:.9g} completed={t.completed} "
                         f"carbon_g={t.carbon_g_cum:.9g} "
                         f"intensity={t.mean_intensity:.9g}")
        uid, sub, st, fin, nc, cg, en, df, tc = self._data()
        names = self._names
        for u, m, s_, a, f, c, d, t in zip(
                uid.tolist(), nc.tolist(), sub.tolist(), st.tolist(),
                fin.tolist(), cg.tolist(), df.tolist(), tc.tolist()):
            tenant = f" tenant={names[t]}" if names[t] else ""
            lines.append(
                f"task uid={u} node={names[m]} submit={s_:.9g} "
                f"start={a:.9g} finish={f:.9g} "
                f"carbon_g={c:.9g} "
                f"deferred_h={d:.9g}{tenant}")
        return "\n".join(lines) + "\n"
