"""Composable arrival processes for the serving simulator (DESIGN.md §2).

Each process answers one question — *at which simulated hours do requests
arrive in [t0, t0 + horizon)?* — and is deterministic under a fixed seed:
``times()`` draws from a fresh ``np.random.Generator`` seeded at
construction, so two runs of the same scenario are identical sample for
sample (the seed-determinism regression test asserts byte-identical
metric reports).

Processes (GreenScale's workload taxonomy, arXiv 2304.00404: arrival
dynamics drive the carbon savings available to a deferral policy):

- :class:`ConstantRateArrivals` — deterministic, equally spaced. The
  static-scenario parity case: driving the engine with this process and a
  StaticProvider must reproduce the paper's Table II/IV/V numbers.
- :class:`PoissonArrivals`      — homogeneous Poisson (exponential gaps).
- :class:`DiurnalArrivals`      — non-homogeneous Poisson, rate modulated
  by a diurnal (duck-curve-shaped) profile, via Lewis–Shedler thinning.
- :class:`MMPPArrivals`         — bursty 2-state Markov-modulated Poisson.
- :class:`TraceReplayArrivals`  — replay recorded absolute arrival hours.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol, Sequence, Union, runtime_checkable

import numpy as np

SeedLike = Union[int, np.random.Generator]


def _fresh_rng(seed: SeedLike) -> np.random.Generator:
    """A generator whose stream restarts every call — int seeds make
    ``times()`` a pure function; passing a Generator hands the caller
    control of (and responsibility for) the stream position."""
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(seed)


@runtime_checkable
class ArrivalProcess(Protocol):
    """Arrival hours in [t0, t0 + horizon), sorted ascending."""

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        ...


@dataclass(frozen=True)
class ConstantRateArrivals:
    """Equally spaced arrivals — no RNG, the parity baseline."""

    rate_per_hour: float

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        n = int(np.floor(self.rate_per_hour * horizon_hours))
        if n <= 0:
            return np.empty(0)
        return t0_hours + np.arange(n) / self.rate_per_hour


@dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson process at ``rate_per_hour``."""

    rate_per_hour: float
    seed: SeedLike = 0

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        if self.rate_per_hour <= 0:
            return np.empty(0)
        rng = _fresh_rng(self.seed)
        # Draw gaps in chunks until the horizon is covered.
        out = []
        t = 0.0
        while t < horizon_hours:
            gaps = rng.exponential(1.0 / self.rate_per_hour, size=256)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = float(ts[-1])
        ts = np.concatenate(out)
        return t0_hours + ts[ts < horizon_hours]


@dataclass(frozen=True)
class DiurnalArrivals:
    """Non-homogeneous Poisson: rate(t) = base * profile(t % 24).

    ``profile`` maps an hour-of-day to a non-negative multiplier (default:
    a duck-curve-shaped demand profile peaking in the evening ramp —
    load is *highest* exactly when grid intensity is highest, the
    adversarial case for a carbon-aware scheduler). Sampled by
    Lewis–Shedler thinning against the profile's 24 h supremum — for a
    custom profile spikier than the 0.1 h sampling grid, pass its true
    supremum as ``profile_sup``; thinning against an underestimate is
    invalid and is rejected at sample time.
    """

    base_rate_per_hour: float
    seed: SeedLike = 0
    profile: Callable[[float], float] = None  # type: ignore[assignment]
    amplitude: float = 0.6
    profile_sup: float = 0.0                  # 0 -> estimate from a 24 h grid

    def _profile(self, hour: float) -> float:
        if self.profile is not None:
            return self.profile(hour)
        h = hour % 24.0
        evening = np.exp(-0.5 * ((h - 19.0) / 2.5) ** 2)
        night = np.exp(-0.5 * ((h - 4.0) / 3.0) ** 2)
        return float(1.0 + self.amplitude * (evening - 0.7 * night))

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        if self.base_rate_per_hour <= 0:
            return np.empty(0)
        rng = _fresh_rng(self.seed)
        if self.profile_sup > 0.0:
            sup = self.profile_sup
        else:
            grid = np.linspace(0.0, 24.0, 241)
            sup = max(self._profile(float(h)) for h in grid)
        lam_max = self.base_rate_per_hour * sup
        out = []
        t = 0.0
        while t < horizon_hours:
            t += float(rng.exponential(1.0 / lam_max))
            if t >= horizon_hours:
                break
            lam_t = self.base_rate_per_hour * self._profile(t0_hours + t)
            if lam_t > lam_max:
                raise ValueError(
                    f"profile({t0_hours + t:.3f}) = {lam_t / self.base_rate_per_hour:.4g} "
                    f"exceeds the thinning supremum {sup:.4g}; pass the "
                    "profile's true supremum via profile_sup")
            if rng.uniform() * lam_max <= lam_t:
                out.append(t0_hours + t)
        return np.array(out)


@dataclass(frozen=True)
class MMPPArrivals:
    """Bursty 2-state Markov-modulated Poisson process.

    The phase alternates between a quiet and a burst state with
    exponentially distributed sojourns; within a phase, arrivals are
    Poisson at that phase's rate. Captures the flash-crowd arrival
    pattern a mean-rate Poisson model cannot.
    """

    quiet_rate_per_hour: float
    burst_rate_per_hour: float
    mean_sojourn_hours: float = 1.0
    seed: SeedLike = 0

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        rng = _fresh_rng(self.seed)
        rates = (self.quiet_rate_per_hour, self.burst_rate_per_hour)
        out = []
        t, phase = 0.0, 0
        while t < horizon_hours:
            sojourn = float(rng.exponential(self.mean_sojourn_hours))
            end = min(t + sojourn, horizon_hours)
            rate = rates[phase]
            if rate > 0:
                tt = t
                while True:
                    tt += float(rng.exponential(1.0 / rate))
                    if tt >= end:
                        break
                    out.append(t0_hours + tt)
            t, phase = end, 1 - phase
        return np.array(out)


@dataclass(frozen=True)
class TraceReplayArrivals:
    """Replay recorded absolute arrival hours (e.g. a production schedule
    or a previous sim run's arrival log) — clipped to the window."""

    arrival_hours: Sequence[float]

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        ts = np.sort(np.asarray(self.arrival_hours, dtype=float))
        return ts[(ts >= t0_hours) & (ts < t0_hours + horizon_hours)]
