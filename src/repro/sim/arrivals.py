"""Composable arrival processes for the serving simulator (DESIGN.md §2).

Each process answers one question — *at which simulated hours do requests
arrive in [t0, t0 + horizon)?* — and is deterministic under a fixed seed:
``times()`` draws from a fresh ``np.random.Generator`` seeded at
construction, so two runs of the same scenario are identical sample for
sample (the seed-determinism regression test asserts byte-identical
metric reports).

Processes (GreenScale's workload taxonomy, arXiv 2304.00404: arrival
dynamics drive the carbon savings available to a deferral policy):

- :class:`ConstantRateArrivals` — deterministic, equally spaced. The
  static-scenario parity case: driving the engine with this process and a
  StaticProvider must reproduce the paper's Table II/IV/V numbers.
- :class:`PoissonArrivals`      — homogeneous Poisson (exponential gaps).
- :class:`DiurnalArrivals`      — non-homogeneous Poisson, rate modulated
  by a diurnal (duck-curve-shaped) profile, via Lewis–Shedler thinning.
- :class:`MMPPArrivals`         — bursty 2-state Markov-modulated Poisson.
- :class:`TraceReplayArrivals`  — replay recorded absolute arrival hours.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Protocol, Sequence, Union, runtime_checkable

import numpy as np

SeedLike = Union[int, np.random.Generator]


def _fresh_rng(seed: SeedLike) -> np.random.Generator:
    """A generator whose stream restarts every call — int seeds make
    ``times()`` a pure function; passing a Generator hands the caller
    control of (and responsibility for) the stream position."""
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(seed)


@runtime_checkable
class ArrivalProcess(Protocol):
    """Arrival hours in [t0, t0 + horizon), sorted ascending."""

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        ...


@dataclass(frozen=True)
class ConstantRateArrivals:
    """Equally spaced arrivals — no RNG, the parity baseline."""

    rate_per_hour: float

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        n = int(np.floor(self.rate_per_hour * horizon_hours))
        if n <= 0:
            return np.empty(0)
        return t0_hours + np.arange(n) / self.rate_per_hour


@dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson process at ``rate_per_hour``."""

    rate_per_hour: float
    seed: SeedLike = 0

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        if self.rate_per_hour <= 0:
            return np.empty(0)
        rng = _fresh_rng(self.seed)
        # Draw gaps in chunks until the horizon is covered.
        out = []
        t = 0.0
        while t < horizon_hours:
            gaps = rng.exponential(1.0 / self.rate_per_hour, size=256)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = float(ts[-1])
        ts = np.concatenate(out)
        return t0_hours + ts[ts < horizon_hours]


@dataclass(frozen=True)
class DiurnalArrivals:
    """Non-homogeneous Poisson: rate(t) = base * profile(t % 24).

    ``profile`` maps an hour-of-day to a non-negative multiplier (default:
    a duck-curve-shaped demand profile peaking in the evening ramp —
    load is *highest* exactly when grid intensity is highest, the
    adversarial case for a carbon-aware scheduler). Sampled by
    Lewis–Shedler thinning against the profile's 24 h supremum — for a
    custom profile spikier than the 0.1 h sampling grid, pass its true
    supremum as ``profile_sup``; thinning against an underestimate is
    invalid and is rejected at sample time.
    """

    base_rate_per_hour: float
    seed: SeedLike = 0
    profile: Callable[[float], float] = None  # type: ignore[assignment]
    amplitude: float = 0.6
    profile_sup: float = 0.0                  # 0 -> estimate from a 24 h grid

    def _profile(self, hour: float) -> float:
        if self.profile is not None:
            return self.profile(hour)
        h = hour % 24.0
        evening = np.exp(-0.5 * ((h - 19.0) / 2.5) ** 2)
        night = np.exp(-0.5 * ((h - 4.0) / 3.0) ** 2)
        return float(1.0 + self.amplitude * (evening - 0.7 * night))

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        if self.base_rate_per_hour <= 0:
            return np.empty(0)
        rng = _fresh_rng(self.seed)
        if self.profile_sup > 0.0:
            sup = self.profile_sup
        else:
            grid = np.linspace(0.0, 24.0, 241)
            sup = max(self._profile(float(h)) for h in grid)
        lam_max = self.base_rate_per_hour * sup
        out = []
        t = 0.0
        while t < horizon_hours:
            t += float(rng.exponential(1.0 / lam_max))
            if t >= horizon_hours:
                break
            lam_t = self.base_rate_per_hour * self._profile(t0_hours + t)
            if lam_t > lam_max:
                raise ValueError(
                    f"profile({t0_hours + t:.3f}) = {lam_t / self.base_rate_per_hour:.4g} "
                    f"exceeds the thinning supremum {sup:.4g}; pass the "
                    "profile's true supremum via profile_sup")
            if rng.uniform() * lam_max <= lam_t:
                out.append(t0_hours + t)
        return np.array(out)


@dataclass(frozen=True)
class MMPPArrivals:
    """Bursty 2-state Markov-modulated Poisson process.

    The phase alternates between a quiet and a burst state with
    exponentially distributed sojourns; within a phase, arrivals are
    Poisson at that phase's rate. Captures the flash-crowd arrival
    pattern a mean-rate Poisson model cannot.
    """

    quiet_rate_per_hour: float
    burst_rate_per_hour: float
    mean_sojourn_hours: float = 1.0
    seed: SeedLike = 0

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        rng = _fresh_rng(self.seed)
        rates = (self.quiet_rate_per_hour, self.burst_rate_per_hour)
        out = []
        t, phase = 0.0, 0
        while t < horizon_hours:
            sojourn = float(rng.exponential(self.mean_sojourn_hours))
            end = min(t + sojourn, horizon_hours)
            rate = rates[phase]
            if rate > 0:
                tt = t
                while True:
                    tt += float(rng.exponential(1.0 / rate))
                    if tt >= end:
                        break
                    out.append(t0_hours + tt)
            t, phase = end, 1 - phase
        return np.array(out)


@dataclass(frozen=True)
class TraceReplayArrivals:
    """Replay recorded absolute arrival hours (e.g. a production schedule
    or a previous sim run's arrival log) — clipped to the window."""

    arrival_hours: Sequence[float]

    def times(self, t0_hours: float, horizon_hours: float) -> np.ndarray:
        ts = np.sort(np.asarray(self.arrival_hours, dtype=float))
        return ts[(ts >= t0_hours) & (ts < t0_hours + horizon_hours)]


# ---------------------------------------------------------------------------
# Closed-loop clients (DESIGN.md §7)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClientPopulation:
    """A tenant's closed-loop client population.

    Each of the ``n_clients`` clients cycles *think → request → wait for
    completion → think*; demand therefore reacts to latency (a saturated
    executor slows completions, which throttles the offered load — the
    behaviour an open-loop arrival process cannot express). A completion
    slower than ``slo_latency_s`` — or an admission rejection — makes the
    client retry the request after a capped exponential backoff, and
    abandon it (returning to think) after ``max_attempts`` total tries.
    """

    tenant: str
    n_clients: int
    mean_think_hours: float = 0.01
    slo_latency_s: float = float("inf")
    max_attempts: int = 3
    backoff_base_hours: float = 0.002
    backoff_cap_hours: float = 0.05
    priority: int = 0        # same-instant seeding order (higher first)


class ClosedLoopClientPool:
    """Per-tenant closed-loop client populations driving CLIENT_READY /
    RETRY events (DESIGN.md §7, vectorized per §11).

    Determinism contract: all think-time draws come from one
    ``np.random.Generator`` consumed in event-processing order, which the
    event queue makes a pure function of the scenario — so two same-seed
    runs (and the batched vs scalar execute paths, which produce
    identical completions) replay identical client behaviour.

    State lives in per-client *columns* (the TenantRegistry pattern,
    DESIGN.md §7): attempts, think means, SLOs and backoff parameters are
    numpy arrays indexed by client id. The scalar ``on_ready`` /
    ``on_complete`` / ``on_reject`` methods (the heap-oracle path) and
    the ``*_batch`` methods (the calendar path) read the same columns and
    consume the same RNG stream draw-for-draw: numpy Generators produce
    identical values whether ``exponential``/``uniform`` is called once
    per element or once with the parameter vector, which the parity tests
    pin down.
    """

    def __init__(self, populations: Sequence[ClientPopulation], seed: int = 0):
        self.populations = list(populations)
        self._rng = np.random.default_rng(seed)
        self._pop: List[ClientPopulation] = []   # per client (scalar path)
        self.tenant_names: List[str] = []
        tenant_idx: dict = {}
        codes: List[int] = []
        for p in self.populations:
            code = tenant_idx.get(p.tenant)
            if code is None:
                code = tenant_idx[p.tenant] = len(self.tenant_names)
                self.tenant_names.append(p.tenant)
            for _ in range(p.n_clients):
                self._pop.append(p)
                codes.append(code)
        n = len(self._pop)
        self._attempts = np.zeros(n, dtype=np.int64)  # current request
        self._tenant_code = np.asarray(codes, dtype=np.int64)
        self._mean_think = np.array(
            [p.mean_think_hours for p in self._pop], dtype=float)
        self._slo = np.array([p.slo_latency_s for p in self._pop],
                             dtype=float)
        self._max_attempts = np.array([p.max_attempts for p in self._pop],
                                      dtype=np.int64)
        self._backoff_base = np.array(
            [p.backoff_base_hours for p in self._pop], dtype=float)
        self._backoff_cap = np.array(
            [p.backoff_cap_hours for p in self._pop], dtype=float)
        self._priority = np.array([p.priority for p in self._pop],
                                  dtype=np.int64)

    @property
    def n_clients(self) -> int:
        return len(self._pop)

    def tenant_of(self, client_id: int) -> str:
        return self._pop[client_id].tenant

    def _think(self, client_id: int) -> float:
        return float(self._rng.exponential(
            self._pop[client_id].mean_think_hours))

    def _backoff(self, client_id: int) -> float:
        p = self._pop[client_id]
        tries = max(self._attempts[client_id] - 1, 0)
        return min(p.backoff_base_hours * (2.0 ** tries),
                   p.backoff_cap_hours)

    def initial_events_arrays(self, start_hour: float):
        """Vectorized :meth:`initial_events`: ``(hours, client_ids)``
        arrays in the same (hour, -priority, client_id) order, drawn from
        the same RNG stream position (one ``uniform`` call over the
        per-client think-mean column instead of n scalar draws)."""
        ats = start_hour + self._rng.uniform(0.0, self._mean_think)
        order = np.lexsort((np.arange(self.n_clients), -self._priority, ats))
        return ats[order], order.astype(np.int64)

    def initial_events(self, start_hour: float) -> List:
        """(hour, client_id) first-request times, staggered uniformly over
        each client's mean think time. Sorted by (hour, -priority,
        client_id) so same-instant requests enqueue higher-priority
        tenants first — the only scheduling effect of ``priority``."""
        ats, cids = self.initial_events_arrays(start_hour)
        return list(zip(ats.tolist(), cids.tolist()))

    def on_ready(self, client_id: int) -> str:
        """The client issues a request; returns its tenant name."""
        if self._attempts[client_id] == 0:
            self._attempts[client_id] = 1
        return self.tenant_of(client_id)

    def on_complete(self, client_id: int, latency_s: float,
                    now_hour: float):
        """Request finished with end-to-end ``latency_s``. Returns
        ``(verdict, next_hour)``: ``"ok"``/``"abandon"`` schedule the next
        CLIENT_READY after think time; ``"retry"`` schedules a RETRY after
        backoff."""
        p = self._pop[client_id]
        if latency_s <= p.slo_latency_s:
            self._attempts[client_id] = 0
            return "ok", now_hour + self._think(client_id)
        return self._failed(client_id, now_hour)

    def on_reject(self, client_id: int, now_hour: float):
        """Admission control rejected the request — same retry/abandon
        ladder as an SLO miss."""
        return self._failed(client_id, now_hour)

    def give_up(self, client_id: int) -> None:
        """Drop the client's in-flight request without a further retry
        (the driver calls this when a retry lands past the sim horizon,
        counting the abandon itself)."""
        self._attempts[client_id] = 0

    def _failed(self, client_id: int, now_hour: float):
        p = self._pop[client_id]
        if self._attempts[client_id] >= p.max_attempts:
            self._attempts[client_id] = 0
            return "abandon", now_hour + self._think(client_id)
        back = self._backoff(client_id)
        self._attempts[client_id] += 1
        return "retry", now_hour + back

    # -- batched verdicts (DESIGN.md §11: the calendar driver's path) -------
    def tenant_codes_of(self, client_ids: np.ndarray) -> np.ndarray:
        """Tenant code per client (index into :attr:`tenant_names`)."""
        return self._tenant_code[client_ids]

    def on_ready_batch(self, client_ids: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`on_ready` over a CLIENT_READY/RETRY run:
        first tries mark attempt 1; returns tenant codes. No RNG."""
        att = self._attempts[client_ids]
        self._attempts[client_ids] = np.where(att == 0, 1, att)
        return self._tenant_code[client_ids]

    def _failed_batch(self, ids: np.ndarray, att: np.ndarray,
                      fail: np.ndarray, now_hours: np.ndarray,
                      next_hours: np.ndarray):
        """Shared retry/abandon ladder over the failing subset; fills
        ``next_hours`` for retries and returns (retry_mask,
        abandon_mask, think_pending_mask) over the full batch. Think
        draws for abandons are left to the caller so ok+abandon draws
        stay in completion order (one stream, DESIGN.md §2.2)."""
        abandon = fail & (att >= self._max_attempts[ids])
        retry = fail & ~abandon
        if retry.any():
            tries = np.maximum(att[retry] - 1, 0)
            back = np.minimum(
                self._backoff_base[ids[retry]] * (2.0 ** tries),
                self._backoff_cap[ids[retry]])
            next_hours[retry] = now_hours[retry] + back
        self._attempts[ids] = np.where(retry, att + 1, 0)
        return retry, abandon

    def on_complete_batch(self, client_ids: np.ndarray,
                          latencies_s: np.ndarray, now_hours: np.ndarray):
        """Vectorized :meth:`on_complete` over a completion batch, RNG
        draw-for-draw identical to the scalar loop: one ``exponential``
        call covers the ok+abandon think times in completion order (retry
        backoff is deterministic and draws nothing). Returns
        ``(retry_mask, abandon_mask, next_hours)``."""
        ids = np.asarray(client_ids)
        att = self._attempts[ids]
        ok = latencies_s <= self._slo[ids]
        next_hours = np.empty(ids.size, dtype=float)
        retry, abandon = self._failed_batch(ids, att, ~ok, now_hours,
                                            next_hours)
        think = ok | abandon
        if think.any():
            next_hours[think] = now_hours[think] + self._rng.exponential(
                self._mean_think[ids[think]])
        return retry, abandon, next_hours

    def on_reject_batch(self, client_ids: np.ndarray,
                        now_hours: np.ndarray):
        """Vectorized :meth:`on_reject`: every request in the batch
        failed admission — same ladder, same RNG order."""
        ids = np.asarray(client_ids)
        att = self._attempts[ids]
        next_hours = np.empty(ids.size, dtype=float)
        fail = np.ones(ids.size, dtype=bool)
        retry, abandon = self._failed_batch(ids, att, fail, now_hours,
                                            next_hours)
        if abandon.any():
            next_hours[abandon] = now_hours[abandon] + self._rng.exponential(
                self._mean_think[ids[abandon]])
        return retry, abandon, next_hours
