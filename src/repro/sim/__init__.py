"""Discrete-event carbon-aware serving simulator (DESIGN.md §2).

Drives the engine/serving stack through simulated time: seeded arrival
processes -> event heap -> batched ``CarbonEdgeEngine.step`` calls with an
advancing ``now_hour`` -> queueing/SLO/carbon metrics.
"""
from repro.sim.arrivals import (ArrivalProcess, ClientPopulation,
                                ClosedLoopClientPool, ConstantRateArrivals,
                                DiurnalArrivals, MMPPArrivals,
                                PoissonArrivals, TraceReplayArrivals)
from repro.sim.clock import VirtualClock, hours_to_s, ms_to_hours, s_to_hours
from repro.sim.driver import AsyncEngineDriver, BatchExecutor
from repro.sim.events import (Event, EventCalendar, EventHeap, EventKind,
                              SimExhausted)
from repro.sim.metrics import (MetricsCollector, TaskRecord, TimelineSample,
                               WAIT_HIST_EDGES_S)

__all__ = [
    "ArrivalProcess", "ClientPopulation", "ClosedLoopClientPool",
    "ConstantRateArrivals", "DiurnalArrivals",
    "MMPPArrivals", "PoissonArrivals", "TraceReplayArrivals",
    "VirtualClock", "hours_to_s", "ms_to_hours", "s_to_hours",
    "AsyncEngineDriver", "BatchExecutor",
    "Event", "EventCalendar", "EventHeap", "EventKind", "SimExhausted",
    "MetricsCollector", "TaskRecord", "TimelineSample", "WAIT_HIST_EDGES_S",
]
