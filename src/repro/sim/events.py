"""Event model for the discrete-event simulator (DESIGN.md §2).

Nine event kinds drive the serving loop:

- ``ARRIVAL``        — an open-loop request enters the system;
- ``CLIENT_READY``   — a closed-loop client's think time elapsed: it
  issues its next request (payload: client id, DESIGN.md §7);
- ``RETRY``          — a closed-loop client re-issues a request that
  missed its SLO or was rejected by admission control, after backoff
  (payload: client id);
- ``BATCH_READY``    — the driver should drain a batch through the engine;
- ``DEFER_WAKE``     — a deferred task's planned green slot (payload: the
  parked task tuple) or a budget-deferred tenant's next accounting
  period (payload ``None`` — the driver polls ``engine.pop_ripe``)
  has arrived;
- ``INTENSITY_TICK`` — periodic sample point for the carbon/latency timeline;
- ``NODE_DOWN``      — a node degrades (payload: the
  :class:`repro.resilience.Fault` — a crash, a latency-straggler window
  opening, a link flap, or the delayed *detection* of an earlier crash,
  DESIGN.md §10);
- ``NODE_UP``        — the matching restoration (recover / window close);
- ``PROVIDER_OUTAGE`` — a carbon-provider blackout window opens or closes
  (payload: the Fault; the injector toggles the engine provider's
  last-known-good degraded mode).

Determinism contract: events are totally ordered by
``(time_hours, seq)`` where ``seq`` is a per-heap monotonic counter
assigned at push time. Two events at the same simulated instant therefore
pop in *insertion* order — no hash ordering, no RNG, no wall clock — so a
run is a pure function of (arrival process seed, scenario parameters).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, List, Optional


class EventKind(Enum):
    ARRIVAL = "arrival"
    CLIENT_READY = "client_ready"
    RETRY = "retry"
    BATCH_READY = "batch_ready"
    DEFER_WAKE = "defer_wake"
    INTENSITY_TICK = "intensity_tick"
    NODE_DOWN = "node_down"
    NODE_UP = "node_up"
    PROVIDER_OUTAGE = "provider_outage"


@dataclass(frozen=True, order=True)
class Event:
    time_hours: float
    seq: int
    kind: EventKind = field(compare=False)
    payload: Any = field(compare=False, default=None)


class EventHeap:
    """Min-heap of :class:`Event` with deterministic FIFO tie-breaking."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time_hours: float, kind: EventKind,
             payload: Any = None) -> Event:
        ev = Event(float(time_hours), self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
