"""Event model for the discrete-event simulator (DESIGN.md §2, §11).

Nine event kinds drive the serving loop:

- ``ARRIVAL``        — an open-loop request enters the system;
- ``CLIENT_READY``   — a closed-loop client's think time elapsed: it
  issues its next request (payload: client id, DESIGN.md §7);
- ``RETRY``          — a closed-loop client re-issues a request that
  missed its SLO or was rejected by admission control, after backoff
  (payload: client id);
- ``BATCH_READY``    — the driver should drain a batch through the engine;
- ``DEFER_WAKE``     — a deferred task's planned green slot (payload: the
  parked task tuple) or a budget-deferred tenant's next accounting
  period (payload ``None`` — the driver polls ``engine.pop_ripe``)
  has arrived;
- ``INTENSITY_TICK`` — periodic sample point for the carbon/latency timeline;
- ``NODE_DOWN``      — a node degrades (payload: the
  :class:`repro.resilience.Fault` — a crash, a latency-straggler window
  opening, a link flap, or the delayed *detection* of an earlier crash,
  DESIGN.md §10);
- ``NODE_UP``        — the matching restoration (recover / window close);
- ``PROVIDER_OUTAGE`` — a carbon-provider blackout window opens or closes
  (payload: the Fault; the injector toggles the engine provider's
  last-known-good degraded mode).

Determinism contract: events are totally ordered by
``(time_hours, seq)`` where ``seq`` is a per-queue monotonic counter
assigned at push time. Two events at the same simulated instant therefore
pop in *insertion* order — no hash ordering, no RNG, no wall clock — so a
run is a pure function of (arrival process seed, scenario parameters).

Two queue implementations honour that contract:

- :class:`EventHeap` — the original scalar ``heapq``: one Python
  comparison-driven pop per event. Retained as the bit-exact parity
  oracle (the same role the scalar scheduler plays for the vectorized
  policy, DESIGN.md §1).
- :class:`EventCalendar` — an array-based calendar queue (DESIGN.md §11):
  events live in time-bucketed column arrays ``(time, seq, kind,
  payload)``; each bucket is lazily ``np.lexsort``-ed by ``(time, seq)``
  when the drain reaches it, pops advance a cursor, and
  :meth:`EventCalendar.pop_run` hands the driver a whole same-kind run of
  events in one numpy slice — the O(batches) event loop.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class SimExhausted(IndexError):
    """``pop()`` on an empty event queue.

    Subclasses :class:`IndexError` (what ``heapq.heappop`` used to leak)
    so pre-existing callers that caught the bare built-in keep working,
    while the message now says *what* ran dry instead of pointing at a
    heapq internal.
    """


class EventKind(Enum):
    ARRIVAL = "arrival"
    CLIENT_READY = "client_ready"
    RETRY = "retry"
    BATCH_READY = "batch_ready"
    DEFER_WAKE = "defer_wake"
    INTENSITY_TICK = "intensity_tick"
    NODE_DOWN = "node_down"
    NODE_UP = "node_up"
    PROVIDER_OUTAGE = "provider_outage"


# Stable integer codes for the calendar's kind column. Enum definition
# order is part of the public layout (DESIGN.md §11).
KIND_LIST: Tuple[EventKind, ...] = tuple(EventKind)
KIND_CODE: Dict[EventKind, int] = {k: i for i, k in enumerate(KIND_LIST)}
# Kinds whose payload is a small int (a client id): the calendar stores
# the value directly in the payload column — the id doubles as the index
# into the client pool's state columns, so no per-event object exists.
_INT_PAYLOAD_CODES = frozenset((KIND_CODE[EventKind.CLIENT_READY],
                                KIND_CODE[EventKind.RETRY]))


@dataclass(frozen=True, order=True)
class Event:
    time_hours: float
    seq: int
    kind: EventKind = field(compare=False)
    payload: Any = field(compare=False, default=None)


class EventHeap:
    """Min-heap of :class:`Event` with deterministic FIFO tie-breaking."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time_hours: float, kind: EventKind,
             payload: Any = None) -> Event:
        ev = Event(float(time_hours), self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        if not self._heap:
            raise SimExhausted("pop from an empty EventHeap — the event "
                               "loop drained every scheduled event")
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class _Bucket:
    """One calendar bucket: sorted column arrays + a cursor, plus two
    overlays for events pushed after the bucket was built:

    - ``chunks`` — batch pushes (array quads). Folded into the sorted
      columns (one ``np.lexsort`` over the *remaining* rows) the next
      time the bucket is read; batch pushes rarely target the current
      bucket (client verdicts land think/backoff hours ahead), so the
      fold is amortized.
    - ``ph`` — scalar pushes, kept as a ``heapq`` of ``(time, seq, kind,
      payload)`` tuples and *served in place*: reads compare the heap
      front against the array cursor and take the smaller key, so the
      saturated regime (one immediate BATCH_READY push per drained
      batch, straight into the current bucket) costs O(log overlay) per
      event instead of re-sorting the bucket remainder on every
      push/pop cycle.
    """

    __slots__ = ("t", "s", "k", "p", "i", "ph", "pl", "chunks")

    def __init__(self):
        self.t = _EMPTY_F
        self.s = _EMPTY_I
        self.k = _EMPTY_I
        self.p = _EMPTY_I
        self.i = 0
        self.ph: List[tuple] = []      # scalar-push overlay (heapq)
        self.pl: List[tuple] = []      # small batch-push spill (tuples)
        self.chunks: List[tuple] = []  # batch-push overlay (array quads)

    def remaining(self) -> int:
        n = (self.t.size - self.i) + len(self.ph) + len(self.pl)
        for c in self.chunks:
            n += c[0].size
        return n

    def fold_chunks(self) -> None:
        """Fold the batch-push overlay into the sorted remainder.

        Every overlay event carries a seq strictly greater than every
        stored row's (seq is globally monotonic and the stored rows were
        all pushed before the fold that built them), so a stable
        time-keyed ``searchsorted(side="right")`` insert restores the
        exact global ``(time, seq)`` order — one O(remaining) memcpy
        instead of a lexsort over the whole bucket remainder."""
        if not self.chunks and not self.pl:
            return
        ts: List[np.ndarray] = []
        ss: List[np.ndarray] = []
        ks: List[np.ndarray] = []
        ps: List[np.ndarray] = []
        if self.pl:
            a, b, c, d = zip(*self.pl)
            self.pl = []
            ts.append(np.asarray(a, dtype=np.float64))
            ss.append(np.asarray(b, dtype=np.int64))
            ks.append(np.asarray(c, dtype=np.int64))
            ps.append(np.asarray(d, dtype=np.int64))
        for ct, cs, ck, cp in self.chunks:
            ts.append(ct)
            ss.append(cs)
            ks.append(ck)
            ps.append(cp)
        self.chunks = []
        if len(ts) == 1:
            t, s, k, p = ts[0], ss[0], ks[0], ps[0]
        else:
            t = np.concatenate(ts)
            s = np.concatenate(ss)
            k = np.concatenate(ks)
            p = np.concatenate(ps)
        order = np.lexsort((s, t))
        t, s, k, p = t[order], s[order], k[order], p[order]
        i = self.i
        if i >= self.t.size:
            self.t, self.s, self.k, self.p = t, s, k, p
            self.i = 0
            return
        pos = np.searchsorted(self.t[i:], t, side="right")
        self.t = np.insert(self.t[i:], pos, t)
        self.s = np.insert(self.s[i:], pos, s)
        self.k = np.insert(self.k[i:], pos, k)
        self.p = np.insert(self.p[i:], pos, p)
        self.i = 0

    def heap_first(self) -> bool:
        """True when the scalar overlay holds the bucket's next event."""
        if not self.ph:
            return False
        if self.i >= self.t.size:
            return True
        ot, os_ = self.ph[0][0], self.ph[0][1]
        at = float(self.t[self.i])
        return ot < at or (ot == at and os_ < int(self.s[self.i]))

    def array_cut(self, end: int) -> int:
        """First array index in ``[i, end)`` whose ``(time, seq)`` key is
        past the scalar overlay's front — the sorted prefix that may be
        served before the overlay interleaves."""
        if not self.ph:
            return end
        kt, ks = self.ph[0][0], self.ph[0][1]
        i = self.i
        lo = i + int(np.searchsorted(self.t[i:end], kt, side="left"))
        hi = i + int(np.searchsorted(self.t[i:end], kt, side="right"))
        if lo < hi:
            lo += int(np.searchsorted(self.s[lo:hi], ks, side="left"))
        return lo


_EMPTY_F = np.empty(0, dtype=np.float64)
_EMPTY_I = np.empty(0, dtype=np.int64)


class EventCalendar:
    """Array-based event calendar (DESIGN.md §11) — same ``(time, seq)``
    total order as :class:`EventHeap`, O(batches) access.

    Layout: events are four parallel columns ``(time f64, seq i64, kind
    i64 code, payload i64)`` split into fixed-width time buckets. Buckets
    fill as append-only chunks and are ``np.lexsort``-ed once when the
    drain reaches them; a cursor then serves pops in order. Scalar
    pushes into the *current* bucket (immediate flushes, short retries)
    land in a per-bucket heap overlay that reads interleave with the
    sorted columns on the fly — O(log overlay) per event, no re-sort.
    Exhausted buckets are freed, so live memory tracks the future-event
    population, not the replay length.

    Payload column: ``-1`` = no payload; for ``CLIENT_READY`` / ``RETRY``
    the value is the client id itself (the index into the pool's state
    columns); for every other kind it indexes a per-kind Python object
    store (fault objects, parked-task tuples).

    The bucket width is chosen at first read so the initial load averages
    ``target_bucket_events`` per bucket; later pushes land in O(1). The
    default target balances merge cost (lexsort over a bucket's remaining
    rows on every overlay fold) against push fan-out (batch pushes split
    into one chunk per touched bucket) — benchmarks/sim_scale.py sweeps
    it; output is invariant to it by construction.
    """

    def __init__(self, target_bucket_events: int = 512):
        self._target = max(1, int(target_bucket_events))
        self._seq = 0
        self._n = 0
        self._active = False
        self._stage: List[tuple] = []      # pre-activation chunks
        self._t0 = 0.0
        self._width = 1.0
        self._buckets: Dict[int, _Bucket] = {}
        self._bq: List[int] = []           # min-heap of bucket indices
        self._cur: Optional[_Bucket] = None
        self._cur_idx = 0
        self._obj: Dict[int, List[Any]] = {}

    # -- push ---------------------------------------------------------------
    def _pidx(self, code: int, payload: Any) -> int:
        if payload is None:
            return -1
        if code in _INT_PAYLOAD_CODES:
            return int(payload)
        store = self._obj.setdefault(code, [])
        store.append(payload)
        return len(store) - 1

    def push(self, time_hours: float, kind: EventKind,
             payload: Any = None) -> None:
        code = KIND_CODE[kind]
        t = float(time_hours)
        seq = self._seq
        self._seq += 1
        self._n += 1
        p = self._pidx(code, payload)
        if not self._active:
            one = (np.array([t]), np.array([seq], dtype=np.int64),
                   np.array([code], dtype=np.int64),
                   np.array([p], dtype=np.int64))
            self._stage.append(one)
            return
        b = self._bucket_for(t)
        heapq.heappush(b.ph, (t, seq, code, p))

    def push_batch(self, times: np.ndarray, kind, payloads=None) -> None:
        """Push ``len(times)`` events in one call, assigning the same
        consecutive seq numbers a scalar push loop would. ``kind`` is one
        :class:`EventKind` or an int-code array (mixed-kind runs, e.g.
        interleaved CLIENT_READY/RETRY schedules); ``payloads`` is None
        or an int array (client ids)."""
        t = np.ascontiguousarray(times, dtype=np.float64)
        n = t.size
        if n == 0:
            return
        s = np.arange(self._seq, self._seq + n, dtype=np.int64)
        self._seq += n
        self._n += n
        if isinstance(kind, EventKind):
            k = np.full(n, KIND_CODE[kind], dtype=np.int64)
        else:
            k = np.ascontiguousarray(kind, dtype=np.int64)
        if payloads is None:
            p = np.full(n, -1, dtype=np.int64)
        else:
            p = np.ascontiguousarray(payloads, dtype=np.int64)
        if not self._active:
            self._stage.append((t, s, k, p))
            return
        idx = self._indices_for(t)
        if idx.size == 1 or (idx[0] == idx).all():
            self._bucket_at(int(idx[0])).chunks.append((t, s, k, p))
            return
        if n <= 128:
            # small scatter (client verdicts fanning out over think
            # times): a tuple loop into per-bucket spill lists beats the
            # argsort/split machinery below, which pays ~one chunk per
            # touched bucket
            tl = t.tolist()
            sl = s.tolist()
            kl = k.tolist()
            pl = p.tolist()
            for j, bi in enumerate(idx.tolist()):
                self._bucket_at(bi).pl.append((tl[j], sl[j], kl[j], pl[j]))
            return
        order = np.argsort(idx, kind="stable")
        idx_sorted = idx[order]
        t, s, k, p = t[order], s[order], k[order], p[order]
        cuts = np.flatnonzero(np.diff(idx_sorted)) + 1
        starts = np.concatenate(([0], cuts))
        ends = np.concatenate((cuts, [n]))
        for a, z in zip(starts.tolist(), ends.tolist()):
            self._bucket_at(int(idx_sorted[a])).chunks.append(
                (t[a:z], s[a:z], k[a:z], p[a:z]))

    def _indices_for(self, t: np.ndarray) -> np.ndarray:
        idx = np.floor((t - self._t0) / self._width).astype(np.int64)
        # Never file behind the drain: an index at-or-before the current
        # bucket merges into it (its (time, seq) key still sorts first).
        return np.maximum(idx, self._cur_idx)

    def _bucket_for(self, t: float) -> _Bucket:
        i = int((t - self._t0) / self._width)
        if i < self._cur_idx:
            i = self._cur_idx
        return self._bucket_at(i)

    def _bucket_at(self, i: int) -> _Bucket:
        b = self._buckets.get(i)
        if b is None:
            b = _Bucket()
            self._buckets[i] = b
            heapq.heappush(self._bq, i)
        return b

    # -- activation ---------------------------------------------------------
    def _activate(self) -> None:
        """First read: derive the bucket width from the staged bulk load
        and distribute it. Until now every push was O(1) staging."""
        self._active = True
        if not self._stage:
            return
        t = np.concatenate([c[0] for c in self._stage])
        s = np.concatenate([c[1] for c in self._stage])
        k = np.concatenate([c[2] for c in self._stage])
        p = np.concatenate([c[3] for c in self._stage])
        self._stage = []
        self._t0 = float(t.min())
        span = float(t.max()) - self._t0
        n_buckets = max(1, min(t.size // self._target, 1 << 20))
        self._width = (span / n_buckets) if span > 0 and n_buckets > 1 else \
            max(span, 1.0)
        self._cur_idx = 0
        idx = np.floor((t - self._t0) / self._width).astype(np.int64)
        order = np.argsort(idx, kind="stable")
        idx = idx[order]
        t, s, k, p = t[order], s[order], k[order], p[order]
        cuts = np.flatnonzero(np.diff(idx)) + 1
        starts = np.concatenate(([0], cuts))
        ends = np.concatenate((cuts, [t.size]))
        for a, z in zip(starts.tolist(), ends.tolist()):
            self._bucket_at(int(idx[a])).chunks.append(
                (t[a:z], s[a:z], k[a:z], p[a:z]))

    # -- drain --------------------------------------------------------------
    def _front(self) -> Optional[_Bucket]:
        """The bucket holding the globally-next event, batch overlay
        folded in and cursor/scalar-overlay valid — or None when the
        calendar is empty."""
        if not self._active:
            self._activate()
        while True:
            b = self._cur
            if b is not None:
                b.fold_chunks()
                if b.i < b.t.size or b.ph:
                    return b
                del self._buckets[self._cur_idx]
                self._cur = None
            if not self._bq:
                return None
            i = heapq.heappop(self._bq)
            b = self._buckets.get(i)
            if b is None or (b.i >= b.t.size and not b.ph and not b.chunks
                             and not b.pl):
                continue      # stale heap entry (freed / already drained)
            self._cur = b
            self._cur_idx = i

    def _resolve(self, code: int, p: int) -> Any:
        if p < 0:
            return None
        if code in _INT_PAYLOAD_CODES:
            return p
        return self._obj[code][p]

    def pop(self) -> Event:
        b = self._front()
        if b is None:
            raise SimExhausted("pop from an empty EventCalendar — the "
                               "event loop drained every scheduled event")
        self._n -= 1
        if b.heap_first():
            t, s, code, p = heapq.heappop(b.ph)
            return Event(t, s, KIND_LIST[code], self._resolve(code, p))
        i = b.i
        b.i = i + 1
        code = int(b.k[i])
        return Event(float(b.t[i]), int(b.s[i]), KIND_LIST[code],
                     self._resolve(code, int(b.p[i])))

    def peek(self) -> Optional[Event]:
        b = self._front()
        if b is None:
            return None
        if b.heap_first():
            t, s, code, p = b.ph[0]
            return Event(t, s, KIND_LIST[code], self._resolve(code, p))
        i = b.i
        code = int(b.k[i])
        return Event(float(b.t[i]), int(b.s[i]), KIND_LIST[code],
                     self._resolve(code, int(b.p[i])))

    def peek_key(self) -> Optional[Tuple[float, int]]:
        """(time_hours, kind_code) of the next event without building an
        :class:`Event` — the driver's dispatch probe."""
        b = self._front()
        if b is None:
            return None
        if b.heap_first():
            return b.ph[0][0], b.ph[0][2]
        return float(b.t[b.i]), int(b.k[b.i])

    def pop_run(self, codes: Sequence[int], max_n: int,
                max_time: float = np.inf):
        """Pop the maximal prefix of events whose kind code is in
        ``codes``, up to ``max_n`` events with ``time <= max_time`` —
        the batched-dispatch primitive (DESIGN.md §11 windowing rule).
        Returns ``(times, payload_ints, kind_codes)`` in global
        ``(time, seq)`` order; empty arrays when the next event doesn't
        qualify."""
        seg_t: List[np.ndarray] = []
        seg_p: List[np.ndarray] = []
        seg_k: List[np.ndarray] = []
        buf_t: List[float] = []        # scalar-overlay events, in order
        buf_p: List[int] = []
        buf_k: List[int] = []

        def flush_buf() -> None:
            if buf_t:
                seg_t.append(np.asarray(buf_t, dtype=np.float64))
                seg_p.append(np.asarray(buf_p, dtype=np.int64))
                seg_k.append(np.asarray(buf_k, dtype=np.int64))
                del buf_t[:], buf_p[:], buf_k[:]

        left = int(max_n)
        stop = False
        while left > 0 and not stop:
            b = self._front()
            if b is None:
                break
            while left > 0:
                if b.heap_first():
                    ot, _, oc, op = b.ph[0]
                    if oc not in codes or not ot <= max_time:
                        stop = True         # next event doesn't qualify
                        break
                    heapq.heappop(b.ph)
                    buf_t.append(ot)
                    buf_p.append(op)
                    buf_k.append(oc)
                    self._n -= 1
                    left -= 1
                    continue
                i = b.i
                if i >= b.t.size:
                    break                   # bucket drained -> next bucket
                end = b.array_cut(b.t.size)
                ks = b.k[i:end]
                ok = ks == codes[0]
                for c in codes[1:]:
                    ok |= ks == c
                if max_time != np.inf:
                    ok &= b.t[i:end] <= max_time
                bad = np.flatnonzero(~ok)
                run = int(bad[0]) if bad.size else ok.size
                take = min(run, left)
                if take:
                    flush_buf()
                    seg_t.append(b.t[i:i + take])
                    seg_p.append(b.p[i:i + take])
                    seg_k.append(ks[:take])
                    b.i = i + take
                    self._n -= take
                    left -= take
                if take < run:
                    break                   # max_n reached
                if bad.size:
                    stop = True             # kind change or past the window
                    break
        flush_buf()
        if not seg_t:
            return _EMPTY_F, _EMPTY_I, _EMPTY_I
        if len(seg_t) == 1:
            return seg_t[0], seg_p[0], seg_k[0]
        return (np.concatenate(seg_t), np.concatenate(seg_p),
                np.concatenate(seg_k))

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0
