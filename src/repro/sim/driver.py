"""Async engine driver: the discrete-event serving loop (DESIGN.md §2).

:class:`AsyncEngineDriver` interleaves an arrival process with batched
executor steps through simulated time:

- ``ARRIVAL`` events materialise tasks (via ``task_factory``) and enqueue
  them on the executor; deferrable tasks (``deadline_hours > 0``) are
  instead *planned* through :func:`repro.core.temporal.plan_wake` against
  the driver's forecast provider and parked until their ``DEFER_WAKE``
  (fleet-scale: the planner reads the whole (slots x nodes) grid in one
  batched provider call — DESIGN.md §3.6);
- ``BATCH_READY`` events drain up to ``max_batch`` pending tasks in one
  ``executor.step(now_hour=clock.hour, limit=...)`` call — with the
  default :class:`~repro.core.api.CarbonEdgeEngine` that is one (B, N, 8)
  featurize + one vectorized/Pallas scorer invocation per event batch,
  not one per task, and since DESIGN.md §6 the execute+billing half is
  batched too (one ``cluster.execute_batch`` + one
  ``monitor.record_energy_batch`` per drained batch, bit-identical to the
  per-task loop, so ``metrics.to_text`` is byte-stable across both
  execution paths) — honouring the executor's busy time so queueing
  delay emerges from load rather than being assumed;
- ``INTENSITY_TICK`` events sample the carbon-vs-latency timeline.

``now_hour`` is always the virtual clock, so every provider read (policy
scoring, cluster billing, monitor billing) tracks simulated time — the
property :meth:`CarbonEdgeEngine.run` cannot offer (it freezes the hour
for the whole drain).

Event queues (DESIGN.md §11): ``event_queue="calendar"`` (the default)
runs the loop over the array-based :class:`EventCalendar` — same-kind
event runs pop as numpy slices, client verdicts and metric records move
in column batches, so driver overhead is O(batches).
``event_queue="heap"`` keeps the original scalar loop over
:class:`EventHeap`, retained as the bit-exact parity oracle: both modes
produce byte-identical ``metrics.to_text()`` for the same scenario
(``gate_sim_scale`` pins this in CI).

Executors: anything with ``submit(task)`` and
``step(now_hour, limit) -> results`` — ``CarbonEdgeEngine`` natively, and
``runtime.serving.ServingEngine`` through its ``step`` alias. Results
expose either ``latency_ms`` (serial cluster: service times accumulate)
or ``service_s`` (parallel serving batch: the batch occupies the executor
for its max service time). Note the determinism contract (DESIGN.md
§2.2) covers modelled executors only: a ServingEngine measures real
wall-clock service, so its runs repeat only up to host timing noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from time import perf_counter
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    runtime_checkable)

import numpy as np

from repro.obs.journey import PARK_DEFER, PARK_RETRY
from repro.sim.arrivals import ArrivalProcess, ClosedLoopClientPool
from repro.sim.clock import VirtualClock, hours_to_s, ms_to_hours, s_to_hours
from repro.sim.events import (KIND_CODE, EventCalendar, EventHeap, EventKind)
from repro.sim.metrics import MetricsCollector, TaskRecord, TimelineSample


@runtime_checkable
class BatchExecutor(Protocol):
    """What the driver needs from an engine."""

    def submit(self, task) -> object: ...

    def step(self, now_hour: float = 0.0,
             limit: Optional[int] = None) -> Sequence: ...


@dataclass
class _Pending:
    uid: int
    submit_hour: float
    deferred_hours: float = 0.0
    tenant: str = ""
    client: Optional[int] = None     # closed-loop client id, if any


class _PendFifo:
    """The pending-submission FIFO in column form (DESIGN.md §11): one
    list per :class:`_Pending` field plus a head cursor, so draining a
    batch is a slice — not an O(queue) list copy per event batch, which
    at 10^6 backlogged clients turned the driver quadratic. Used by both
    queue modes; the scalar path materializes `_Pending` objects from the
    columns on take, so its record loop is unchanged."""

    __slots__ = ("_uid", "_sub", "_def", "_ten", "_cli", "_head")

    def __init__(self):
        self._uid: List[int] = []
        self._sub: List[float] = []
        self._def: List[float] = []
        self._ten: List[str] = []
        self._cli: List[int] = []    # -1 = not a closed-loop request
        self._head = 0

    def __len__(self) -> int:
        return len(self._uid) - self._head

    def __bool__(self) -> bool:
        return len(self._uid) > self._head

    def append(self, p: _Pending) -> None:
        self._uid.append(p.uid)
        self._sub.append(p.submit_hour)
        self._def.append(p.deferred_hours)
        self._ten.append(p.tenant)
        self._cli.append(-1 if p.client is None else p.client)

    def append_arrays(self, uids, submit_hours, tenants,
                      client_ids=None) -> None:
        self._uid.extend(uids.tolist())
        self._sub.extend(submit_hours.tolist())
        self._def.extend([0.0] * len(tenants))
        self._ten.extend(tenants)
        if client_ids is None:
            self._cli.extend([-1] * len(tenants))
        else:
            self._cli.extend(client_ids.tolist())

    def _compact(self) -> None:
        h = self._head
        if h > 1024 and h * 2 > len(self._uid):
            del self._uid[:h], self._sub[:h], self._def[:h]
            del self._ten[:h], self._cli[:h]
            self._head = 0

    def take_list(self, n: int) -> List[_Pending]:
        """Drain the first ``n`` entries as `_Pending` objects (the
        scalar record path)."""
        a = self._head
        z = min(a + n, len(self._uid))
        self._head = z
        out = [_Pending(u, s, d, t, None if c < 0 else c)
               for u, s, d, t, c in zip(
                   self._uid[a:z], self._sub[a:z], self._def[a:z],
                   self._ten[a:z], self._cli[a:z])]
        self._compact()
        return out

    def take_arrays(self, n: int):
        """Drain the first ``n`` entries as columns:
        ``(uids, submit_hours, deferred_hours, tenants, client_ids)``."""
        a = self._head
        z = min(a + n, len(self._uid))
        self._head = z
        out = (np.asarray(self._uid[a:z], dtype=np.int64),
               np.asarray(self._sub[a:z], dtype=float),
               np.asarray(self._def[a:z], dtype=float),
               self._ten[a:z],
               np.asarray(self._cli[a:z], dtype=np.int64))
        self._compact()
        return out


_CR = KIND_CODE[EventKind.CLIENT_READY]
_RT = KIND_CODE[EventKind.RETRY]
_AR = KIND_CODE[EventKind.ARRIVAL]


class AsyncEngineDriver:
    """Drive a batch executor through simulated time under an arrival
    process, producing queueing/SLO/carbon metrics.

    ``task_factory(uid, hour)`` builds the submitted object (a ``Task``,
    ``DeferrableTask`` or serving ``Request``). When ``forecast`` is given
    (any provider; a :class:`~repro.core.api.ForecastProvider` uses its
    ``window``), tasks with ``deadline_hours > 0`` are deferred to the
    minimum-forecast-intensity slot within their deadline.
    """

    def __init__(self, executor: BatchExecutor,
                 arrivals: Optional[ArrivalProcess],
                 task_factory: Callable[..., object], *,
                 start_hour: float = 0.0, horizon_hours: float = 1.0,
                 max_batch: int = 8, batch_window_hours: float = 0.0,
                 forecast=None, slot_hours: float = 0.5,
                 slo_latency_s: Optional[float] = None,
                 tick_hours: float = 0.0,
                 clients: Optional[ClosedLoopClientPool] = None,
                 risk_coverage: Optional[float] = None,
                 obs=None, faults=None,
                 event_queue: str = "calendar"):
        if arrivals is None and clients is None:
            raise ValueError("need an arrival process, a closed-loop "
                             "client pool, or both")
        if event_queue not in ("calendar", "heap"):
            raise ValueError("event_queue must be 'calendar' or 'heap', "
                             f"got {event_queue!r}")
        self.executor = executor
        self.arrivals = arrivals
        self.task_factory = task_factory
        self.start_hour = start_hour
        self.horizon_hours = horizon_hours
        self.max_batch = max_batch
        self.batch_window_hours = batch_window_hours
        self.forecast = forecast
        self.slot_hours = slot_hours
        # Risk-bounded deferral planning (DESIGN.md §8): with a coverage
        # level set, deferrable arrivals are planned through
        # plan_wake_risk — a task parks only when the forecast's conformal
        # interval says the future slot beats executing now even at the
        # interval's pessimistic end. None keeps point-forecast planning.
        if risk_coverage is not None and not 0.0 < risk_coverage < 1.0:
            raise ValueError("risk_coverage must be in (0, 1) or None")
        self.risk_coverage = risk_coverage
        self.tick_hours = tick_hours
        # Closed-loop mode (DESIGN.md §7): `clients` drives CLIENT_READY /
        # RETRY events and the task_factory is called as
        # factory(uid, hour, tenant) — for EVERY task source, so mixing an
        # open-loop arrival process with client populations keeps one
        # factory signature (ARRIVAL events pass tenant=""). New requests
        # stop at the horizon; in-flight ones drain.
        self.clients = clients
        # Observability (DESIGN.md §9, §12): spans around the
        # step/record/plan phases of each event batch plus per-EventKind
        # counters; the journeys pillar records each uid's causal path at
        # the enqueue/drain/outcome hooks, and the rollups pillar gets the
        # driver-side folds (SLO misses, availability — the engine folds
        # carbon/energy/verdicts/tenant spend, so sharing one hub between
        # both layers never double-counts). Off (None / disabled) leaves
        # the event loop byte-identical — every hook sits behind a single
        # `is not None` check. Pass the same Observability to the engine
        # and the driver to get one unified view across both layers.
        self.obs = obs if obs is not None and obs.enabled else None
        # Fault injection (DESIGN.md §10): a repro.resilience.FaultInjector
        # whose schedule is surfaced as NODE_DOWN/NODE_UP/PROVIDER_OUTAGE
        # events and applied to the executor when each fires. None (the
        # default) leaves the event loop byte-identical.
        self.faults = faults
        self.clock = VirtualClock(start_hour)
        self._vectorized = event_queue == "calendar"
        self.heap = EventCalendar() if self._vectorized else EventHeap()
        self.metrics = MetricsCollector(slo_latency_s=slo_latency_s)
        self._pending = _PendFifo()          # FIFO, mirrors executor queue
        self._parked: List[tuple] = []       # budget-deferred (wake, _Pending)
        # Earliest armed BATCH_READY hour, or None. Single-flush
        # discipline: _schedule_flush pushes only when nothing is armed
        # or the new flush fires strictly earlier (the superseded event
        # then pops as a harmless extra drain). An unconditional push per
        # fill-triggering enqueue looks equivalent but is quadratic under
        # sustained saturation: every pop re-arms one flush, so the
        # BATCH_READY population grows by one per enqueue and each
        # 256-task drain drags the whole population of same-time events
        # along with it (~10^9 pops at 10^6 closed-loop clients).
        self._flush_at: Optional[float] = None
        self._busy_until = start_hour
        self._uid = 0
        self.events_processed = 0

    # -- planning ------------------------------------------------------------
    def _plan(self, task, now: float) -> float:
        """Wake hour for a deferrable task (== now when not deferrable or
        no forecast/cluster to plan against)."""
        if self.forecast is None or getattr(task, "deadline_hours", 0.0) <= 0:
            return now
        cluster = getattr(self.executor, "cluster", None)
        if cluster is None:
            return now
        prof = self.obs.profiler if self.obs is not None else None
        t0 = perf_counter() if prof is not None else 0.0
        if self.risk_coverage is not None:
            from repro.core.temporal import plan_wake_risk
            wake = plan_wake_risk(self.forecast, cluster, task, now,
                                  slot_hours=self.slot_hours,
                                  coverage=self.risk_coverage)
        else:
            from repro.core.temporal import plan_wake
            wake = plan_wake(self.forecast, cluster, task, now,
                             slot_hours=self.slot_hours)
        if prof is not None:
            prof.add("sim_plan", perf_counter() - t0)
        return wake

    # -- event handlers ------------------------------------------------------
    def _enqueue(self, uid: int, task, submit_hour: float,
                 deferred_hours: float, now: float,
                 client: Optional[int] = None) -> None:
        # Keep the executor's own clock on sim time: a serving Request
        # not pre-stamped by the factory would otherwise get a *wall*
        # submission stamp and mix clocks in Completion.wait_s.
        if hasattr(task, "submitted_s") and task.submitted_s is None:
            task.submitted_s = hours_to_s(submit_hour)
        self.executor.submit(task)
        self._pending.append(_Pending(uid, submit_hour, deferred_hours,
                                      getattr(task, "tenant", ""), client))
        jt = self.obs.journeys if self.obs is not None else None
        if jt is not None:
            jt.enqueue((uid,), now)
        if len(self._pending) >= self.max_batch:
            # Flush immediately, even past an already-scheduled window
            # flush — the superseded event then drains whatever is
            # pending (or nothing) and reschedules harmlessly.
            self._schedule_flush(now)
        else:
            self._schedule_flush(now + self.batch_window_hours)

    def _enqueue_batch(self, tasks: List, uids: np.ndarray,
                       times: np.ndarray,
                       client_ids: Optional[np.ndarray]) -> None:
        """Batched :meth:`_enqueue` over one same-kind event run
        (nondecreasing ``times``). Replicates the scalar loop's flush
        pushes exactly (DESIGN.md §11 windowing rule): the run's first
        task would have scheduled the window flush, its last can trigger
        at most one immediate flush — ``pop_run``'s limit guarantees the
        batch never overshoots ``max_batch`` mid-run."""
        hours = times.tolist()
        if hasattr(tasks[0], "submitted_s"):
            for task, h in zip(tasks, hours):
                if task.submitted_s is None:
                    task.submitted_s = hours_to_s(h)
        submit_many = getattr(self.executor, "submit_many", None)
        if submit_many is not None:
            submit_many(tasks)
        else:
            for task in tasks:
                self.executor.submit(task)
        tenants = [getattr(task, "tenant", "") for task in tasks]
        pend0 = len(self._pending)
        self._pending.append_arrays(uids, times, tenants, client_ids)
        jt = self.obs.journeys if self.obs is not None else None
        if jt is not None:
            jt.enqueue(uids, times)
        k = len(tasks)
        # window flush: armed while processing the run's first event
        # (pend0 + 1 < max_batch is guaranteed by pop_run's room limit);
        # intermediate enqueues would arm at later hours — no-ops under
        # the strictly-earlier rule, so only the first is replayed here
        self._schedule_flush(hours[0] + self.batch_window_hours)
        # immediate flush: the run's last event filled the batch
        if pend0 + k >= self.max_batch:
            self._schedule_flush(hours[-1])

    def _schedule_flush(self, at_hour: float) -> None:
        if self._flush_at is None or at_hour < self._flush_at - 1e-12:
            self._flush_at = at_hour
            self.heap.push(at_hour, EventKind.BATCH_READY)

    def _on_arrival(self, now: float) -> None:
        self._uid += 1
        uid = self._uid
        # one factory arity per driver: 3-arg whenever a client pool is
        # attached (open-loop arrivals are the untenanted source)
        task = (self.task_factory(uid, now) if self.clients is None
                else self.task_factory(uid, now, ""))
        jt = self.obs.journeys if self.obs is not None else None
        if jt is not None:
            jt.begin((uid,), now)
        wake = self._plan(task, now)
        if wake > now + 1e-12:
            if jt is not None:
                jt.plan_defer(uid, wake - now)
            self.heap.push(wake, EventKind.DEFER_WAKE,
                           payload=(uid, task, now, wake - now))
        else:
            self._enqueue(uid, task, now, 0.0, now)

    def _on_arrivals_batch(self, times: np.ndarray) -> None:
        """A run of ARRIVAL events with nothing to plan against
        (``_plan`` degenerates to ``now``): build and enqueue the tasks
        in one batch."""
        n = times.size
        uids = np.arange(self._uid + 1, self._uid + n + 1, dtype=np.int64)
        self._uid += n
        factory = self.task_factory
        if self.clients is None:
            tasks = [factory(u, h)
                     for u, h in zip(uids.tolist(), times.tolist())]
        else:
            tasks = [factory(u, h, "")
                     for u, h in zip(uids.tolist(), times.tolist())]
        jt = self.obs.journeys if self.obs is not None else None
        if jt is not None:
            jt.begin(uids, times)
        self._enqueue_batch(tasks, uids, times, None)

    def _on_client_ready(self, client_id: int, now: float,
                         retry: bool = False) -> None:
        """A closed-loop client issues its next request (first try or
        retry). Clients stop issuing new requests at the horizon so the
        event loop drains; in-flight work completes normally. A *retry*
        that lands past the horizon is a request that dies with the sim —
        it counts as abandoned rather than silently vanishing."""
        if now >= self.start_hour + self.horizon_hours:
            if retry:
                self.metrics.count_abandoned(
                    self.clients.tenant_of(client_id))
                self.clients.give_up(client_id)
            return
        self._uid += 1
        uid = self._uid
        tenant = self.clients.on_ready(client_id)
        task = self.task_factory(uid, now, tenant)
        jt = self.obs.journeys if self.obs is not None else None
        if jt is not None:
            jt.begin((uid,), now)
        self._enqueue(uid, task, now, 0.0, now, client=client_id)

    def _on_clients_batch(self, times: np.ndarray, ids: np.ndarray,
                          retry_mask: np.ndarray) -> None:
        """Batched :meth:`_on_client_ready` over a CLIENT_READY/RETRY
        run. ``times`` is nondecreasing, so past-horizon drops are a
        suffix: retries there count as abandoned (same bookkeeping as the
        scalar path), first tries vanish silently."""
        pool = self.clients
        live = int(np.searchsorted(times,
                                   self.start_hour + self.horizon_hours,
                                   side="left"))
        if live < times.size:
            for cid in ids[live:][retry_mask[live:]].tolist():
                self.metrics.count_abandoned(pool.tenant_of(cid))
                pool.give_up(cid)
        if live == 0:
            return
        times, ids = times[:live], ids[:live]
        uids = np.arange(self._uid + 1, self._uid + live + 1,
                         dtype=np.int64)
        self._uid += live
        tcodes = pool.on_ready_batch(ids)
        tnames = pool.tenant_names
        factory = self.task_factory
        tasks = [factory(u, h, tnames[c])
                 for u, h, c in zip(uids.tolist(), times.tolist(),
                                    tcodes.tolist())]
        jt = self.obs.journeys if self.obs is not None else None
        if jt is not None:
            jt.begin(uids, times)
        self._enqueue_batch(tasks, uids, times, ids)

    def _client_verdict(self, client_id: int, verdict: str,
                        at_hour: float, tenant: str) -> None:
        """Translate a pool verdict into the next client event + counters."""
        if verdict == "retry":
            self.metrics.count_retry(tenant)
            self.heap.push(at_hour, EventKind.RETRY, payload=client_id)
        else:
            if verdict == "abandon":
                self.metrics.count_abandoned(tenant)
            self.heap.push(at_hour, EventKind.CLIENT_READY,
                           payload=client_id)

    def _on_tenancy_wake(self, now: float) -> None:
        """A budget-deferred task's next accounting period arrived: pop
        every ripe task off the executor's parking lot and re-enqueue it,
        matching our parked pending entries by the same wake filter in
        park order (both sides are FIFO over identical wake hours)."""
        pop = getattr(self.executor, "pop_ripe", None)
        if pop is None:
            return
        ripe = pop(now)
        if not ripe:
            return
        take, rest = [], []
        for entry in self._parked:
            if entry[0] <= now and len(take) < len(ripe):
                take.append(entry)
            else:
                rest.append(entry)
        self._parked = rest
        # Tasks the ENGINE parked before this driver attached (direct
        # engine.step use, or a reused engine) have no parked record of
        # ours; they precede our own in the lot's FIFO, so the unmatched
        # head is exactly them — adopt each with a fresh uid at the wake.
        jt = self.obs.journeys if self.obs is not None else None
        extra = len(ripe) - len(take)
        adopted: List[int] = []
        for task in ripe[:extra]:
            self._uid += 1
            self.executor.submit(task)
            self._pending.append(_Pending(self._uid, now, 0.0,
                                          getattr(task, "tenant", ""),
                                          None))
            adopted.append(self._uid)
        for task, (wake, parked_at, p) in zip(ripe[extra:], take):
            self.executor.submit(task)
            p.deferred_hours += now - parked_at
            self._pending.append(p)
        if jt is not None:
            if adopted:
                jt.begin(adopted, now)
                jt.enqueue(adopted, now)
            if take:
                woke = [p.uid for _, _, p in take]
                jt.wake(woke, now)
                jt.enqueue(woke, now)
        if len(self._pending) >= self.max_batch:
            self._schedule_flush(now)
        else:
            self._schedule_flush(now + self.batch_window_hours)

    def _monitor(self):
        """The executor's CarbonMonitor: directly on a CarbonEdgeEngine,
        behind the router on a ServingEngine."""
        m = getattr(self.executor, "monitor", None)
        if m is None:
            m = getattr(getattr(self.executor, "router", None),
                        "monitor", None)
        return m

    def _record_batch(self, results: Sequence, exec_hour: float,
                      batch_energy_kwh: Optional[float] = None,
                      outcomes: Optional[Sequence] = None) -> float:
        """Emit TaskRecords for ``results`` against the pending FIFO head;
        returns the hour the executor frees up. ``batch_energy_kwh``
        (the monitor's delta across the step) backfills executors whose
        results carry no per-task energy, apportioned evenly like their
        per-batch carbon.

        ``outcomes`` (an admission-controlled executor's
        ``last_outcomes``, DESIGN.md §7) maps the drained FIFO prefix to
        per-task verdicts: completions are recorded as before, rejections
        are counted (and fed back to the closed-loop client, which
        retries or abandons), deferrals park the pending entry until the
        executor's wake event. ``None`` means every drained task
        completed in order — the pre-tenancy contract.
        """
        if outcomes is None:
            outcomes = [("done", r) for r in results]
        done, free = self._pending.take_list(len(outcomes)), exec_hour
        pool = self.clients
        obs = self.obs
        jt = obs.journeys if obs is not None else None
        roll = obs.rollups if obs is not None else None
        # per-verdict journey/rollup gathers, scattered batched after the
        # loop (the loop itself is the pre-existing scalar record path)
        j_rej: List[tuple] = []              # (uid, tenant)
        j_defer: List[int] = []
        j_retry: List[int] = []
        j_dead: List[tuple] = []             # (uid, tenant)
        j_done: List[tuple] = []             # (uid, finish, node, tenant, sub)
        t = exec_hour
        for p, (kind, val) in zip(done, outcomes):
            if kind == "reject":
                self.metrics.count_rejected(p.tenant)
                if jt is not None:
                    j_rej.append((p.uid, p.tenant))
                if pool is not None and p.client is not None:
                    verdict, at = pool.on_reject(p.client, exec_hour)
                    self._client_verdict(p.client, verdict, at, p.tenant)
                continue
            if kind == "defer" or kind == "retry":
                # a resilience retry parks on the executor exactly like a
                # budget deferral: wake at `val`, resubmit, re-plan
                if jt is not None:
                    (j_defer if kind == "defer" else j_retry).append(p.uid)
                self._parked.append((val, exec_hour, p))
                self.heap.push(val, EventKind.DEFER_WAKE, payload=None)
                continue
            if kind == "dead":
                # dead letter (DESIGN.md §10): the executor consumed the
                # task permanently; a closed-loop client sees a rejection
                self.metrics.count_dead(p.tenant)
                if jt is not None:
                    j_dead.append((p.uid, p.tenant))
                if pool is not None and p.client is not None:
                    verdict, at = pool.on_reject(p.client, exec_hour)
                    self._client_verdict(p.client, verdict, at, p.tenant)
                continue
            res = val
            if hasattr(res, "latency_ms"):        # serial cluster result
                t += ms_to_hours(res.latency_ms)
                finish = t
                free = t
            else:                                 # parallel serving batch
                finish = exec_hour + s_to_hours(getattr(res, "service_s", 0.0))
                free = max(free, finish)
            energy = getattr(res, "energy_kwh", None)
            if energy is None:
                energy = (batch_energy_kwh / len(results)
                          if batch_energy_kwh is not None else 0.0)
            rec = TaskRecord(
                uid=p.uid, submit_hour=p.submit_hour, start_hour=exec_hour,
                finish_hour=finish,
                node=getattr(res, "node", getattr(res, "pod", "")),
                carbon_g=getattr(res, "carbon_g", 0.0),
                energy_kwh=energy,
                deferred_hours=p.deferred_hours, tenant=p.tenant)
            self.metrics.add(rec)
            if jt is not None or roll is not None:
                j_done.append((p.uid, finish, rec.node, p.tenant,
                               p.submit_hour))
            if pool is not None and p.client is not None:
                verdict, at = pool.on_complete(p.client, rec.latency_s,
                                               finish)
                self._client_verdict(p.client, verdict, at, p.tenant)
        if jt is not None:
            if j_rej:
                jt.reject([u for u, _ in j_rej], exec_hour,
                          jt.intern_tenants([tn for _, tn in j_rej]))
            if j_defer:
                jt.park(j_defer, exec_hour, PARK_DEFER)
            if j_retry:
                jt.park(j_retry, exec_hour, PARK_RETRY)
            if j_dead:
                jt.dead([u for u, _ in j_dead], exec_hour,
                        jt.intern_tenants([tn for _, tn in j_dead]))
            if j_done:
                jt.done([e[0] for e in j_done], exec_hour,
                        [e[1] for e in j_done],
                        node_ids=jt.intern_names([e[2] for e in j_done]),
                        tenant_ids=jt.intern_tenants(
                            [e[3] for e in j_done]))
            fo = getattr(self.executor, "last_failover_pos", None)
            if fo:
                jt.failover([done[i].uid for i in fo])
        if roll is not None and j_done:
            base = (self.metrics.slo_latency_s
                    if self.metrics.slo_latency_s is not None
                    else float("inf"))
            fins = np.asarray([e[1] for e in j_done])
            subs = np.asarray([e[4] for e in j_done])
            thr = np.asarray([self.metrics.tenant_slo_s.get(e[3], base)
                              for e in j_done])
            roll.fold_slo(fins, (fins - subs) * 3600.0 > thr)
        return free

    def _record_batch_vec(self, results: Sequence,
                          exec_hour: float) -> float:
        """Columnar :meth:`_record_batch` for the all-completed serial
        case (DESIGN.md §11): gathers the step's per-task arrays (the
        engine's ``last_exec`` snapshot when available — the same floats
        its result objects carry — else one fromiter pass), folds finish
        hours with the scalar loop's exact left-to-right accumulation,
        records one ``add_batch``, and feeds every closed-loop client its
        verdict through one ``on_complete_batch``."""
        n = len(results)
        uids, subs, defs, tenants, clis = self._pending.take_arrays(n)
        metrics = self.metrics
        snap = getattr(self.executor, "last_exec", None)
        if snap is not None and len(snap[2]) == n:
            uniq, inverse, lat_ms, e_kwh, c_g = snap
            node_codes = metrics.intern_array(uniq)[inverse]
        else:
            lat_ms = np.fromiter((r.latency_ms for r in results), float, n)
            e_kwh = np.fromiter((r.energy_kwh for r in results), float, n)
            c_g = np.fromiter((getattr(r, "carbon_g", 0.0)
                               for r in results), float, n)
            node_codes = np.fromiter(
                (metrics.intern(getattr(r, "node", getattr(r, "pod", "")))
                 for r in results), np.int64, n)
        # serial finish hours: exactly the scalar `t += ms_to_hours(lat)`
        # fold (np.add.accumulate is sequential, so bit-identical)
        acc = np.add.accumulate(
            np.concatenate(([exec_hour], lat_ms / 3.6e6)))
        finishes = acc[1:]
        tenant_codes = np.fromiter((metrics.intern(t) for t in tenants),
                                   np.int64, n)
        metrics.add_batch(uids, subs, exec_hour, finishes, node_codes,
                          c_g, e_kwh, defs, tenant_codes)
        obs = self.obs
        jt = obs.journeys if obs is not None else None
        roll = obs.rollups if obs is not None else None
        if jt is not None:
            if snap is not None and len(snap[2]) == n:
                node_ids = jt.intern_names(uniq)[inverse]
            else:
                node_ids = jt.intern_names(
                    [getattr(r, "node", getattr(r, "pod", ""))
                     for r in results])
            jt.done(uids, exec_hour, finishes, node_ids=node_ids,
                    tenant_ids=jt.intern_tenants(tenants))
        if roll is not None:
            thr = metrics.slo_for_codes()
            roll.fold_slo(finishes,
                          (finishes - subs) * 3600.0 > thr[tenant_codes])
        pool = self.clients
        if pool is not None:
            pos = np.flatnonzero(clis >= 0)
            if pos.size:
                ids = clis[pos]
                fin = finishes[pos]
                lat_s = (fin - subs[pos]) * 3600.0
                retry, abandon, next_h = pool.on_complete_batch(
                    ids, lat_s, fin)
                for j in np.flatnonzero(retry).tolist():
                    metrics.count_retry(tenants[pos[j]])
                for j in np.flatnonzero(abandon).tolist():
                    metrics.count_abandoned(tenants[pos[j]])
                kinds = np.where(retry, _RT, _CR)
                self.heap.push_batch(next_h, kinds, ids)
        return float(acc[-1])

    def _on_batch_ready(self, now: float) -> None:
        if self._flush_at is not None and now >= self._flush_at - 1e-12:
            self._flush_at = None           # the armed flush fired (or we
        # popped a same-time superseded one — the armed event then drains
        # nothing and falls through the re-arm below, which is harmless)
        if not self._pending:
            return
        if now < self._busy_until - 1e-12:        # executor still serving
            self._schedule_flush(self._busy_until)
            return
        n = min(len(self._pending), self.max_batch)
        monitor = self._monitor()
        e0 = monitor.total_energy_kwh() if monitor is not None else None
        prof = self.obs.profiler if self.obs is not None else None
        t0 = perf_counter() if prof is not None else 0.0
        results = self.executor.step(now_hour=now, limit=n)
        if prof is not None:
            prof.add("sim_step", perf_counter() - t0)
        e_batch = (monitor.total_energy_kwh() - e0
                   if monitor is not None else None)
        outcomes = getattr(self.executor, "last_outcomes", None)
        t0 = perf_counter() if prof is not None else 0.0
        if (self._vectorized and outcomes is None and results
                and hasattr(results[0], "latency_ms")
                and getattr(results[0], "energy_kwh", None) is not None):
            self._busy_until = self._record_batch_vec(results, now)
        else:
            self._busy_until = self._record_batch(results, now, e_batch,
                                                  outcomes)
        if prof is not None:
            prof.add("sim_record", perf_counter() - t0)
        if len(self._pending) >= self.max_batch:
            # saturated: drain back-to-back the moment the executor frees
            # up instead of idling a whole window on a full batch
            self._schedule_flush(max(self._busy_until, now))
        elif self._pending:
            self._schedule_flush(max(self._busy_until,
                                     now + self.batch_window_hours))

    def _on_tick(self, now: float) -> None:
        cluster = getattr(self.executor, "cluster", None)
        provider = getattr(self.executor, "provider", None)
        mean_int = 0.0
        if cluster is not None and provider is not None:
            from repro.core.api import intensity_batch

            names = list(cluster.nodes)
            try:
                # fleet-scale: one batched provider read per tick, not N
                # Python calls (DESIGN.md §3.2); the mean stays ndarray math
                arr = np.asarray(intensity_batch(provider, names, now),
                                 dtype=float)
                if arr.size:
                    mean_int = float(arr.sum() / arr.size)
            except KeyError:
                # partial-coverage provider: sample per node, skip holes
                vals = []
                for name in names:
                    try:
                        vals.append(provider.intensity(name, now))
                    except KeyError:
                        pass
                if vals:
                    mean_int = float(sum(vals) / len(vals))
        monitor = self._monitor()
        carbon = monitor.total_carbon_g() if monitor is not None else \
            self.metrics.carbon_g_total()
        self.metrics.add_sample(TimelineSample(
            hour=now, completed=self.metrics.n_records,
            carbon_g_cum=float(carbon), mean_intensity=mean_int))

    # -- main loop -----------------------------------------------------------
    def _dispatch(self, ev, now: float) -> None:
        """Scalar dispatch of one popped event (both queue modes)."""
        if ev.kind is EventKind.ARRIVAL:
            self._on_arrival(now)
        elif (ev.kind is EventKind.CLIENT_READY
              or ev.kind is EventKind.RETRY):
            self._on_client_ready(ev.payload, now,
                                  retry=ev.kind is EventKind.RETRY)
        elif ev.kind is EventKind.DEFER_WAKE:
            if ev.payload is None:            # budget-deferred wake
                self._on_tenancy_wake(now)
            else:                             # forecast-planned wake
                uid, task, submit_hour, deferred = ev.payload
                self._enqueue(uid, task, submit_hour, deferred, now)
        elif ev.kind is EventKind.BATCH_READY:
            self._on_batch_ready(now)
        elif ev.kind is EventKind.INTENSITY_TICK:
            self._on_tick(now)
        elif (ev.kind is EventKind.NODE_DOWN
              or ev.kind is EventKind.NODE_UP
              or ev.kind is EventKind.PROVIDER_OUTAGE):
            self.faults.apply(ev.payload, self.executor)
            roll = self.obs.rollups if self.obs is not None else None
            if roll is not None:
                res = getattr(self.executor, "resilience", None)
                cluster = getattr(self.executor, "cluster", None)
                if res is not None and cluster is not None and cluster.nodes:
                    roll.note_availability(
                        now, res.availability(len(cluster.nodes)))

    def _run_loop_calendar(self, ev_counts: Optional[Dict[str, int]]) -> None:
        """The O(batches) event loop (DESIGN.md §11): a same-kind run of
        CLIENT_READY/RETRY (or plan-free ARRIVAL) events pops as one
        numpy slice, bounded by the windowing rule — up to the batch-size
        room so at most the run's last event triggers an immediate flush,
        and (when no flush is scheduled yet) up to the window the run's
        first event would have opened. Everything else dispatches
        scalar, so fault/defer/tick semantics are untouched."""
        q = self.heap
        clock = self.clock
        pool = self.clients
        arrivals_plain = (self.forecast is None
                          or getattr(self.executor, "cluster", None) is None)
        while True:
            key = q.peek_key()
            if key is None:
                break
            t0k, code = key
            batchable = ((code == _CR or code == _RT)
                         if pool is not None else False)
            if not batchable and code == _AR and arrivals_plain:
                batchable = True
            room = self.max_batch - len(self._pending)
            if room <= 1:
                # saturated: a one-element array run costs more than the
                # scalar path, which processes the same single event with
                # identical semantics (no RNG is drawn before the flush)
                batchable = False
            if batchable:
                limit = room
                # an already-armed flush is a physical BATCH_READY event
                # in the queue, so the same-kind run stops at it for
                # free; the cap covers the one flush the run's FIRST
                # enqueue may arm (strictly-earlier rule) that the queue
                # cannot know about yet
                max_t = t0k + self.batch_window_hours
                codes = (_CR, _RT) if code != _AR else (_AR,)
                times, payloads, kinds = q.pop_run(codes, limit, max_t)
                clock.advance_run(times)
                self.events_processed += times.size
                if ev_counts is not None:
                    nr = int(np.count_nonzero(kinds == _RT))
                    nc = times.size - nr
                    name = ("ARRIVAL" if code == _AR
                            else EventKind.CLIENT_READY.name)
                    if nc:
                        ev_counts[name] = ev_counts.get(name, 0) + nc
                    if nr:
                        ev_counts["RETRY"] = ev_counts.get("RETRY", 0) + nr
                if code == _AR:
                    self._on_arrivals_batch(times)
                else:
                    self._on_clients_batch(times, payloads, kinds == _RT)
            else:
                ev = q.pop()
                now = clock.advance_to(ev.time_hours)
                self.events_processed += 1
                if ev_counts is not None:
                    k = ev.kind.name
                    ev_counts[k] = ev_counts.get(k, 0) + 1
                self._dispatch(ev, now)

    def run(self) -> MetricsCollector:
        if self.faults is not None:
            # pushed before arrivals so a fault and an arrival at the same
            # instant resolve fault-first (heap ties break by push order)
            for f in self.faults.schedule:
                self.heap.push(float(f.hour), f.event_kind, payload=f)
        if self.arrivals is not None:
            ts = self.arrivals.times(self.start_hour, self.horizon_hours)
            if self._vectorized:
                self.heap.push_batch(np.asarray(ts, dtype=float),
                                     EventKind.ARRIVAL)
            else:
                for t in ts:
                    self.heap.push(float(t), EventKind.ARRIVAL)
        if self.clients is not None:
            if self._vectorized:
                ats, cids = self.clients.initial_events_arrays(
                    self.start_hour)
                self.heap.push_batch(ats, EventKind.CLIENT_READY, cids)
            else:
                for at, cid in self.clients.initial_events(self.start_hour):
                    self.heap.push(at, EventKind.CLIENT_READY, payload=cid)
            # advertise per-tenant SLO classes to the metrics layer
            for pop in self.clients.populations:
                if pop.slo_latency_s != float("inf"):
                    self.metrics.tenant_slo_s[pop.tenant] = pop.slo_latency_s
        # the executor's tenant registry (if any) supplies spec-level SLO
        # classes: latency targets (client populations take precedence)
        # and miss tolerances
        reg = getattr(getattr(self.executor, "policy", None),
                      "registry", None)
        if reg is not None and hasattr(reg, "miss_tolerance"):
            for name, i in reg.index.items():
                if reg.slo_latency_s[i] != float("inf"):
                    self.metrics.tenant_slo_s.setdefault(
                        name, float(reg.slo_latency_s[i]))
                if reg.miss_tolerance[i] > 0:
                    self.metrics.tenant_miss_tolerance[name] = float(
                        reg.miss_tolerance[i])
        if self.tick_hours > 0:
            n_ticks = int(self.horizon_hours / self.tick_hours)
            for k in range(1, n_ticks + 1):
                self.heap.push(self.start_hour + k * self.tick_hours,
                               EventKind.INTENSITY_TICK)
        # Per-EventKind counters (obs metrics only): a plain dict on the
        # loop, folded into one `sim_events_total` family after the drain
        # so the hot loop never touches the registry.
        ev_counts: Optional[Dict[str, int]] = (
            {} if self.obs is not None and self.obs.metrics is not None
            else None)
        if self._vectorized:
            self._run_loop_calendar(ev_counts)
        else:
            while self.heap:
                ev = self.heap.pop()
                now = self.clock.advance_to(ev.time_hours)
                self.events_processed += 1
                if ev_counts is not None:
                    k = ev.kind.name
                    ev_counts[k] = ev_counts.get(k, 0) + 1
                self._dispatch(ev, now)
        assert not self._pending, "event loop ended with tasks still queued"
        if ev_counts is not None:
            fam = self.obs.metrics.counter(
                "sim_events_total", "Events processed by the sim loop",
                labels=("kind",))
            for k in sorted(ev_counts):
                fam.inc(ev_counts[k], (k,))
            self.metrics.export_obs(self.obs.metrics)
        # Alert evaluation (DESIGN.md §12): one vectorized pass over the
        # run's complete rollup windows. With no rules configured, default
        # fleet rules plus the tenant policy's per-tenant carbon-pace
        # rules (when the executor carries one) are installed first.
        obs = self.obs
        if (obs is not None and obs.alerts is not None
                and obs.rollups is not None):
            alerts = obs.alerts
            if not alerts.rules:
                from repro.obs.alerts import default_rules
                rules = default_rules()
                mk = getattr(getattr(self.executor, "policy", None),
                             "alert_rules", None)
                if mk is not None:
                    rules += mk(obs.rollups.window_hours)
                alerts.add_rules(rules)
            alerts.evaluate(obs.rollups)
            if obs.metrics is not None:
                alerts.export(obs.metrics)
        return self.metrics
