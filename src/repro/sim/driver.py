"""Async engine driver: the discrete-event serving loop (DESIGN.md §2).

:class:`AsyncEngineDriver` interleaves an arrival process with batched
executor steps through simulated time:

- ``ARRIVAL`` events materialise tasks (via ``task_factory``) and enqueue
  them on the executor; deferrable tasks (``deadline_hours > 0``) are
  instead *planned* through :func:`repro.core.temporal.plan_wake` against
  the driver's forecast provider and parked until their ``DEFER_WAKE``
  (fleet-scale: the planner reads the whole (slots x nodes) grid in one
  batched provider call — DESIGN.md §3.6);
- ``BATCH_READY`` events drain up to ``max_batch`` pending tasks in one
  ``executor.step(now_hour=clock.hour, limit=...)`` call — with the
  default :class:`~repro.core.api.CarbonEdgeEngine` that is one (B, N, 8)
  featurize + one vectorized/Pallas scorer invocation per event batch,
  not one per task, and since DESIGN.md §6 the execute+billing half is
  batched too (one ``cluster.execute_batch`` + one
  ``monitor.record_energy_batch`` per drained batch, bit-identical to the
  per-task loop, so ``metrics.to_text`` is byte-stable across both
  execution paths) — honouring the executor's busy time so queueing
  delay emerges from load rather than being assumed;
- ``INTENSITY_TICK`` events sample the carbon-vs-latency timeline.

``now_hour`` is always the virtual clock, so every provider read (policy
scoring, cluster billing, monitor billing) tracks simulated time — the
property :meth:`CarbonEdgeEngine.run` cannot offer (it freezes the hour
for the whole drain).

Executors: anything with ``submit(task)`` and
``step(now_hour, limit) -> results`` — ``CarbonEdgeEngine`` natively, and
``runtime.serving.ServingEngine`` through its ``step`` alias. Results
expose either ``latency_ms`` (serial cluster: service times accumulate)
or ``service_s`` (parallel serving batch: the batch occupies the executor
for its max service time). Note the determinism contract (DESIGN.md
§2.2) covers modelled executors only: a ServingEngine measures real
wall-clock service, so its runs repeat only up to host timing noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from time import perf_counter
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    runtime_checkable)

from repro.sim.arrivals import ArrivalProcess, ClosedLoopClientPool
from repro.sim.clock import VirtualClock, hours_to_s, ms_to_hours, s_to_hours
from repro.sim.events import EventHeap, EventKind
from repro.sim.metrics import MetricsCollector, TaskRecord, TimelineSample


@runtime_checkable
class BatchExecutor(Protocol):
    """What the driver needs from an engine."""

    def submit(self, task) -> object: ...

    def step(self, now_hour: float = 0.0,
             limit: Optional[int] = None) -> Sequence: ...


@dataclass
class _Pending:
    uid: int
    submit_hour: float
    deferred_hours: float = 0.0
    tenant: str = ""
    client: Optional[int] = None     # closed-loop client id, if any


class AsyncEngineDriver:
    """Drive a batch executor through simulated time under an arrival
    process, producing queueing/SLO/carbon metrics.

    ``task_factory(uid, hour)`` builds the submitted object (a ``Task``,
    ``DeferrableTask`` or serving ``Request``). When ``forecast`` is given
    (any provider; a :class:`~repro.core.api.ForecastProvider` uses its
    ``window``), tasks with ``deadline_hours > 0`` are deferred to the
    minimum-forecast-intensity slot within their deadline.
    """

    def __init__(self, executor: BatchExecutor,
                 arrivals: Optional[ArrivalProcess],
                 task_factory: Callable[..., object], *,
                 start_hour: float = 0.0, horizon_hours: float = 1.0,
                 max_batch: int = 8, batch_window_hours: float = 0.0,
                 forecast=None, slot_hours: float = 0.5,
                 slo_latency_s: Optional[float] = None,
                 tick_hours: float = 0.0,
                 clients: Optional[ClosedLoopClientPool] = None,
                 risk_coverage: Optional[float] = None,
                 obs=None, faults=None):
        if arrivals is None and clients is None:
            raise ValueError("need an arrival process, a closed-loop "
                             "client pool, or both")
        self.executor = executor
        self.arrivals = arrivals
        self.task_factory = task_factory
        self.start_hour = start_hour
        self.horizon_hours = horizon_hours
        self.max_batch = max_batch
        self.batch_window_hours = batch_window_hours
        self.forecast = forecast
        self.slot_hours = slot_hours
        # Risk-bounded deferral planning (DESIGN.md §8): with a coverage
        # level set, deferrable arrivals are planned through
        # plan_wake_risk — a task parks only when the forecast's conformal
        # interval says the future slot beats executing now even at the
        # interval's pessimistic end. None keeps point-forecast planning.
        if risk_coverage is not None and not 0.0 < risk_coverage < 1.0:
            raise ValueError("risk_coverage must be in (0, 1) or None")
        self.risk_coverage = risk_coverage
        self.tick_hours = tick_hours
        # Closed-loop mode (DESIGN.md §7): `clients` drives CLIENT_READY /
        # RETRY events and the task_factory is called as
        # factory(uid, hour, tenant) — for EVERY task source, so mixing an
        # open-loop arrival process with client populations keeps one
        # factory signature (ARRIVAL events pass tenant=""). New requests
        # stop at the horizon; in-flight ones drain.
        self.clients = clients
        # Observability (DESIGN.md §9): spans around the step/record/plan
        # phases of each event batch plus per-EventKind counters. Off
        # (None / disabled) leaves the event loop byte-identical — every
        # hook sits behind a single `is not None` check. Pass the same
        # Observability to the engine and the driver to get one unified
        # profiler/registry across both layers.
        self.obs = obs if obs is not None and obs.enabled else None
        # Fault injection (DESIGN.md §10): a repro.resilience.FaultInjector
        # whose schedule is surfaced as NODE_DOWN/NODE_UP/PROVIDER_OUTAGE
        # events and applied to the executor when each fires. None (the
        # default) leaves the event loop byte-identical.
        self.faults = faults
        self.clock = VirtualClock(start_hour)
        self.heap = EventHeap()
        self.metrics = MetricsCollector(slo_latency_s=slo_latency_s)
        self._pending: List[_Pending] = []   # FIFO, mirrors executor queue
        self._parked: List[tuple] = []       # budget-deferred (wake, _Pending)
        self._flush_scheduled = False
        self._busy_until = start_hour
        self._uid = 0

    # -- planning ------------------------------------------------------------
    def _plan(self, task, now: float) -> float:
        """Wake hour for a deferrable task (== now when not deferrable or
        no forecast/cluster to plan against)."""
        if self.forecast is None or getattr(task, "deadline_hours", 0.0) <= 0:
            return now
        cluster = getattr(self.executor, "cluster", None)
        if cluster is None:
            return now
        prof = self.obs.profiler if self.obs is not None else None
        t0 = perf_counter() if prof is not None else 0.0
        if self.risk_coverage is not None:
            from repro.core.temporal import plan_wake_risk
            wake = plan_wake_risk(self.forecast, cluster, task, now,
                                  slot_hours=self.slot_hours,
                                  coverage=self.risk_coverage)
        else:
            from repro.core.temporal import plan_wake
            wake = plan_wake(self.forecast, cluster, task, now,
                             slot_hours=self.slot_hours)
        if prof is not None:
            prof.add("sim_plan", perf_counter() - t0)
        return wake

    # -- event handlers ------------------------------------------------------
    def _enqueue(self, uid: int, task, submit_hour: float,
                 deferred_hours: float, now: float,
                 client: Optional[int] = None) -> None:
        # Keep the executor's own clock on sim time: a serving Request
        # not pre-stamped by the factory would otherwise get a *wall*
        # submission stamp and mix clocks in Completion.wait_s.
        if hasattr(task, "submitted_s") and task.submitted_s is None:
            task.submitted_s = hours_to_s(submit_hour)
        self.executor.submit(task)
        self._pending.append(_Pending(uid, submit_hour, deferred_hours,
                                      getattr(task, "tenant", ""), client))
        if len(self._pending) >= self.max_batch:
            # Flush immediately, even past an already-scheduled window
            # flush — the later event then drains whatever is pending (or
            # nothing) and reschedules harmlessly.
            self.heap.push(now, EventKind.BATCH_READY)
            self._flush_scheduled = True
        else:
            self._schedule_flush(now + self.batch_window_hours)

    def _schedule_flush(self, at_hour: float) -> None:
        if not self._flush_scheduled:
            self._flush_scheduled = True
            self.heap.push(at_hour, EventKind.BATCH_READY)

    def _on_arrival(self, now: float) -> None:
        self._uid += 1
        uid = self._uid
        # one factory arity per driver: 3-arg whenever a client pool is
        # attached (open-loop arrivals are the untenanted source)
        task = (self.task_factory(uid, now) if self.clients is None
                else self.task_factory(uid, now, ""))
        wake = self._plan(task, now)
        if wake > now + 1e-12:
            self.heap.push(wake, EventKind.DEFER_WAKE,
                           payload=(uid, task, now, wake - now))
        else:
            self._enqueue(uid, task, now, 0.0, now)

    def _on_client_ready(self, client_id: int, now: float,
                         retry: bool = False) -> None:
        """A closed-loop client issues its next request (first try or
        retry). Clients stop issuing new requests at the horizon so the
        event loop drains; in-flight work completes normally. A *retry*
        that lands past the horizon is a request that dies with the sim —
        it counts as abandoned rather than silently vanishing."""
        if now >= self.start_hour + self.horizon_hours:
            if retry:
                self.metrics.count_abandoned(
                    self.clients.tenant_of(client_id))
                self.clients.give_up(client_id)
            return
        self._uid += 1
        uid = self._uid
        tenant = self.clients.on_ready(client_id)
        task = self.task_factory(uid, now, tenant)
        self._enqueue(uid, task, now, 0.0, now, client=client_id)

    def _client_verdict(self, client_id: int, verdict: str,
                        at_hour: float, tenant: str) -> None:
        """Translate a pool verdict into the next client event + counters."""
        if verdict == "retry":
            self.metrics.count_retry(tenant)
            self.heap.push(at_hour, EventKind.RETRY, payload=client_id)
        else:
            if verdict == "abandon":
                self.metrics.count_abandoned(tenant)
            self.heap.push(at_hour, EventKind.CLIENT_READY,
                           payload=client_id)

    def _on_tenancy_wake(self, now: float) -> None:
        """A budget-deferred task's next accounting period arrived: pop
        every ripe task off the executor's parking lot and re-enqueue it,
        matching our parked pending entries by the same wake filter in
        park order (both sides are FIFO over identical wake hours)."""
        pop = getattr(self.executor, "pop_ripe", None)
        if pop is None:
            return
        ripe = pop(now)
        if not ripe:
            return
        take, rest = [], []
        for entry in self._parked:
            if entry[0] <= now and len(take) < len(ripe):
                take.append(entry)
            else:
                rest.append(entry)
        self._parked = rest
        # Tasks the ENGINE parked before this driver attached (direct
        # engine.step use, or a reused engine) have no parked record of
        # ours; they precede our own in the lot's FIFO, so the unmatched
        # head is exactly them — adopt each with a fresh uid at the wake.
        extra = len(ripe) - len(take)
        for task in ripe[:extra]:
            self._uid += 1
            self.executor.submit(task)
            self._pending.append(_Pending(self._uid, now, 0.0,
                                          getattr(task, "tenant", ""),
                                          None))
        for task, (wake, parked_at, p) in zip(ripe[extra:], take):
            self.executor.submit(task)
            p.deferred_hours += now - parked_at
            self._pending.append(p)
        if len(self._pending) >= self.max_batch:
            self.heap.push(now, EventKind.BATCH_READY)
            self._flush_scheduled = True
        else:
            self._schedule_flush(now + self.batch_window_hours)

    def _monitor(self):
        """The executor's CarbonMonitor: directly on a CarbonEdgeEngine,
        behind the router on a ServingEngine."""
        m = getattr(self.executor, "monitor", None)
        if m is None:
            m = getattr(getattr(self.executor, "router", None),
                        "monitor", None)
        return m

    def _record_batch(self, results: Sequence, exec_hour: float,
                      batch_energy_kwh: Optional[float] = None,
                      outcomes: Optional[Sequence] = None) -> float:
        """Emit TaskRecords for ``results`` against the pending FIFO head;
        returns the hour the executor frees up. ``batch_energy_kwh``
        (the monitor's delta across the step) backfills executors whose
        results carry no per-task energy, apportioned evenly like their
        per-batch carbon.

        ``outcomes`` (an admission-controlled executor's
        ``last_outcomes``, DESIGN.md §7) maps the drained FIFO prefix to
        per-task verdicts: completions are recorded as before, rejections
        are counted (and fed back to the closed-loop client, which
        retries or abandons), deferrals park the pending entry until the
        executor's wake event. ``None`` means every drained task
        completed in order — the pre-tenancy contract.
        """
        if outcomes is None:
            outcomes = [("done", r) for r in results]
        done, free = self._pending[:len(outcomes)], exec_hour
        self._pending = self._pending[len(outcomes):]
        pool = self.clients
        t = exec_hour
        for p, (kind, val) in zip(done, outcomes):
            if kind == "reject":
                self.metrics.count_rejected(p.tenant)
                if pool is not None and p.client is not None:
                    verdict, at = pool.on_reject(p.client, exec_hour)
                    self._client_verdict(p.client, verdict, at, p.tenant)
                continue
            if kind == "defer" or kind == "retry":
                # a resilience retry parks on the executor exactly like a
                # budget deferral: wake at `val`, resubmit, re-plan
                self._parked.append((val, exec_hour, p))
                self.heap.push(val, EventKind.DEFER_WAKE, payload=None)
                continue
            if kind == "dead":
                # dead letter (DESIGN.md §10): the executor consumed the
                # task permanently; a closed-loop client sees a rejection
                self.metrics.count_dead(p.tenant)
                if pool is not None and p.client is not None:
                    verdict, at = pool.on_reject(p.client, exec_hour)
                    self._client_verdict(p.client, verdict, at, p.tenant)
                continue
            res = val
            if hasattr(res, "latency_ms"):        # serial cluster result
                t += ms_to_hours(res.latency_ms)
                finish = t
                free = t
            else:                                 # parallel serving batch
                finish = exec_hour + s_to_hours(getattr(res, "service_s", 0.0))
                free = max(free, finish)
            energy = getattr(res, "energy_kwh", None)
            if energy is None:
                energy = (batch_energy_kwh / len(results)
                          if batch_energy_kwh is not None else 0.0)
            rec = TaskRecord(
                uid=p.uid, submit_hour=p.submit_hour, start_hour=exec_hour,
                finish_hour=finish,
                node=getattr(res, "node", getattr(res, "pod", "")),
                carbon_g=getattr(res, "carbon_g", 0.0),
                energy_kwh=energy,
                deferred_hours=p.deferred_hours, tenant=p.tenant)
            self.metrics.add(rec)
            if pool is not None and p.client is not None:
                verdict, at = pool.on_complete(p.client, rec.latency_s,
                                               finish)
                self._client_verdict(p.client, verdict, at, p.tenant)
        return free

    def _on_batch_ready(self, now: float) -> None:
        self._flush_scheduled = False
        if not self._pending:
            return
        if now < self._busy_until - 1e-12:        # executor still serving
            self._schedule_flush(self._busy_until)
            return
        n = min(len(self._pending), self.max_batch)
        monitor = self._monitor()
        e0 = monitor.total_energy_kwh() if monitor is not None else None
        prof = self.obs.profiler if self.obs is not None else None
        t0 = perf_counter() if prof is not None else 0.0
        results = self.executor.step(now_hour=now, limit=n)
        if prof is not None:
            prof.add("sim_step", perf_counter() - t0)
        e_batch = (monitor.total_energy_kwh() - e0
                   if monitor is not None else None)
        outcomes = getattr(self.executor, "last_outcomes", None)
        t0 = perf_counter() if prof is not None else 0.0
        self._busy_until = self._record_batch(results, now, e_batch, outcomes)
        if prof is not None:
            prof.add("sim_record", perf_counter() - t0)
        if self._pending:
            self._schedule_flush(max(self._busy_until,
                                     now + self.batch_window_hours))

    def _on_tick(self, now: float) -> None:
        cluster = getattr(self.executor, "cluster", None)
        provider = getattr(self.executor, "provider", None)
        mean_int = 0.0
        if cluster is not None and provider is not None:
            import numpy as np

            from repro.core.api import intensity_batch

            names = list(cluster.nodes)
            try:
                # fleet-scale: one batched provider read per tick, not N
                # Python calls (DESIGN.md §3.2); the mean stays ndarray math
                arr = np.asarray(intensity_batch(provider, names, now),
                                 dtype=float)
                if arr.size:
                    mean_int = float(arr.sum() / arr.size)
            except KeyError:
                # partial-coverage provider: sample per node, skip holes
                vals = []
                for name in names:
                    try:
                        vals.append(provider.intensity(name, now))
                    except KeyError:
                        pass
                if vals:
                    mean_int = float(sum(vals) / len(vals))
        monitor = self._monitor()
        carbon = monitor.total_carbon_g() if monitor is not None else \
            sum(r.carbon_g for r in self.metrics.records)
        self.metrics.add_sample(TimelineSample(
            hour=now, completed=len(self.metrics.records),
            carbon_g_cum=float(carbon), mean_intensity=mean_int))

    # -- main loop -----------------------------------------------------------
    def run(self) -> MetricsCollector:
        if self.faults is not None:
            # pushed before arrivals so a fault and an arrival at the same
            # instant resolve fault-first (heap ties break by push order)
            for f in self.faults.schedule:
                self.heap.push(float(f.hour), f.event_kind, payload=f)
        if self.arrivals is not None:
            for t in self.arrivals.times(self.start_hour, self.horizon_hours):
                self.heap.push(float(t), EventKind.ARRIVAL)
        if self.clients is not None:
            for at, cid in self.clients.initial_events(self.start_hour):
                self.heap.push(at, EventKind.CLIENT_READY, payload=cid)
            # advertise per-tenant SLO classes to the metrics layer
            for pop in self.clients.populations:
                if pop.slo_latency_s != float("inf"):
                    self.metrics.tenant_slo_s[pop.tenant] = pop.slo_latency_s
        # the executor's tenant registry (if any) supplies spec-level SLO
        # classes: latency targets (client populations take precedence)
        # and miss tolerances
        reg = getattr(getattr(self.executor, "policy", None),
                      "registry", None)
        if reg is not None and hasattr(reg, "miss_tolerance"):
            for name, i in reg.index.items():
                if reg.slo_latency_s[i] != float("inf"):
                    self.metrics.tenant_slo_s.setdefault(
                        name, float(reg.slo_latency_s[i]))
                if reg.miss_tolerance[i] > 0:
                    self.metrics.tenant_miss_tolerance[name] = float(
                        reg.miss_tolerance[i])
        if self.tick_hours > 0:
            n_ticks = int(self.horizon_hours / self.tick_hours)
            for k in range(1, n_ticks + 1):
                self.heap.push(self.start_hour + k * self.tick_hours,
                               EventKind.INTENSITY_TICK)
        # Per-EventKind counters (obs metrics only): a plain dict on the
        # loop, folded into one `sim_events_total` family after the drain
        # so the hot loop never touches the registry.
        ev_counts: Optional[Dict[str, int]] = (
            {} if self.obs is not None and self.obs.metrics is not None
            else None)
        while self.heap:
            ev = self.heap.pop()
            now = self.clock.advance_to(ev.time_hours)
            if ev_counts is not None:
                k = ev.kind.name
                ev_counts[k] = ev_counts.get(k, 0) + 1
            if ev.kind is EventKind.ARRIVAL:
                self._on_arrival(now)
            elif (ev.kind is EventKind.CLIENT_READY
                  or ev.kind is EventKind.RETRY):
                self._on_client_ready(ev.payload, now,
                                      retry=ev.kind is EventKind.RETRY)
            elif ev.kind is EventKind.DEFER_WAKE:
                if ev.payload is None:            # budget-deferred wake
                    self._on_tenancy_wake(now)
                else:                             # forecast-planned wake
                    uid, task, submit_hour, deferred = ev.payload
                    self._enqueue(uid, task, submit_hour, deferred, now)
            elif ev.kind is EventKind.BATCH_READY:
                self._on_batch_ready(now)
            elif ev.kind is EventKind.INTENSITY_TICK:
                self._on_tick(now)
            elif (ev.kind is EventKind.NODE_DOWN
                  or ev.kind is EventKind.NODE_UP
                  or ev.kind is EventKind.PROVIDER_OUTAGE):
                self.faults.apply(ev.payload, self.executor)
        assert not self._pending, "event loop ended with tasks still queued"
        if ev_counts is not None:
            fam = self.obs.metrics.counter(
                "sim_events_total", "Events processed by the sim loop",
                labels=("kind",))
            for k in sorted(ev_counts):
                fam.inc(ev_counts[k], (k,))
            self.metrics.export_obs(self.obs.metrics)
        return self.metrics
