"""Virtual clock for the discrete-event simulator (DESIGN.md §2).

Simulated time is measured in *hours* — the same unit every
``CarbonIntensityProvider`` and ``now_hour`` argument in the engine stack
already speaks — so the clock value flows unconverted into scheduling,
billing and deferral planning. Task service times arrive in milliseconds
from the cluster; :func:`ms_to_hours` is the single conversion point.
"""
from __future__ import annotations

import numpy as np

MS_PER_HOUR = 3.6e6


def ms_to_hours(ms: float) -> float:
    return ms / MS_PER_HOUR


def hours_to_s(hours: float) -> float:
    return hours * 3600.0


def s_to_hours(s: float) -> float:
    return s / 3600.0


class VirtualClock:
    """Monotonic simulated clock. Only the event loop advances it."""

    def __init__(self, start_hour: float = 0.0):
        self._now = float(start_hour)

    @property
    def hour(self) -> float:
        return self._now

    def advance_to(self, hour: float) -> float:
        """Move to ``hour``; rejects travel into the past — an event popped
        out of order means the heap invariant broke, fail loudly."""
        if hour < self._now - 1e-12:
            raise ValueError(
                f"clock cannot run backwards: at {self._now}, asked for {hour}")
        self._now = max(self._now, float(hour))
        return self._now

    def advance_run(self, hours) -> float:
        """Advance through a whole event run (a nondecreasing hour array
        from ``EventCalendar.pop_run``) in one call, applying the same
        no-backward-travel check to every element — the vectorized
        equivalent of one ``advance_to`` per event. Returns the final
        hour."""
        h = np.asarray(hours, dtype=float)
        if h.size == 0:
            return self._now
        if float(h[0]) < self._now - 1e-12 or \
                (h.size > 1 and bool((np.diff(h) < 0).any())):
            raise ValueError(
                f"clock cannot run backwards: at {self._now}, asked for a "
                "non-monotone event run — the calendar ordering invariant "
                "broke")
        self._now = max(self._now, float(h[-1]))
        return self._now
