"""Virtual clock for the discrete-event simulator (DESIGN.md §2).

Simulated time is measured in *hours* — the same unit every
``CarbonIntensityProvider`` and ``now_hour`` argument in the engine stack
already speaks — so the clock value flows unconverted into scheduling,
billing and deferral planning. Task service times arrive in milliseconds
from the cluster; :func:`ms_to_hours` is the single conversion point.
"""
from __future__ import annotations

MS_PER_HOUR = 3.6e6


def ms_to_hours(ms: float) -> float:
    return ms / MS_PER_HOUR


def hours_to_s(hours: float) -> float:
    return hours * 3600.0


def s_to_hours(s: float) -> float:
    return s / 3600.0


class VirtualClock:
    """Monotonic simulated clock. Only the event loop advances it."""

    def __init__(self, start_hour: float = 0.0):
        self._now = float(start_hour)

    @property
    def hour(self) -> float:
        return self._now

    def advance_to(self, hour: float) -> float:
        """Move to ``hour``; rejects travel into the past — an event popped
        out of order means the heap invariant broke, fail loudly."""
        if hour < self._now - 1e-12:
            raise ValueError(
                f"clock cannot run backwards: at {self._now}, asked for {hour}")
        self._now = max(self._now, float(hour))
        return self._now
