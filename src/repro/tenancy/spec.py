"""Tenant model for multi-tenant serving (DESIGN.md §7).

The paper evaluates one anonymous workload; real edge deployments serve
many tenants whose SLO classes, carbon allowances and mode preferences
differ (Ecomap's multi-tenant DNN execution, arXiv 2503.04148). This
module holds the *data* half of the tenancy subsystem:

- :class:`TenantSpec` — immutable per-tenant contract: SLO class (latency
  target + miss tolerance), a periodic carbon allowance, a preferred
  operating mode (the escalation *floor*), a priority and whether
  over-budget work is deferred to the next period or rejected outright;
- :class:`TenantTask` — a :class:`~repro.core.scheduler.Task` tagged with
  its tenant (the engine, policies and sim all resolve tenancy through
  ``getattr(task, "tenant", ...)``, so plain Tasks keep working);
- :class:`TenantRegistry` — the shared mutable state: per-tenant
  **column arrays** (allowance, current-period spend, counters), so the
  batched scheduling fast path (PR 3/4) stays O(distinct tenants), not
  O(B), per step. The engine and the sim driver share one registry.

Accounting periods are anchored at hour 0: tenant ``i`` is in period
``floor(now_hour / period_hours[i])``. :meth:`TenantRegistry.roll` resets
``spent_g`` when a tenant crosses into a new period, so escalation
thresholds are always evaluated against the *current* period's spend only
(lifetime totals live in ``total_carbon_g``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence

import numpy as np

from repro.core.energy import ledger_add
from repro.core.scheduler import Task

# Escalation ladder: budget pressure only ever pushes a tenant *toward*
# green (a tenant's preferred mode is the floor, never the ceiling).
MODE_ORDER = ("performance", "balanced", "green")

# Budget-pressure escalation boundaries (fraction of the current period's
# allowance spent): < 0.5 -> performance, < 0.8 -> balanced, else green.
# Same ladder the deprecated BudgetedRouter used (core/budget.py).
ESCALATION_BOUNDS = (0.5, 0.8)


@dataclass(frozen=True)
class SLOClass:
    """Latency service-level objective: a target and the fraction of
    requests allowed to miss it before the tenant's SLO is considered
    violated (closed-loop clients retry on a per-request miss regardless;
    the tolerance is the *reporting* threshold)."""

    latency_s: float = float("inf")
    miss_tolerance: float = 0.0


@dataclass(frozen=True)
class TenantSpec:
    name: str
    allowance_g: float = float("inf")     # carbon allowance per period
    period_hours: float = float("inf")    # inf -> one everlasting period
    slo: SLOClass = SLOClass()
    mode: str = "performance"             # preferred mode (escalation floor)
    priority: int = 0                     # client seeding order tie-break
    defer_over_reject: bool = True        # park over-budget work for the
    #                                       next period instead of rejecting

    def __post_init__(self):
        if self.mode not in MODE_ORDER:
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"choose from {MODE_ORDER}")
        if self.allowance_g < 0:
            raise ValueError("allowance_g must be >= 0")
        if self.period_hours <= 0:
            raise ValueError("period_hours must be > 0")


@dataclass(frozen=True)
class TenantTask(Task):
    """A schedulable task tagged with its tenant. Untagged tasks (or an
    empty tenant) pass through admission unconditionally with the
    engine's default weights."""

    tenant: str = ""


class TenantRegistry:
    """Vectorized per-tenant state shared by engine, policy and sim.

    Static columns come from the specs at registration; the mutable
    columns (``spent_g``, ``period_idx``, counters) are updated in bulk by
    :meth:`roll` / :meth:`charge` — one numpy op or one Python iteration
    per *distinct* tenant, never per task. Registration is setup-time
    (columns are rebuilt per register call); the hot path only reads.
    """

    def __init__(self, specs: Iterable[TenantSpec] = ()):
        self.specs: Dict[str, TenantSpec] = {}
        self.index: Dict[str, int] = {}
        self.names: List[str] = []
        self._rebuild_static()
        for col in ("spent_g", "total_carbon_g", "peak_spent_g"):
            setattr(self, col, np.zeros(0))
        for col in ("period_idx", "completed", "admitted", "rejected",
                    "deferred"):
            setattr(self, col, np.zeros(0, np.int64))
        for s in specs:
            self.register(s)

    # -- registration ------------------------------------------------------
    def _rebuild_static(self) -> None:
        specs = [self.specs[n] for n in self.names]
        self.allowance_g = np.array([s.allowance_g for s in specs])
        self.period_hours = np.array([s.period_hours for s in specs])
        self.priority = np.array([s.priority for s in specs], dtype=np.int64)
        self.slo_latency_s = np.array([s.slo.latency_s for s in specs])
        self.miss_tolerance = np.array([s.slo.miss_tolerance for s in specs])
        self.mode_floor = np.array([MODE_ORDER.index(s.mode) for s in specs],
                                   dtype=np.int8)
        self.defer_ok = np.array([s.defer_over_reject for s in specs],
                                 dtype=bool)

    def register(self, spec: TenantSpec) -> TenantSpec:
        if spec.name in self.index:
            raise ValueError(f"tenant {spec.name!r} already registered")
        self.specs[spec.name] = spec
        self.index[spec.name] = len(self.names)
        self.names.append(spec.name)
        self._rebuild_static()
        for col in ("spent_g", "total_carbon_g", "peak_spent_g",
                    "period_idx", "completed", "admitted", "rejected",
                    "deferred"):
            arr = getattr(self, col)
            setattr(self, col, np.append(arr, arr.dtype.type(0)))
        return spec

    @property
    def n(self) -> int:
        return len(self.names)

    # -- task resolution ---------------------------------------------------
    def ids(self, tasks: Sequence) -> np.ndarray:
        """(B,) registry index per task; -1 for untagged/unknown tenants
        (admitted unconditionally, default weights)."""
        idx = self.index
        return np.array([idx.get(getattr(t, "tenant", ""), -1)
                         for t in tasks], dtype=np.int64)

    # -- accounting periods ------------------------------------------------
    def roll(self, now_hour: float) -> None:
        """Advance tenants whose accounting period boundary has passed:
        reset the current-period spend (escalation thresholds must see the
        *current* period only — the rollover bug the shimmed
        BudgetedRouter had). Lifetime totals are untouched."""
        if not self.n:
            return
        finite = np.isfinite(self.period_hours)
        if not finite.any():
            return
        idx = np.zeros(self.n, dtype=np.int64)
        ph = self.period_hours[finite]
        div = np.floor(now_hour / ph).astype(np.int64)
        # Deferral wakes are computed by MULTIPLICATION ((k+1) * period,
        # next_period_start); float division can land an ulp short of that
        # boundary (0.29 / 0.01 -> 28.999…), which would leave a woken
        # task in its exhausted period forever. Align the two arithmetics:
        # a tenant is in period k+1 once (k+1) * period <= now.
        div += ((div + 1) * ph <= now_hour)
        idx[finite] = div
        fresh = idx > self.period_idx
        if fresh.any():
            self.spent_g[fresh] = 0.0
            self.period_idx[fresh] = idx[fresh]

    def next_period_start(self) -> np.ndarray:
        """(T,) hour each tenant's next period begins (inf for everlasting
        periods — such tenants can never be deferred into fresh budget)."""
        return (self.period_idx + 1) * self.period_hours

    # -- spend -------------------------------------------------------------
    def remaining_g(self) -> np.ndarray:
        return np.maximum(self.allowance_g - self.spent_g, 0.0)

    def utilisation(self) -> np.ndarray:
        """(T,) fraction of the current period's allowance spent (1.0 for a
        zero allowance — always maximally escalated)."""
        out = np.ones(self.n)
        pos = self.allowance_g > 0
        np.divide(self.spent_g, self.allowance_g, out=out, where=pos)
        return out

    def charge(self, tenant_idx: np.ndarray, carbon_g: np.ndarray) -> None:
        """Bill executed carbon to tenants: one ledger fold per *distinct*
        tenant, with each tenant's values accumulated in task order via
        :func:`~repro.core.energy.ledger_add` — bit-identical to a scalar
        ``spent += c`` loop (the same contract the cluster/monitor batched
        ledgers honour, DESIGN.md §6). Entries with index -1 (untagged
        tasks) are skipped."""
        tid = np.asarray(tenant_idx, dtype=np.int64).reshape(-1)
        c = np.asarray(carbon_g, dtype=float).reshape(-1)
        valid = tid >= 0
        if not valid.any():
            return
        tid, c = tid[valid], c[valid]
        order = np.argsort(tid, kind="stable")
        ts, cs = tid[order], c[order]
        uniq, starts = np.unique(ts, return_index=True)
        bounds = np.append(starts, ts.size)
        for k, u in enumerate(uniq):
            seg = cs[bounds[k]:bounds[k + 1]]
            self.spent_g[u] = ledger_add(self.spent_g[u], seg)
            self.total_carbon_g[u] = ledger_add(self.total_carbon_g[u], seg)
            self.completed[u] += seg.size
            # lifetime max of any single period's spend — the observable
            # the admission invariant (spend <= allowance, up to one
            # task's float noise) is asserted against
            if self.spent_g[u] > self.peak_spent_g[u]:
                self.peak_spent_g[u] = self.spent_g[u]

    def uncount_admitted(self, tenant_idx: np.ndarray) -> None:
        """Reverse :meth:`plan`'s admitted counting for tasks that were
        requeued by a mid-batch failure — they will be re-planned (and
        re-counted) when the caller retries the step, so without this the
        admission counters would inflate per retry."""
        tid = np.asarray(tenant_idx, dtype=np.int64).reshape(-1)
        tid = tid[tid >= 0]
        if tid.size:
            np.add.at(self.admitted, tid, -1)

    # -- reporting ---------------------------------------------------------
    def report(self) -> Dict[str, Dict[str, float]]:
        util = self.utilisation()
        rem = self.remaining_g()
        return {
            name: {
                "allowance_g": float(self.allowance_g[i]),
                "period_hours": float(self.period_hours[i]),
                "period_idx": int(self.period_idx[i]),
                "spent_g": float(self.spent_g[i]),
                "remaining_g": float(rem[i]),
                "utilisation": float(util[i]),
                "peak_spent_g": float(self.peak_spent_g[i]),
                "total_carbon_g": float(self.total_carbon_g[i]),
                "completed": int(self.completed[i]),
                "admitted": int(self.admitted[i]),
                "rejected": int(self.rejected[i]),
                "deferred": int(self.deferred[i]),
            }
            for name, i in self.index.items()
        }
