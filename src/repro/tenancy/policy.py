"""Budget-aware per-tenant scheduling as a policy wrapper (DESIGN.md §7).

:class:`TenantPolicy` composes over any
:class:`~repro.core.api.SchedulingPolicy` and a
:class:`~repro.tenancy.spec.TenantRegistry`. It replaces the deprecated
``BudgetedRouter``'s weight-swapping with three explicit, batched phases
the engine drives per step:

1. :meth:`plan` — **admission**: for each drained task, decide
   admit / defer / reject from the tenant's *current-period* budget, and
   compute the tenant's **effective mode** (performance → balanced →
   green) from budget pressure. All of it is column math over the
   registry's vectorized tenant state: O(B) numpy plus O(distinct
   tenants) Python per step, never O(B) Python.
2. :meth:`select_admitted` — **placement**: admitted tasks are grouped by
   effective mode (≤ 3 groups) and each group goes through the wrapped
   policy's batched ``select_batch`` with that mode's weights. A tenant
   whose mode-chosen placements would overrun its remaining budget has
   its *whole group this step* re-placed on the greenest feasible node
   (the reservation admission made), so actual spend can never exceed the
   allowance — the per-request special case of this rule is exactly the
   old BudgetedRouter's greenest-pod fallback.
3. :meth:`charge` — **billing**: executed carbon is folded into the
   registry per distinct tenant in task order
   (:func:`~repro.core.energy.ledger_add`), bit-identical to a scalar
   ``spent += c`` loop — the same contract as the batched cluster/monitor
   ledgers (DESIGN.md §6).

Admission semantics (per tenant, per step): tasks are considered in batch
order; each is admitted while the cumulative expected carbon of the
tenant's admitted prefix — expected = the task's energy on the
minimum-intensity ("greenest") feasible node — still fits the remaining
allowance. Expected carbon is cumulative and non-negative, so denial is
always a suffix of the tenant's slice of the batch. A denied task is
DEFERred to the tenant's next period start when the spec allows it, the
period is finite and a fresh period's allowance could cover the task;
otherwise it is REJECTed. Tasks that are feasible nowhere are admitted
with zero expected carbon — resource infeasibility is the selection
layer's verdict, not admission's.

``TenantPolicy`` also satisfies the plain ``SchedulingPolicy`` protocol:
``select``/``select_batch`` apply mode escalation (no admission, no
charging), so it can drop into any engine or router as a scoring policy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.api import intensity_interval_batch
from repro.core.energy import carbon_g
from repro.core.scheduler import MODES, Task, Weights, node_feasible
from repro.tenancy.spec import (ESCALATION_BOUNDS, MODE_ORDER, TenantRegistry,
                                TenantSpec)

# Admission actions (AdmissionPlan.actions values).
ADMIT, DEFER, REJECT = 0, 1, 2


@dataclass
class AdmissionPlan:
    """Struct-of-arrays admission decisions for one drained batch.

    ``modes`` indexes :data:`~repro.tenancy.spec.MODE_ORDER`; -1 means
    "engine default weights" (untagged / unregistered tenant).
    ``wake_hour`` is only meaningful where ``actions == DEFER``.
    ``expected_g`` is the greenest-feasible expected carbon admission
    reserved (0 for untagged or nowhere-feasible tasks).
    """

    actions: np.ndarray        # (B,) int8
    modes: np.ndarray          # (B,) int8
    tenant_idx: np.ndarray     # (B,) int64, -1 = untagged
    expected_g: np.ndarray     # (B,) float
    greenest: np.ndarray       # (B,) int64 node index, -1 = none feasible
    wake_hour: np.ndarray      # (B,) float
    node_names: List[str]      # node order `greenest` indexes
    intensities: np.ndarray    # (N,) grid signal admission read
    energy_kwh: np.ndarray     # (B, 1) or (B, N) per-task energy model
    pue: float

    @property
    def all_admitted(self) -> bool:
        return bool((self.actions == ADMIT).all())

    def admitted_index(self) -> np.ndarray:
        return np.nonzero(self.actions == ADMIT)[0]


def cluster_energy_model(cluster, tasks: Sequence[Task],
                         names: Sequence[str]) -> np.ndarray:
    """Default expected-energy model: the execution cost model itself
    (``EdgeCluster.latency_energy`` — full host power over the measured
    distributed latency), so admission reservations equal the carbon the
    engine will actually bill. Node-independent: returns (B, 1)."""
    base = np.array([t.base_latency_ms for t in tasks], dtype=float)
    fn = getattr(cluster, "latency_energy", None)
    if fn is None:
        # duck-typed cluster without the execution cost model: admission
        # cannot price work, so everything is affordable (expected 0)
        return np.zeros((len(tasks), 1))
    _, e_kwh = fn(base, distributed=True)
    return np.asarray(e_kwh, dtype=float)[:, None]


class TenantPolicy:
    """Composable multi-tenant admission + escalation wrapper around any
    scheduling policy (see module docstring for the three-phase engine
    protocol; :class:`~repro.core.api.CarbonEdgeEngine` detects the
    ``plan``/``charge`` hooks and drives them automatically).

    ``energy_model(cluster, tasks, node_names) -> (B, 1) | (B, N) kWh``
    prices a task's execution for admission; the default is the cluster's
    own execution cost model. ``pue`` defaults to the cluster's.
    """

    name = "tenant"

    def __init__(self, inner=None, registry: Optional[TenantRegistry] = None,
                 *, energy_model: Optional[Callable] = None,
                 escalation_bounds: Sequence[float] = ESCALATION_BOUNDS,
                 defer_risk_coverage: Optional[float] = None):
        if inner is None:
            from repro.core.policy import VectorizedPolicy
            inner = VectorizedPolicy()
        self.inner = inner
        self.registry = registry if registry is not None else TenantRegistry()
        self.energy_model = energy_model or cluster_energy_model
        self._bounds = np.asarray(escalation_bounds, dtype=float)
        # Risk-bounded deferral (DESIGN.md §8): when set, a budget DEFER
        # must also be defensible against the provider's conformal
        # intensity interval at this coverage level, else it downgrades
        # to REJECT. None (default) keeps the point-forecast behaviour.
        if defer_risk_coverage is not None and not 0.0 < defer_risk_coverage < 1.0:
            raise ValueError("defer_risk_coverage must be in (0, 1) or None")
        self.defer_risk_coverage = defer_risk_coverage
        # Observability (DESIGN.md §9): full-length per-task score capture
        # assembled from the wrapped policy's per-mode sub-batches; the
        # capture/profiler switches themselves forward to `inner`.
        self.last_scores = None

    def register(self, spec: TenantSpec) -> TenantSpec:
        return self.registry.register(spec)

    def alert_rules(self, window_hours: float):
        """Per-tenant carbon-pace alert rules for the obs alerting engine
        (DESIGN.md §12): a tenant burning faster than
        ``allowance_g * window_hours / period_hours`` per rollup window is
        on pace to exhaust its budget before the period rolls. Tenants
        with infinite allowances or everlasting periods get no rule.
        Deterministically ordered by tenant name."""
        from repro.obs.alerts import AlertRule
        reg = self.registry
        rules = []
        for name in sorted(reg.index):
            i = reg.index[name]
            allow = float(reg.allowance_g[i])
            period = float(reg.period_hours[i])
            if not (np.isfinite(allow) and np.isfinite(period)):
                continue
            rules.append(AlertRule(
                name=f"carbon_pace[{name}]", kind="carbon_pace",
                threshold=allow * float(window_hours) / period,
                tenant=name))
        return rules

    # -- observability passthrough (DESIGN.md §9) --------------------------
    @property
    def capture_scores(self) -> bool:
        return bool(getattr(self.inner, "capture_scores", False))

    @capture_scores.setter
    def capture_scores(self, value: bool) -> None:
        if hasattr(self.inner, "capture_scores"):
            self.inner.capture_scores = bool(value)

    @property
    def profiler(self):
        return getattr(self.inner, "profiler", None)

    @profiler.setter
    def profiler(self, value) -> None:
        if hasattr(self.inner, "profiler"):
            self.inner.profiler = value

    # -- shared helpers ----------------------------------------------------
    def _latency_threshold(self) -> float:
        # admission must probe feasibility with the same filter the
        # wrapped policy selects with, or it would reserve on nodes the
        # selection layer will never use
        return getattr(self.inner, "latency_threshold_ms", 5000.0)

    def _feasibility(self, cluster, tasks: Sequence[Task], provider,
                     now_hour: float):
        """Greenest feasible node per task: returns ``(greenest_idx (B,),
        names, intensities (N,))`` with -1 where no node is feasible.
        Dedups (cpu, mem) resource profiles so the (U, N) mask — not a
        (B, N) one — is the only per-node work."""
        fc = getattr(cluster, "feature_cache", None)
        cache = fc() if callable(fc) else None
        keys = [(t.cpu, t.mem_mb) for t in tasks]
        uniq: dict = {}
        for k in keys:
            if k not in uniq:
                uniq[k] = len(uniq)
        prof = np.array([uniq[k] for k in keys], dtype=np.int64)
        cpu_u = np.array([k[0] for k in uniq], dtype=float)
        mem_u = np.array([k[1] for k in uniq], dtype=float)
        if cache is not None:
            names = cache.names
            feas = cache.feasible(cpu_u, mem_u, self._latency_threshold())
            ints = np.asarray(cache.intensities(provider, now_hour,
                                                need=feas.any(axis=0)),
                              dtype=float)
        else:
            # duck-typed cluster: scalar fallback (small fleets only)
            names = list(cluster.nodes)
            thresh = self._latency_threshold()
            feas = np.zeros((len(uniq), len(names)), dtype=bool)
            ints = np.zeros(len(names))
            probes = [Task(cpu=c, mem_mb=m) for c, m in uniq]
            for j, n in enumerate(names):
                st = cluster.nodes[n]
                col = np.array([st.avg_time_ms <= thresh
                                and node_feasible(st, p) for p in probes])
                feas[:, j] = col
                if col.any():
                    ints[j] = (provider.intensity(n, now_hour)
                               if provider is not None
                               else st.spec.carbon_intensity)
        masked = np.where(feas, ints[None, :], np.inf)
        g_u = np.where(feas.any(axis=1), np.argmin(masked, axis=1), -1)
        return g_u[prof], names, ints

    def _modes_from_util(self, util: np.ndarray,
                         tid: np.ndarray) -> np.ndarray:
        """Escalation stage from utilisation, floored at each tenant's
        preferred mode — vectorized ``BudgetedRouter._mode_for``."""
        stage = np.searchsorted(self._bounds, util, side="right")
        floor = self.registry.mode_floor[tid]
        return np.minimum(np.maximum(stage, floor),
                          len(MODE_ORDER) - 1).astype(np.int8)

    def effective_modes(self) -> dict:
        """Current effective mode per tenant from current-period
        utilisation — a side-effect-free observability read (the per-task
        modes :meth:`plan` assigns additionally account for the batch's
        own cumulative reservations)."""
        reg = self.registry
        tid = np.arange(reg.n, dtype=np.int64)
        modes = self._modes_from_util(reg.utilisation(), tid)
        return {name: MODE_ORDER[modes[i]]
                for name, i in reg.index.items()}

    # -- phase 1: admission ------------------------------------------------
    def plan(self, cluster, tasks: Sequence[Task], provider=None,
             now_hour: float = 0.0) -> AdmissionPlan:
        """Batched admit/defer/reject + effective-mode decisions for one
        drained batch (see module docstring for the semantics)."""
        reg = self.registry
        reg.roll(now_hour)
        B = len(tasks)
        tid = reg.ids(tasks)
        actions = np.zeros(B, dtype=np.int8)
        modes = np.full(B, -1, dtype=np.int8)
        expected = np.zeros(B)
        wake = np.full(B, np.inf)
        pue = float(getattr(cluster, "pue", 1.0))
        reg_pos = np.nonzero(tid >= 0)[0]
        if not reg_pos.size:
            # nothing to price: every task is untagged/unknown, so skip
            # the feasibility masks, provider reads and energy model
            return AdmissionPlan(actions, modes, tid, expected,
                                 np.full(B, -1, dtype=np.int64), wake,
                                 [], np.zeros(0), np.zeros((B, 1)), pue)
        greenest, names, ints = self._feasibility(cluster, tasks, provider,
                                                  now_hour)
        e_kwh = np.asarray(self.energy_model(cluster, tasks, names),
                           dtype=float)
        # expected carbon at the greenest feasible node (the admission
        # reservation); nowhere-feasible tasks price at 0 — selection,
        # not admission, is what fails them
        g = greenest[reg_pos]
        feas = g >= 0
        e_at_g = (e_kwh[reg_pos, 0] if e_kwh.shape[1] == 1
                  else e_kwh[reg_pos, np.maximum(g, 0)])
        exp = np.where(feas,
                       carbon_g(e_at_g, ints[np.maximum(g, 0)], pue), 0.0)
        expected[reg_pos] = exp
        # per-tenant segmented cumulative reservation, in batch order
        t = tid[reg_pos]
        order = np.argsort(t, kind="stable")
        ts, es = t[order], exp[order]
        cs = np.cumsum(es)
        new_seg = np.r_[True, ts[1:] != ts[:-1]]
        starts = np.nonzero(new_seg)[0]
        seg_id = np.cumsum(new_seg) - 1
        base = np.where(starts > 0, cs[np.maximum(starts - 1, 0)], 0.0)
        cum_incl = cs - base[seg_id]
        cum_excl = cum_incl - es
        allow = reg.allowance_g[ts]
        spent = reg.spent_g[ts]
        remaining = np.maximum(allow - spent, 0.0)
        util = np.ones(ts.size)
        np.divide(spent + cum_excl, allow, out=util, where=allow > 0)
        mode_s = self._modes_from_util(util, ts)
        ok = cum_incl <= remaining
        # a denied task defers only when fresh budget could ever cover
        # it; otherwise deferral is a busy-loop and we reject outright
        can_defer = (reg.defer_ok[ts] & np.isfinite(reg.period_hours[ts])
                     & (es <= allow))
        act_s = np.where(ok, ADMIT, np.where(can_defer, DEFER, REJECT))
        wake_s = np.where(act_s == DEFER,
                          reg.next_period_start()[ts], np.inf)
        if self.defer_risk_coverage is not None and provider is not None:
            act_s = self._risk_defer_gate(provider, names, act_s, wake_s,
                                          g[order], now_hour)
            wake_s[act_s != DEFER] = np.inf
        pos = reg_pos[order]
        actions[pos] = act_s
        modes[pos] = mode_s
        wake[pos] = wake_s
        np.add.at(reg.admitted, ts[act_s == ADMIT], 1)
        np.add.at(reg.deferred, ts[act_s == DEFER], 1)
        np.add.at(reg.rejected, ts[act_s == REJECT], 1)
        return AdmissionPlan(actions, modes, tid, expected, greenest, wake,
                             list(names), ints, e_kwh, pue)

    def _risk_defer_gate(self, provider, names, act: np.ndarray,
                         wake: np.ndarray, gidx: np.ndarray,
                         now_hour: float) -> np.ndarray:
        """Risk-bounded deferral (DESIGN.md §8): a budget DEFER survives
        only while the conformal intensity interval at its wake hour could
        still be at least as good as executing now on the task's greenest
        feasible node — ``lo_wake <= hi_now``. When even the optimistic
        wake-hour bound certainly loses (``lo_wake > hi_now``), deferral
        burns the client's time for provably worse carbon, so the task is
        REJECTed outright instead. Zero-width (measured/static) intervals
        keep every DEFER — the gate only bites when a calibrated forecast
        is confidently pessimistic about the wake window. One batched
        interval read per distinct wake hour; nowhere-feasible tasks
        (``gidx < 0``) are admission-priced at zero and pass through."""
        d = np.nonzero((act == DEFER) & (gidx >= 0) & np.isfinite(wake))[0]
        if not d.size:
            return act
        cov = self.defer_risk_coverage
        _, hi_now = intensity_interval_batch(provider, names, now_hour,
                                             coverage=cov)
        hi_now = np.asarray(hi_now, dtype=float)
        for h in np.unique(wake[d]):
            sel = d[wake[d] == h]
            lo_w, _ = intensity_interval_batch(provider, names, float(h),
                                               coverage=cov)
            lo_w = np.asarray(lo_w, dtype=float)
            gs = gidx[sel]
            act[sel[lo_w[gs] > hi_now[gs]]] = REJECT
        return act

    # -- phase 2: placement ------------------------------------------------
    def select_admitted(self, cluster, tasks: Sequence[Task],
                        plan: AdmissionPlan, weights: Weights, provider=None,
                        now_hour: float = 0.0) -> List[Optional[str]]:
        """Place the plan's admitted tasks: one wrapped ``select_batch``
        per distinct effective mode, then the budget fallback — a tenant
        whose mode-chosen placements would overrun its remaining
        allowance is re-placed wholesale on its greenest feasible nodes
        (the reservation admission checked). Returns a full-length choice
        list with ``None`` at non-admitted positions."""
        out: List[Optional[str]] = [None] * len(tasks)
        aidx = plan.admitted_index()
        if not aidx.size:
            return out
        self._select_by_modes(cluster, tasks, aidx, plan.modes[aidx],
                              weights, provider, now_hour, out)
        self._budget_fallback(plan, out, aidx)
        return out

    def _select_by_modes(self, cluster, tasks: Sequence[Task],
                         positions: np.ndarray, modes: np.ndarray,
                         weights: Weights, provider, now_hour: float,
                         out: List[Optional[str]]) -> None:
        """Scatter mode-grouped placements into ``out``: one wrapped
        ``select_batch`` per distinct effective mode (-1 = the caller's
        default weights)."""
        agg = None
        if self.capture_scores:
            B = len(tasks)
            agg = {"score": np.full(B, np.nan),
                   "runner_up": np.full(B, np.nan)}
        for m in np.unique(modes):
            sel = positions[modes == m]
            w = weights if m < 0 else MODES[MODE_ORDER[m]]
            sub = self.inner.select_batch(cluster, [tasks[i] for i in sel],
                                          w, provider=provider,
                                          now_hour=now_hour)
            for i, ch in zip(sel, sub):
                out[i] = ch
            if agg is not None:
                # scatter the sub-batch's capture into full-length columns
                # (NB: a later budget fallback may move a task off its
                # mode-chosen node; the captured score stays the mode
                # selection's — DESIGN.md §9)
                ls = getattr(self.inner, "last_scores", None)
                if ls is not None and len(ls.get("score", ())) == len(sel):
                    agg["score"][sel] = ls["score"]
                    if ls.get("runner_up") is not None:
                        agg["runner_up"][sel] = ls["runner_up"]
                    cut = ls.get("cut")
                    if cut is not None:
                        agg.setdefault(
                            "cut", np.full(len(tasks), -1,
                                           dtype=np.int32))[sel] = cut
        if agg is not None:
            self.last_scores = agg

    def _budget_fallback(self, plan: AdmissionPlan,
                         out: List[Optional[str]], aidx: np.ndarray) -> None:
        """Clamp spend to the admission reservation: if the sum of a
        tenant's *chosen-node* expected carbon this step exceeds its
        remaining allowance, every admitted task of that tenant moves to
        its greenest feasible node — whose cumulative cost admission
        already verified fits. The single-request case degenerates to the
        deprecated BudgetedRouter's greenest-pod fallback."""
        reg = self.registry
        tid = plan.tenant_idx[aidx]
        capped = tid >= 0
        if capped.any():
            capped &= np.isfinite(reg.allowance_g[np.maximum(tid, 0)])
        if not capped.any():
            return                      # unlimited tenants: nothing to clamp
        cpos = aidx[capped]
        placed = np.array([out[i] is not None for i in cpos])
        if not placed.any():
            return
        cpos = cpos[placed]
        nidx = {n: j for j, n in enumerate(plan.node_names)}
        chosen = np.array([nidx[out[i]] for i in cpos], dtype=np.int64)
        e = (plan.energy_kwh[cpos, 0] if plan.energy_kwh.shape[1] == 1
             else plan.energy_kwh[cpos, chosen])
        cost = carbon_g(e, plan.intensities[chosen], plan.pue)
        t = plan.tenant_idx[cpos]
        remaining = np.maximum(reg.allowance_g - reg.spent_g, 0.0)
        totals = np.zeros(reg.n)
        np.add.at(totals, t, cost)
        over = totals[t] > remaining[t]
        for i, g in zip(cpos[over], plan.greenest[cpos[over]]):
            if g >= 0:
                out[i] = plan.node_names[g]

    # -- phase 3: billing --------------------------------------------------
    def charge(self, tenant_idx: np.ndarray, carbon: np.ndarray,
               now_hour: float = 0.0) -> None:
        """Fold executed carbon into the registry (see module docstring).
        Safe to call with the executed *prefix* after a mid-batch
        failure — the engine does exactly that."""
        self.registry.roll(now_hour)
        self.registry.charge(tenant_idx, carbon)

    # -- SchedulingPolicy protocol (escalation only, no admission) ---------
    def select_batch(self, cluster, tasks: Sequence[Task], weights: Weights,
                     provider=None, now_hour: float = 0.0
                     ) -> List[Optional[str]]:
        """Mode-escalated placement without admission control or charging
        (protocol use — a router or engine that doesn't speak the
        plan/charge protocol still gets budget-pressure escalation)."""
        reg = self.registry
        reg.roll(now_hour)
        B = len(tasks)
        tid = reg.ids(tasks)
        modes = np.full(B, -1, dtype=np.int8)
        pos = np.nonzero(tid >= 0)[0]
        if pos.size:
            util = reg.utilisation()[tid[pos]]
            modes[pos] = self._modes_from_util(util, tid[pos])
        out: List[Optional[str]] = [None] * B
        self._select_by_modes(cluster, tasks, np.arange(B), modes, weights,
                              provider, now_hour, out)
        return out

    def select(self, cluster, task, weights, provider=None,
               now_hour: float = 0.0) -> Optional[str]:
        return self.select_batch(cluster, [task], weights, provider=provider,
                                 now_hour=now_hour)[0]
