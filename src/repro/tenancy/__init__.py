"""Multi-tenant workload subsystem (DESIGN.md §7).

Tenant contracts (SLO class, periodic carbon allowance, mode preference),
a vectorized shared :class:`TenantRegistry`, and :class:`TenantPolicy` —
budget-aware admission control and mode escalation as a composable
wrapper around any scheduling policy. The engine
(:class:`~repro.core.api.CarbonEdgeEngine`) detects the policy's
``plan``/``charge`` hooks and applies per-task admit/defer/reject
decisions before selection; the sim's closed-loop clients
(:class:`~repro.sim.arrivals.ClosedLoopClientPool`) react to the
resulting latency, rejections and deferrals.
"""
from repro.tenancy.policy import (ADMIT, DEFER, REJECT, AdmissionPlan,
                                  TenantPolicy, cluster_energy_model)
from repro.tenancy.spec import (ESCALATION_BOUNDS, MODE_ORDER, SLOClass,
                                TenantRegistry, TenantSpec, TenantTask)

__all__ = [
    "ADMIT", "DEFER", "REJECT", "AdmissionPlan", "TenantPolicy",
    "cluster_energy_model",
    "ESCALATION_BOUNDS", "MODE_ORDER", "SLOClass", "TenantRegistry",
    "TenantSpec", "TenantTask",
]
