"""msgpack tensor checkpoints: save/restore arbitrary param pytrees.

Layout: one .msgpack file with {path: {dtype, shape, data(bytes)}} plus a
meta record (step, config name). Sharded arrays are gathered to host before
writing (fine at the scales this container trains); restore reshards via
jax.device_put with the target sharding tree when provided.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    flat = _flatten(tree)
    payload = {
        "__meta__": meta or {},
        "tensors": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(".tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, p)


def restore(path: str, target_tree: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree``."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    tensors = payload["tensors"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (pathk, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(pathk)
        rec = tensors[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(path: str) -> Dict:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    return payload.get("__meta__", {})
