"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, softcap: float = 0.0):
    """q: (B,H,Sq,hd); k/v: (B,K,Sk,hd). Plain softmax attention."""
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=1)
        v = jnp.repeat(v, H // K, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, pos, *, window: Optional[int] = None,
                         softcap: float = 0.0):
    """q: (B,H,hd); k/v: (B,K,S,hd); pos scalar."""
    B, H, hd = q.shape
    K, S = k.shape[1], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=1)
        v = jnp.repeat(v, H // K, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    ki = jnp.arange(S)
    mask = ki <= pos
    if window is not None:
        mask &= ki > pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def mamba2_chunk_ref(xdt, Bh, Ch, cum, state):
    """Sequential within-chunk recurrence (the ground truth).

    xdt: (B,H,L,P); Bh/Ch: (B,H,L,N); cum: (B,H,L); state: (B,H,N,P).
    """
    B, H, L, P = xdt.shape
    dA = jnp.diff(jnp.concatenate(
        [jnp.zeros(cum.shape[:-1] + (1,), cum.dtype), cum], axis=-1), axis=-1)

    def step(s, t):
        a = jnp.exp(dA[:, :, t])[..., None, None]              # (B,H,1,1)
        upd = jnp.einsum("bhn,bhp->bhnp", Bh[:, :, t].astype(jnp.float32),
                         xdt[:, :, t].astype(jnp.float32))
        s = a * s + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, :, t].astype(jnp.float32), s)
        return s, y

    s, ys = jax.lax.scan(step, state.astype(jnp.float32), jnp.arange(L))
    y = jnp.moveaxis(ys, 0, 2).astype(xdt.dtype)               # (B,H,L,P)
    return y, s


def node_scores_ref(features, weights):
    """features: (N, 8); weights: (8,) -> (N,). Mirrors core/scheduler."""
    f = features.astype(jnp.float32)
    s_r = 0.5 * jnp.minimum(f[:, 0], 1.0) + 0.5 * jnp.minimum(f[:, 1], 1.0)
    s_l = 1.0 - f[:, 2]
    s_p = 1.0 / (1.0 + f[:, 3])
    s_b = 1.0 / (1.0 + 2.0 * f[:, 4])
    s_c = 1.0 / (1.0 + f[:, 5])
    total = (weights[0] * s_r + weights[1] * s_l + weights[2] * s_p
             + weights[3] * s_b + weights[4] * s_c)
    return jnp.where(f[:, 6] > 0.5, total, NEG_INF)


def node_scores_batched_ref(features, weights):
    """features: (B, N, 8); weights: (8,) -> (B, N)."""
    return jax.vmap(node_scores_ref, in_axes=(0, None))(features, weights)
