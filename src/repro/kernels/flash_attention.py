"""Flash attention forward — Pallas TPU kernel.

Online-softmax blocked attention: grid (batch, q_heads, q_blocks,
kv_blocks) with VMEM scratch accumulators carried across the innermost
(arbitrary) kv dimension. GQA is handled in the KV index_map (kv head =
q_head // group) so KV is never materialised per-q-head. Causal and
sliding-window masks are applied from block offsets; Gemma-style logit
softcap supported.

Block shapes default to (128, 128) — MXU-aligned, and the working set
(q, k, v, scores, acc ≈ 6 * 128 * head_dim * 4B) stays well under VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: float, bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / lsum).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, K, Sk, hd) with H % K == 0.

    Returns (B, H, Sq, hd). Sq/Sk must be multiples of bq/bk.
    """
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    g = H // K
    nq, nk = Sq // bq, Sk // bk
    scale = hd ** -0.5

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
