"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode; on TPU they
compile to Mosaic. ``use_pallas()`` gates whether model code routes through
kernels or the pure-jnp reference path (the default on CPU, where interpret
mode is slow).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba2_chunk as _mc
from repro.kernels import node_score as _ns
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_pallas() -> bool:
    if os.environ.get("REPRO_USE_PALLAS"):
        return os.environ["REPRO_USE_PALLAS"] not in ("0", "false")
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    softcap: float = 0.0):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=_interpret())


def decode_attention(q, k, v, pos, *, window: Optional[int] = None,
                     softcap: float = 0.0):
    return _dec.decode_attention(q, k, v, pos, window=window,
                                 softcap=softcap, interpret=_interpret())


def mamba2_chunk(xdt, Bh, Ch, cum, state):
    return _mc.mamba2_chunk(xdt, Bh, Ch, cum, state, interpret=_interpret())


def node_scores(features, weights):
    return _ns.node_scores(features, weights, interpret=_interpret())


def select_best_node(features, weights):
    return _ns.select_best(features, weights, interpret=_interpret())


def node_scores_batched(features, weights):
    """(B, N, 8) x (8,) -> (B, N): the engine's one-launch batched scorer."""
    return _ns.node_scores_batched(features, weights, interpret=_interpret())


def select_best_node_batched(features, weights):
    return _ns.select_best_batched(features, weights, interpret=_interpret())


def select_best_node_fused(features, weights):
    """(B, N, 8) x (8,) -> ((B,) int32 best index, (B,) f32 best score):
    the fused score+argmax kernel — per-task winners reduced on-chip, no
    (B, N) score matrix shipped to host."""
    return _ns.select_best_fused(features, weights, interpret=_interpret())


def select_best_node_joint(features, weights):
    """(B, P, N, 8) x (8,) -> ((B,) int32 cut idx, (B,) int32 node idx,
    (B,) f32 best score): the fused joint partition+placement reduction —
    per-task (cut, node) winners folded on-chip with lowest-(p, n) tie
    semantics; see node_score.select_best_joint."""
    return _ns.select_best_joint(features, weights, interpret=_interpret())


def select_best_node_sharded(features, weights, mesh=None, axis="nodes"):
    """Fused select with the node axis sharded across devices via
    shard_map (cross-shard argmax combine); see node_score.select_best_sharded."""
    return _ns.select_best_sharded(features, weights, mesh, axis,
                                   interpret=_interpret())


# Re-export oracles for tests/benchmarks.
flash_attention_ref = ref.flash_attention_ref
decode_attention_ref = ref.decode_attention_ref
mamba2_chunk_ref = ref.mamba2_chunk_ref
node_scores_ref = ref.node_scores_ref
node_scores_batched_ref = ref.node_scores_batched_ref
