"""Carbon-aware node scoring (paper Algorithm 1) — Pallas TPU kernel.

The paper's NSA inner loop at fleet scale: for N nodes, fuse the five score
components (Eq. 3) and the feasibility filter into one VMEM pass, emitting
per-node total scores (invalid nodes get -inf). The host (or a tiny jnp
argmax) picks the winner. At 10^5-10^6 nodes this is one HBM read of the
(N, 8) feature matrix — the op is memory-bound and the fusion is the win.

Feature layout (N, 8) float32:
  0 cpu_free_frac, 1 mem_free_frac, 2 load, 3 avg_time_s,
  4 running_tasks, 5 intensity_x_e_est (I * E_est, Eq. 4),
  6 valid (1/0 feasibility), 7 padding
Weights: (8,) = [w_R, w_L, w_P, w_B, w_C, 0, 0, 0].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro import compat

NEG_INF = -1e30


def _eq3_tile_scores(f, w):
    """(bn, 8) feature tile x (1, 8) weights -> (bn,) masked total scores.
    The single in-kernel statement of the Eq. 3/4 component math, shared by
    the score-emitting and the fused select kernels."""
    s_r = 0.5 * jnp.minimum(f[:, 0], 1.0) + 0.5 * jnp.minimum(f[:, 1], 1.0)
    s_l = 1.0 - f[:, 2]
    s_p = 1.0 / (1.0 + f[:, 3])
    s_b = 1.0 / (1.0 + 2.0 * f[:, 4])
    s_c = 1.0 / (1.0 + f[:, 5])
    total = (w[0, 0] * s_r + w[0, 1] * s_l + w[0, 2] * s_p
             + w[0, 3] * s_b + w[0, 4] * s_c)
    valid = f[:, 6] > 0.5
    return jnp.where(valid, total, NEG_INF)


def _kernel(f_ref, w_ref, s_ref):
    f = f_ref[...]                                 # (bn, 8)
    w = w_ref[...]                                 # (1, 8)
    s_ref[...] = _eq3_tile_scores(f, w)[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def node_scores(features, weights, *, bn: int = 1024, interpret: bool = False):
    """features: (N, 8) f32; weights: (8,) f32 -> (N,) scores.

    N is padded up to a multiple of bn internally (padding rows invalid).
    """
    n0 = features.shape[0]
    pad = (-n0) % bn
    if pad:
        features = jnp.pad(features, ((0, pad), (0, 0)))
    N = features.shape[0]
    w2 = weights.reshape(1, 8)
    out = pl.pallas_call(
        _kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, 8), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(features, w2)
    return out[:n0, 0]


def select_best(features, weights, *, interpret: bool = False) -> jnp.ndarray:
    """Fused scoring + argmax; returns best node index (int32)."""
    s = node_scores(features, weights, interpret=interpret)
    return jnp.argmax(s).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Batched variant: B pending tasks x N nodes in ONE kernel launch
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def node_scores_batched(features, weights, *, bn: int = 1024,
                        interpret: bool = False):
    """features: (B, N, 8) f32; weights: (8,) f32 -> (B, N) scores.

    The CarbonEdgeEngine hot path: scoring is row-wise with shared weights,
    so B tasks x N nodes flattens to one (B*N, 8) pass through the single
    kernel above — still exactly one pallas_call (and one HBM read of the
    feature tensor) per batch, with no duplicated Eq. 3 math.
    """
    B, N, _ = features.shape
    flat = node_scores(features.reshape(B * N, 8), weights, bn=bn,
                       interpret=interpret)
    return flat.reshape(B, N)


def select_best_batched(features, weights, *, interpret: bool = False):
    """Fused batched scoring + per-task argmax -> (B,) int32 node indices."""
    idx, _ = select_best_fused(features, weights, interpret=interpret)
    return idx


# ---------------------------------------------------------------------------
# Fused score + argmax: reduce to (best_index, best_score) on-chip
# ---------------------------------------------------------------------------


def _select_kernel(f_ref, w_ref, idx_ref, val_ref):
    """One (1, bn, 8) node tile of one task row: score it, reduce to the
    tile's (first) max, and fold into the running per-task best across the
    sequential node-tile grid axis. Emits per-task winner index + score —
    the (B, N) score matrix never leaves the chip."""
    j = pl.program_id(1)
    f = f_ref[0]                                   # (bn, 8)
    w = w_ref[...]                                 # (1, 8)
    s = _eq3_tile_scores(f, w)[None, :]            # (1, bn)
    bn = s.shape[1]
    tile_max = jnp.max(s, axis=1)                             # (1,)
    # first-max index via 2D iota (TPU requires >=2D), np.argmax semantics
    ii = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    tile_arg = jnp.min(jnp.where(s == tile_max[:, None], ii, bn), axis=1)
    gidx = (j * bn + tile_arg).astype(jnp.int32)              # (1,)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = tile_max[:, None]
        idx_ref[...] = gidx[:, None]

    @pl.when(j > 0)
    def _fold():
        prev = val_ref[0, 0]
        # strict > keeps the lowest global index on exact ties
        better = tile_max[0] > prev
        val_ref[0, 0] = jnp.where(better, tile_max[0], prev)
        idx_ref[0, 0] = jnp.where(better, gidx[0], idx_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def select_best_fused(features, weights, *, bn: int = 1024,
                      interpret: bool = False):
    """features: (B, N, 8) f32; weights: (8,) f32 ->
    ((B,) int32 best index, (B,) f32 best score).

    One pallas_call tiling the node axis: each tile reduces to its local
    (max, first-argmax) and folds into the per-task running best across
    the sequential tile axis, so only 2*B scalars ship to host instead of
    a (B, N) score matrix. N is padded to a multiple of bn (padding rows
    invalid -> NEG_INF, never selected while any real node is feasible).
    Callers that want a bounded jit cache should pad (B, N) to shape
    buckets first (VectorizedPolicy does).
    """
    B, n0, _ = features.shape
    pad = (-n0) % bn
    if pad:
        features = jnp.pad(features, ((0, 0), (0, pad), (0, 0)))
    N = features.shape[1]
    w2 = weights.reshape(1, 8)
    idx, val = pl.pallas_call(
        _select_kernel,
        grid=(B, N // bn),
        in_specs=[
            pl.BlockSpec((1, bn, 8), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 8), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(features, w2)
    return idx[:, 0], val[:, 0]


# ---------------------------------------------------------------------------
# Joint (cut, node) selection: fold the winner over a (B, P, N) grid
# ---------------------------------------------------------------------------


def _joint_select_kernel(n_pad, f_ref, w_ref, idx_ref, val_ref):
    """One (1, 1, bn, 8) node tile of one (task, cut) cell: score it with
    the shared Eq. 3 tile math and fold into the running per-task best
    across the sequential cut-major (p, then node-tile j) grid axes. The
    emitted index is flat over the padded (P, N_pad) plane — cut-major, so
    strict-> folding keeps the lowest (p, n) on exact ties, np.argmax-
    compatible with the numpy path's reshape over (P, N)."""
    p = pl.program_id(1)
    j = pl.program_id(2)
    f = f_ref[0, 0]                                # (bn, 8)
    w = w_ref[...]                                 # (1, 8)
    s = _eq3_tile_scores(f, w)[None, :]            # (1, bn)
    bn = s.shape[1]
    tile_max = jnp.max(s, axis=1)                             # (1,)
    ii = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    tile_arg = jnp.min(jnp.where(s == tile_max[:, None], ii, bn), axis=1)
    gidx = (p * n_pad + j * bn + tile_arg).astype(jnp.int32)  # (1,)
    first = (p == 0) & (j == 0)

    @pl.when(first)
    def _init():
        val_ref[...] = tile_max[:, None]
        idx_ref[...] = gidx[:, None]

    @pl.when(jnp.logical_not(first))
    def _fold():
        prev = val_ref[0, 0]
        # strict > keeps the lowest flat (p, n) index on exact ties
        better = tile_max[0] > prev
        val_ref[0, 0] = jnp.where(better, tile_max[0], prev)
        idx_ref[0, 0] = jnp.where(better, gidx[0], idx_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def select_best_joint(features, weights, *, bn: int = 1024,
                      interpret: bool = False):
    """features: (B, P, N, 8) f32; weights: (8,) f32 ->
    ((B,) int32 cut index, (B,) int32 node index, (B,) f32 best score).

    The joint partition+placement reduction
    (:class:`repro.partition.policy.PartitionPolicy`): each task row scans
    its P candidate cuts x N nodes in one pallas_call and ships 3*B
    scalars to host — the (B, P, N) score tensor never leaves the chip.
    The fold order is cut-major (all node tiles of cut 0, then cut 1, ...)
    with a strict-> combine, so exact score ties resolve to the lowest
    (p, n) pair — the same winner ``np.argmax`` picks over the flattened
    (P, N) plane. N is padded to a multiple of ``bn`` (padding rows
    invalid -> NEG_INF); callers wanting a bounded jit cache pad (B, P, N)
    to shape buckets first (PartitionPolicy does).
    """
    B, P, n0, _ = features.shape
    pad = (-n0) % bn
    if pad:
        features = jnp.pad(features, ((0, 0), (0, 0), (0, pad), (0, 0)))
    N = features.shape[2]
    w2 = weights.reshape(1, 8)
    idx, val = pl.pallas_call(
        functools.partial(_joint_select_kernel, N),
        grid=(B, P, N // bn),
        in_specs=[
            pl.BlockSpec((1, 1, bn, 8), lambda i, p, j: (i, p, j, 0)),
            pl.BlockSpec((1, 8), lambda i, p, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, p, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(features, w2)
    flat = idx[:, 0]
    # Padding rows can only win when nothing real is feasible, in which
    # case the score is NEG_INF and callers discard the indices anyway.
    return ((flat // N).astype(jnp.int32), (flat % N).astype(jnp.int32),
            val[:, 0])


# ---------------------------------------------------------------------------
# Sharded node axis: N >= 10^5 fleets across devices
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _sharded_select_fn(mesh, axis: str, bn: int, interpret: bool):
    """Build (and cache) the shard_map'd fused select for one mesh: each
    device scores its node shard with the fused kernel, then a cross-shard
    argmax combine picks the global winner (lowest global index on ties)."""
    from repro import compat

    def local_select(f_local, w):
        # f_local: (B, N/d, 8) on this device
        idx, val = select_best_fused(f_local, w, bn=bn, interpret=interpret)
        shard = jax.lax.axis_index(axis)
        gidx = idx + (shard * f_local.shape[1]).astype(jnp.int32)
        vals = jax.lax.all_gather(val, axis)                   # (d, B)
        gidxs = jax.lax.all_gather(gidx, axis)                 # (d, B)
        best_val = jnp.max(vals, axis=0)                       # (B,)
        # among shards attaining the max, take the lowest global index
        cand = jnp.where(vals == best_val[None, :], gidxs, jnp.iinfo(jnp.int32).max)
        return jnp.min(cand, axis=0).astype(jnp.int32), best_val

    from jax.sharding import PartitionSpec as P

    return jax.jit(compat.shard_map(
        local_select, mesh=mesh,
        in_specs=(P(None, axis, None), P(None)),
        out_specs=(P(None), P(None)),
        check_rep=False))


def select_best_sharded(features, weights, mesh=None, axis: str = "nodes",
                        *, bn: int = 1024, interpret: bool = False):
    """Fused select with the node axis sharded across devices.

    features: (B, N, 8) f32 with N divisible by the mesh's ``axis`` size
    (pad with invalid rows first); returns ((B,) int32, (B,) f32) exactly
    like :func:`select_best_fused`. With ``mesh=None`` builds a 1-D mesh
    over all local devices.
    """
    if mesh is None:
        from jax.sharding import Mesh

        devs = np.array(jax.devices())
        mesh = Mesh(devs, (axis,))
    return _sharded_select_fn(mesh, axis, bn, interpret)(features, weights)
