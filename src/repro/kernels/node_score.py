"""Carbon-aware node scoring (paper Algorithm 1) — Pallas TPU kernel.

The paper's NSA inner loop at fleet scale: for N nodes, fuse the five score
components (Eq. 3) and the feasibility filter into one VMEM pass, emitting
per-node total scores (invalid nodes get -inf). The host (or a tiny jnp
argmax) picks the winner. At 10^5-10^6 nodes this is one HBM read of the
(N, 8) feature matrix — the op is memory-bound and the fusion is the win.

Feature layout (N, 8) float32:
  0 cpu_free_frac, 1 mem_free_frac, 2 load, 3 avg_time_s,
  4 running_tasks, 5 intensity_x_e_est (I * E_est, Eq. 4),
  6 valid (1/0 feasibility), 7 padding
Weights: (8,) = [w_R, w_L, w_P, w_B, w_C, 0, 0, 0].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(f_ref, w_ref, s_ref):
    f = f_ref[...]                                 # (bn, 8)
    w = w_ref[...]                                 # (1, 8)
    s_r = 0.5 * jnp.minimum(f[:, 0], 1.0) + 0.5 * jnp.minimum(f[:, 1], 1.0)
    s_l = 1.0 - f[:, 2]
    s_p = 1.0 / (1.0 + f[:, 3])
    s_b = 1.0 / (1.0 + 2.0 * f[:, 4])
    s_c = 1.0 / (1.0 + f[:, 5])
    total = (w[0, 0] * s_r + w[0, 1] * s_l + w[0, 2] * s_p
             + w[0, 3] * s_b + w[0, 4] * s_c)
    valid = f[:, 6] > 0.5
    s_ref[...] = jnp.where(valid, total, NEG_INF)[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def node_scores(features, weights, *, bn: int = 1024, interpret: bool = False):
    """features: (N, 8) f32; weights: (8,) f32 -> (N,) scores.

    N is padded up to a multiple of bn internally (padding rows invalid).
    """
    n0 = features.shape[0]
    pad = (-n0) % bn
    if pad:
        features = jnp.pad(features, ((0, pad), (0, 0)))
    N = features.shape[0]
    w2 = weights.reshape(1, 8)
    out = pl.pallas_call(
        _kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, 8), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(features, w2)
    return out[:n0, 0]


def select_best(features, weights, *, interpret: bool = False) -> jnp.ndarray:
    """Fused scoring + argmax; returns best node index (int32)."""
    s = node_scores(features, weights, interpret=interpret)
    return jnp.argmax(s).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Batched variant: B pending tasks x N nodes in ONE kernel launch
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def node_scores_batched(features, weights, *, bn: int = 1024,
                        interpret: bool = False):
    """features: (B, N, 8) f32; weights: (8,) f32 -> (B, N) scores.

    The CarbonEdgeEngine hot path: scoring is row-wise with shared weights,
    so B tasks x N nodes flattens to one (B*N, 8) pass through the single
    kernel above — still exactly one pallas_call (and one HBM read of the
    feature tensor) per batch, with no duplicated Eq. 3 math.
    """
    B, N, _ = features.shape
    flat = node_scores(features.reshape(B * N, 8), weights, bn=bn,
                       interpret=interpret)
    return flat.reshape(B, N)


def select_best_batched(features, weights, *, interpret: bool = False):
    """Fused batched scoring + per-task argmax -> (B,) int32 node indices."""
    s = node_scores_batched(features, weights, interpret=interpret)
    return jnp.argmax(s, axis=1).astype(jnp.int32)
