"""Single-token decode attention over a long KV cache — Pallas TPU kernel.

One query token per (batch, head); the KV cache is streamed through VMEM in
bk-sized blocks along the innermost (arbitrary) grid dimension with a
running log-sum-exp. Positions > `pos` (and, with a window, positions
<= pos - window) are masked, so the cache may be over-allocated
(decode_32k / long_500k shapes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, window: Optional[int], softcap: float,
            bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)            # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (1, bk)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = cols <= pos
    if window is not None:
        mask &= cols > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / lsum).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "bk", "interpret"))
def decode_attention(q, k, v, pos, *, window: Optional[int] = None,
                     softcap: float = 0.0, bk: int = 256,
                     interpret: bool = False):
    """q: (B, H, hd); k/v: (B, K, S, hd); pos: scalar int32.

    Returns (B, H, hd). S must be a multiple of bk.
    """
    B, H, hd = q.shape
    K, S = k.shape[1], k.shape[2]
    g = H // K
    nk = S // bk
    scale = hd ** -0.5
    q4 = q[:, :, None, :]                          # (B,H,1,hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               softcap=softcap, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, q4, k, v)
    return out[:, :, 0, :]
