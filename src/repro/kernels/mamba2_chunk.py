"""Mamba2 (SSD) chunk step — Pallas TPU kernel.

One program per (batch, head): computes the intra-chunk quadratic term, the
inter-chunk contribution of the carried state, and the updated state for a
single chunk of length L. The chunk loop itself stays a lax.scan in JAX
(models/ssm.py), calling this kernel per step.

VMEM working set per program: x (L,P), B/C (L,N), scores (L,L), state
(N,P) — with L=256, N=64, P=64 that is ~0.6 MB, comfortably resident. The
(L,L) score matmul and the (L,N)x(L,P) state update run on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _kernel(x_ref, b_ref, c_ref, cum_ref, state_ref, y_ref, newstate_ref):
    x = x_ref[0, 0].astype(jnp.float32)            # (L, P)
    Bm = b_ref[0, 0].astype(jnp.float32)           # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (L, N)
    cum = cum_ref[0, 0].astype(jnp.float32)        # (L, 1) cumsum(dt*A)
    state = state_ref[0, 0].astype(jnp.float32)    # (N, P)

    L = x.shape[0]
    # Intra-chunk: scores[t, s] = (C_t . B_s) * exp(cum_t - cum_s), s <= t.
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (L, L)
    dec = cum - cum.T                                           # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = cols <= rows
    scores = jnp.where(mask, cb * jnp.exp(dec), 0.0)
    y = jax.lax.dot(scores, x)                                  # (L, P)
    # Inter-chunk contribution: C_t exp(cum_t) . state.
    y = y + jax.lax.dot(Cm * jnp.exp(cum), state)
    # State update: exp(last - cum_s) B_s^T x_s + exp(last) * state.
    last = cum[L - 1, 0]
    w_in = jnp.exp(last - cum)                                  # (L, 1)
    s_local = jax.lax.dot_general(Bm * w_in, x, (((0,), (0,)), ((), ())))
    newstate_ref[0, 0] = jnp.exp(last) * state + s_local
    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba2_chunk(xdt, Bh, Ch, cum, state, *, interpret: bool = False):
    """One SSD chunk for all (batch, head) pairs.

    xdt:   (B, H, L, P)  x premultiplied by dt
    Bh/Ch: (B, H, L, N)  input/output projections (head-expanded)
    cum:   (B, H, L)     within-chunk cumsum of dt*A
    state: (B, H, N, P)  carried state (f32)
    Returns (y (B,H,L,P), new_state (B,H,N,P)).
    """
    B, H, L, P = xdt.shape
    N = Bh.shape[-1]
    cum4 = cum[..., None]                          # (B,H,L,1)
    y, new_state = pl.pallas_call(
        _kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), xdt.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xdt, Bh, Ch, cum4, state)
    return y, new_state
