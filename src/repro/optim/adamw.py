"""AdamW + cosine schedule, pure JAX (optax-free)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def abstract_init(abstract_params) -> AdamWState:
    return jax.eval_shape(init, abstract_params)


def apply(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    # Global-norm clip.
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
