"""Deterministic data pipeline: synthetic LM batches + byte-level corpus.

Seeded, host-side numpy generation (no device allocation until the step
consumes the batch); supports the extras every architecture needs
(encoder frames, vision patches, M-RoPE positions).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus: Optional[str] = None     # path to a text file (byte-level LM)


def _extras(cfg: ModelConfig, rng: np.random.Generator, B: int, S: int) -> Dict:
    out = {}
    if cfg.encoder_layers:
        out["encoder_embeds"] = rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model), dtype=np.float32) * 0.02
    if cfg.vision_tokens:
        out["vision_embeds"] = rng.standard_normal(
            (B, cfg.vision_tokens, cfg.d_model), dtype=np.float32) * 0.02
    if cfg.mrope_sections:
        pos = np.broadcast_to(np.arange(S)[None, None, :], (B, 3, S)).copy()
        out["mrope_positions"] = pos.astype(np.int32)
    return out


def synthetic_batches(cfg: ModelConfig, dcfg: DataConfig) -> Iterator[Dict]:
    """Markov-ish synthetic tokens (learnable structure, not uniform noise)."""
    rng = np.random.default_rng(dcfg.seed)
    B = dcfg.global_batch
    S = dcfg.seq_len - cfg.vision_tokens
    V = cfg.vocab_size
    # fixed random bigram table over a small "hot" vocab
    hot = min(V, 512)
    table = rng.integers(0, hot, size=(hot, 8))
    while True:
        toks = np.zeros((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, hot, size=B)
        choice = rng.integers(0, 8, size=(B, S))
        for t in range(S):
            toks[:, t + 1] = table[toks[:, t] % hot, choice[:, t]]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        batch.update(_extras(cfg, rng, B, dcfg.seq_len))
        yield batch


def corpus_batches(cfg: ModelConfig, dcfg: DataConfig) -> Iterator[Dict]:
    """Byte-level LM over a text file (vocab must be >= 256)."""
    data = np.frombuffer(open(dcfg.corpus, "rb").read(), dtype=np.uint8)
    rng = np.random.default_rng(dcfg.seed)
    B = dcfg.global_batch
    S = dcfg.seq_len - cfg.vision_tokens
    while True:
        starts = rng.integers(0, len(data) - S - 1, size=B)
        toks = np.stack([data[s: s + S + 1] for s in starts]).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        batch.update(_extras(cfg, rng, B, dcfg.seq_len))
        yield batch


def make_batches(cfg: ModelConfig, dcfg: DataConfig) -> Iterator[Dict]:
    if dcfg.corpus:
        return corpus_batches(cfg, dcfg)
    return synthetic_batches(cfg, dcfg)
