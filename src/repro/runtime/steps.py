"""Jit-able train / prefill / decode step functions.

These are the functions the launcher jits with in/out shardings and the
dry-run lowers against ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import loss as loss_mod


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    hidden, aux = transformer.forward(cfg, params, batch)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.vision_tokens:
        # Prepended stub vision positions are excluded from the LM loss.
        B = labels.shape[0]
        pad = jnp.zeros((B, cfg.vision_tokens), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        m = jnp.concatenate([jnp.zeros((B, cfg.vision_tokens), jnp.float32),
                             jnp.ones(batch["labels"].shape, jnp.float32)], axis=1)
        mask = m if mask is None else mask * m
    ce = loss_mod.chunked_ce(cfg, params, hidden, labels, mask)
    total = ce + aux
    return total, {"ce": ce, "aux": aux}


def train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    def step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw.apply(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics, "loss": total}

    return step


def prefill_step(cfg: ModelConfig, max_len: int):
    def step(params, batch):
        cache, last_h = transformer.prefill(cfg, params, batch, max_len)
        logits = transformer.unembed(cfg, params, last_h)
        return cache, logits

    return step


def decode_fn(cfg: ModelConfig):
    def step(params, cache, token, pos):
        return transformer.decode_step(cfg, params, cache, token, pos)

    return step


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
