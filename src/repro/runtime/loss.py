"""Chunked cross-entropy: 256k-vocab logits are never fully materialised.

The (B, S, V) logits tensor for command-r at train_4k would be
256 x 4096 x 256000 x 4B ≈ 1 TB global; instead we scan over sequence
chunks, computing (B, chunk, V) logits per step and accumulating the loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import modes, transformer

CHUNK = 512


def _ce(cfg, params, h_chunk, labels_chunk, mask_chunk):
    from repro.sharding.constraints import constrain

    logits = transformer.unembed(cfg, params, h_chunk).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_chunk[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask_chunk
    return jnp.sum(nll), jnp.sum(mask_chunk)


def chunked_ce(cfg: ModelConfig, params, hidden, labels, mask=None):
    """hidden: (B,S,D); labels: (B,S) int32; mask: (B,S) or None."""
    B, S, _ = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(CHUNK, S)
    nb = S // chunk

    def body(carry, i):
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        lbl = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        m = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        s, c = _ce(cfg, params, h, lbl, m)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = modes.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(nb))
    rem = S - nb * chunk
    if rem:
        s, c = _ce(cfg, params, hidden[:, -rem:], labels[:, -rem:], mask[:, -rem:])
        tot, cnt = tot + s, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
