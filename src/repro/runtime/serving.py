"""Serving engine: batched request execution with carbon-aware routing.

The engine owns jitted prefill/decode step functions per model and runs
request batches; the GreenRouter (core/router.py) decides which pod/node a
batch executes on, and the CarbonMonitor bills each step's energy. On this
CPU host the "pods" are simulated domains; the step functions are the same
ones the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel, energy
from repro.core.router import GreenRouter, PodSpec
from repro.models import transformer
from repro.runtime import steps


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    submitted_s: float = 0.0


@dataclass
class Completion:
    uid: int
    tokens: List[int]
    pod: str
    latency_s: float
    carbon_g: float


class ServingEngine:
    """Batched prefill+decode with greedy sampling and carbon accounting."""

    def __init__(self, cfg: ModelConfig, params, router: GreenRouter,
                 max_len: int = 256, batch_size: int = 4):
        self.cfg = cfg
        self.params = params
        self.router = router
        self.max_len = max_len
        self.batch_size = batch_size
        self._prefill = jax.jit(steps.prefill_step(cfg, max_len))
        self._decode = jax.jit(steps.decode_fn(cfg))
        self.queue: List[Request] = []
        self.completions: List[Completion] = []

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def _step_terms(self, kind: str, seq: int, batch: int,
                    chips: int) -> energy.RooflineTerms:
        """Roofline terms for this batch on the routed pod (billing +
        history update — must use that pod's chip count)."""
        flops = 2.0 * self.cfg.active_param_count() * batch * (seq if kind == "prefill" else 1)
        hbm = costmodel.step_hbm_bytes(self.cfg, seq, batch, kind)
        return energy.roofline(flops, hbm, 0.0, chips=chips)

    def run_batch(self, now_hour: float = 0.0) -> List[Completion]:
        """Serve up to batch_size queued requests as one batch.

        ``now_hour`` flows into routing and billing so a time-varying
        intensity provider on the router (TraceProvider/ForecastProvider)
        is sampled at the request time, not at hour 0.
        """
        if not self.queue:
            return []
        batch = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        pod = self.router.route(now_hour=now_hour)
        chips = self.router.pods[pod].chips
        t0 = time.perf_counter()
        cache, logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        carbon = self.router.commit(pod, self._step_terms("prefill", S, B, chips),
                                    hour=now_hour)
        max_new = max(r.max_new_tokens for r in batch)
        out = np.zeros((B, max_new), np.int32)
        tok = steps.greedy_sample(logits)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok, jnp.int32(S + t))
            carbon += self.router.commit(
                pod, self._step_terms("decode", S + t + 1, B, chips),
                hour=now_hour)
            tok = steps.greedy_sample(logits)[:, None]
        dt = time.perf_counter() - t0
        comps = []
        for i, r in enumerate(batch):
            c = Completion(r.uid, out[i, : r.max_new_tokens].tolist(), pod,
                           dt, carbon / B)
            comps.append(c)
            self.completions.append(c)
        return comps

    def run_all(self, now_hour: float = 0.0) -> List[Completion]:
        done = []
        while self.queue:
            done.extend(self.run_batch(now_hour))
        return done

    def report(self) -> Dict:
        return {
            "completed": len(self.completions),
            "carbon_g_total": self.router.monitor.total_carbon_g(),
            "energy_kwh_total": self.router.monitor.total_energy_kwh(),
            "per_region": self.router.monitor.report(),
            "policy": self.router.policy.name,
        }
