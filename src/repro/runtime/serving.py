"""Serving engine: batched request execution with carbon-aware routing.

The engine owns jitted prefill/decode step functions per model and runs
request batches; the GreenRouter (core/router.py) decides which pod/node a
batch executes on, and the CarbonMonitor bills each step's energy. On this
CPU host the "pods" are simulated domains; the step functions are the same
ones the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel, energy
from repro.core.router import GreenRouter
from repro.runtime import steps


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    # None = not yet submitted; 0.0 is a valid (virtual) submission time
    submitted_s: Optional[float] = None


@dataclass
class Completion:
    uid: int
    tokens: List[int]
    pod: str
    wait_s: float                # queue time: submit -> batch start
    service_s: float             # batch start -> this request's last token
    carbon_g: float

    @property
    def latency_s(self) -> float:
        """End-to-end: queue wait plus service (wait used to be dropped and
        every request in a batch reported the identical batch dt)."""
        return self.wait_s + self.service_s


class ServingEngine:
    """Batched prefill+decode with greedy sampling and carbon accounting."""

    def __init__(self, cfg: ModelConfig, params, router: GreenRouter,
                 max_len: int = 256, batch_size: int = 4):
        self.cfg = cfg
        self.params = params
        self.router = router
        self.max_len = max_len
        self.batch_size = batch_size
        self._prefill = jax.jit(steps.prefill_step(cfg, max_len))
        self._decode = jax.jit(steps.decode_fn(cfg))
        self.queue: List[Request] = []
        self.completions: List[Completion] = []

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request, now_s: Optional[float] = None):
        """``now_s`` lets a simulator stamp virtual submission time; the
        default is the wall clock (live serving)."""
        if now_s is not None:
            req.submitted_s = now_s
        elif req.submitted_s is None:
            # keep a caller-stamped submission time (sim task factories
            # pre-stamp virtual seconds; 0.0 is a valid virtual instant)
            req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def _step_terms(self, kind: str, seq: int, batch: int,
                    chips: int) -> energy.RooflineTerms:
        """Roofline terms for this batch on the routed pod (billing +
        history update — must use that pod's chip count)."""
        flops = 2.0 * self.cfg.active_param_count() * batch * (seq if kind == "prefill" else 1)
        hbm = costmodel.step_hbm_bytes(self.cfg, seq, batch, kind)
        return energy.roofline(flops, hbm, 0.0, chips=chips)

    def run_batch(self, now_hour: float = 0.0,
                  now_s: Optional[float] = None) -> List[Completion]:
        """Serve up to batch_size queued requests as one batch.

        ``now_hour`` flows into routing and billing so a time-varying
        intensity provider on the router (TraceProvider/ForecastProvider)
        is sampled at the request time, not at hour 0. ``now_s`` is the
        batch start on the same clock ``submitted_s`` was stamped with
        (wall by default, virtual under the simulator) — each request's
        queue wait is ``now_s - submitted_s``, and its service time runs
        until *its own* last decoded token, so a short request in a long
        batch no longer inherits the whole batch's dt.
        """
        if not self.queue:
            return []
        batch = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        pod = self.router.route(now_hour=now_hour)
        chips = self.router.pods[pod].chips
        t0 = time.perf_counter()
        start_s = t0 if now_s is None else now_s
        cache, logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        carbon = self.router.commit(pod, self._step_terms("prefill", S, B, chips),
                                    hour=now_hour)
        prefill_elapsed = time.perf_counter() - t0
        max_new = max(r.max_new_tokens for r in batch)
        out = np.zeros((B, max_new), np.int32)
        elapsed = np.zeros(max_new)     # service elapsed when token t exists
        tok = steps.greedy_sample(logits)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            elapsed[t] = time.perf_counter() - t0
            if t == max_new - 1:
                # token 0 came from prefill, so max_new tokens need only
                # max_new - 1 decodes; running (and billing) a final
                # decode whose sample is discarded inflated carbon by one
                # step per batch
                break
            logits, cache = self._decode(self.params, cache, tok, jnp.int32(S + t))
            carbon += self.router.commit(
                pod, self._step_terms("decode", S + t + 1, B, chips),
                hour=now_hour)
            tok = steps.greedy_sample(logits)[:, None]
        comps = []
        for i, r in enumerate(batch):
            # a zero-token request's service ends at prefill
            service = (float(elapsed[r.max_new_tokens - 1])
                       if r.max_new_tokens > 0 else prefill_elapsed)
            c = Completion(r.uid, out[i, : r.max_new_tokens].tolist(), pod,
                           wait_s=max(0.0, start_s - r.submitted_s),
                           service_s=service,
                           carbon_g=carbon / B)
            comps.append(c)
            self.completions.append(c)
        return comps

    # -- sim integration -----------------------------------------------------
    def step(self, now_hour: float = 0.0,
             limit: Optional[int] = None) -> List[Completion]:
        """:class:`repro.sim.driver.BatchExecutor` interface: the sim
        driver's executor hook. ``limit`` caps this batch; virtual batch
        start is derived from ``now_hour`` so waits stay on sim time."""
        # hours -> virtual seconds inline: the runtime layer must not
        # depend on repro.sim (the sim drives the runtime, not vice versa)
        now_s = now_hour * 3600.0
        if limit is None:
            return self.run_batch(now_hour, now_s=now_s)
        if limit <= 0:
            return []           # match CarbonEdgeEngine.step(limit=0)
        old, self.batch_size = self.batch_size, limit
        try:
            return self.run_batch(now_hour, now_s=now_s)
        finally:
            self.batch_size = old

    def run_all(self, now_hour: float = 0.0) -> List[Completion]:
        done = []
        while self.queue:
            done.extend(self.run_batch(now_hour))
        return done

    def report(self) -> Dict:
        return {
            "completed": len(self.completions),
            "carbon_g_total": self.router.monitor.total_carbon_g(),
            "energy_kwh_total": self.router.monitor.total_energy_kwh(),
            "per_region": self.router.monitor.report(),
            "policy": self.router.policy.name,
        }
