"""Qwen2-MoE-A2.7B [moe] — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
The 4 shared experts are always-on (fused into one MLP of width 4*1408).
"""
from repro.configs.base import ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        arch_type="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            expert_ff=1408,
            num_shared_experts=4,
            # 60 does not divide the 16-way model axis; pad the expert
            # weight layout to 64 for expert parallelism (router-masked).
            padded_experts=64,
        ),
        qkv_bias=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
