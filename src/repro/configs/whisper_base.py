"""Whisper-base [audio] — encoder-decoder, conv frontend STUB
[arXiv:2212.04356].

6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865. The mel-spectrogram +
conv feature extractor is stubbed per the assignment: input_specs() provides
precomputed frame embeddings (batch, 1500, d_model); we implement the
transformer encoder (6L, bidirectional) and decoder (6L, self + cross attn).
"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        arch_type="audio",
        num_layers=6,               # decoder layers
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        encoder_layers=6,
        encoder_seq=1500,
        cross_attention=True,
        # Whisper uses sinusoidal (encoder) / learned (decoder) positions; we
        # use parameter-free sinusoidal everywhere so decode shapes beyond the
        # original 448-token context stay well-defined (noted in DESIGN.md).
        pos_emb="sinusoidal",
        norm_type="layernorm",
        act="gelu",
        mlp_gated=False,
        qkv_bias=True,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )
