"""The paper's own test models (§IV.A.3) as CNNConfig layer lists.

MobileNetV2 (3.5M params) [CVPR 2018], MobileNetV4-conv-S-like (3.8M)
[ECCV 2024], EfficientNet-B0 (5.3M) [ICML 2019]. These drive the faithful
reproduction benchmarks (Tables II/IV/V, Figs 2/3) and exercise the
partitioner's Eq. 5 cost model exactly as published (Conv2D / Linear /
others).

The layer lists are faithful block-structure expansions (inverted
residuals with expansion factors, stem/head convs, classifier); parameter
counts land at the paper's reported 3.5M / 3.8M / 5.3M within a few
percent, which is what the cost model and carbon accounting consume.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.configs.base import CNNConfig, ConvLayerDef


def _inverted_residual(layers: List[ConvLayerDef], cin: int, cout: int,
                       stride: int, expand: int) -> int:
    mid = cin * expand
    if expand != 1:
        layers.append(ConvLayerDef("conv", cin, mid, 1, 1))      # expand 1x1
    layers.append(ConvLayerDef("dwconv", mid, mid, 3, stride))   # depthwise
    layers.append(ConvLayerDef("conv", mid, cout, 1, 1))         # project 1x1
    return cout


def mobilenet_v2() -> CNNConfig:
    # (expansion, cout, repeats, stride) per the MobileNetV2 paper Table 2.
    spec: Tuple[Tuple[int, int, int, int], ...] = (
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
    )
    layers: List[ConvLayerDef] = [ConvLayerDef("conv", 3, 32, 3, 2)]
    c = 32
    for t, cout, n, s in spec:
        for i in range(n):
            c = _inverted_residual(layers, c, cout, s if i == 0 else 1, t)
    layers.append(ConvLayerDef("conv", c, 1280, 1, 1))
    layers.append(ConvLayerDef("pool", 1280, 1280))
    layers.append(ConvLayerDef("linear", 1280, 1000))
    return CNNConfig("mobilenetv2", tuple(layers), source="CVPR 2018 (Sandler et al.)")


def mobilenet_v4() -> CNNConfig:
    # MobileNetV4-Conv-S-like: fused IB early stages, universal IB later.
    layers: List[ConvLayerDef] = [ConvLayerDef("conv", 3, 32, 3, 2)]
    # Fused stage: conv 3x3 expand + 1x1 project.
    layers.append(ConvLayerDef("conv", 32, 32, 3, 2))
    layers.append(ConvLayerDef("conv", 32, 32, 1, 1))
    layers.append(ConvLayerDef("conv", 32, 96, 3, 2))
    layers.append(ConvLayerDef("conv", 96, 64, 1, 1))
    c = 64
    spec = ((4, 96, 3, 2), (4, 128, 4, 2), (4, 160, 2, 1))
    for t, cout, n, s in spec:
        for i in range(n):
            c = _inverted_residual(layers, c, cout, s if i == 0 else 1, t)
    layers.append(ConvLayerDef("conv", c, 960, 1, 1))
    layers.append(ConvLayerDef("conv", 960, 1280, 1, 1))
    layers.append(ConvLayerDef("pool", 1280, 1280))
    layers.append(ConvLayerDef("linear", 1280, 1000))
    return CNNConfig("mobilenetv4", tuple(layers), source="ECCV 2024 (Qin et al.)")


def efficientnet_b0() -> CNNConfig:
    # (expansion, cout, repeats, stride, kernel) per the EfficientNet paper.
    spec = (
        (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3), (6, 112, 3, 1, 5), (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    )
    layers: List[ConvLayerDef] = [ConvLayerDef("conv", 3, 32, 3, 2)]
    c = 32
    for t, cout, n, s, k in spec:
        for i in range(n):
            mid = c * t
            if t != 1:
                layers.append(ConvLayerDef("conv", c, mid, 1, 1))
            layers.append(ConvLayerDef("dwconv", mid, mid, k, s if i == 0 else 1))
            # Squeeze-excite block (cost-model "others": params_count).
            layers.append(ConvLayerDef("se", mid, max(1, c // 4)))
            layers.append(ConvLayerDef("conv", mid, cout, 1, 1))
            c = cout
    layers.append(ConvLayerDef("conv", c, 1280, 1, 1))
    layers.append(ConvLayerDef("pool", 1280, 1280))
    layers.append(ConvLayerDef("linear", 1280, 1000))
    return CNNConfig("efficientnet-b0", tuple(layers), source="ICML 2019 (Tan & Le)")


CNN_MODELS = {
    "mobilenetv2": mobilenet_v2,
    "mobilenetv4": mobilenet_v4,
    "efficientnet-b0": efficientnet_b0,
}


def get_cnn_config(name: str) -> CNNConfig:
    return CNN_MODELS[name]()
