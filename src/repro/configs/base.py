"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's
own CNN test models are ``CNNConfig``. Configs are frozen dataclasses so
they are hashable and usable as jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    expert_ff: int
    # qwen2-moe style always-on shared experts (implemented as one fused MLP
    # of width num_shared_experts * expert_ff).
    num_shared_experts: int = 0
    # arctic style dense residual MLP running in parallel with the MoE.
    dense_residual_ff: int = 0
    router_aux_weight: float = 0.01
    router_jitter: float = 0.0
    # Expert-parallel padding: expert weight arrays are padded to this count
    # so the expert axis divides the `model` mesh axis (padded experts are
    # router-masked and unreachable — pure deployment layout, no semantic
    # change). 0 = num_experts.
    padded_experts: int = 0

    @property
    def e_pad(self) -> int:
        return max(self.num_experts, self.padded_experts)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    state_dim: int = 64          # N
    head_dim: int = 64           # P
    expand: int = 2              # inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256
    num_groups: int = 1          # B/C groups (GVA)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (mLSTM + sLSTM cells)."""

    num_heads: int = 4
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0


@dataclass(frozen=True)
class LayerDef:
    """One layer in the stack pattern.

    kind: "attn" | "mamba2" | "mlstm" | "slstm"
    window: sliding-window size for attention layers (None = global/full).
    """

    kind: str = "attn"
    window: Optional[int] = None


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # Layer stack: `pattern` repeated `repeats` times followed by `suffix`.
    # len(pattern) * repeats + len(suffix) must equal num_layers.
    pattern: Tuple[LayerDef, ...] = (LayerDef("attn"),)
    repeats: int = 0             # 0 -> num_layers (pattern must be length 1)
    suffix: Tuple[LayerDef, ...] = ()

    # Attention details.
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    pos_emb: str = "rope"        # rope | learned | none
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (sums to head_dim/2)
    max_position: int = 1 << 20  # for learned pos-emb sizing

    # Sub-blocks.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # Encoder-decoder (whisper): encoder consumes stub frame embeddings.
    encoder_layers: int = 0
    encoder_seq: int = 0
    cross_attention: bool = False

    # VLM: stub patch embeddings prepended to the token sequence.
    vision_tokens: int = 0

    # Norm / activation / misc.
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    mlp_gated: bool = True       # SwiGLU-style gated MLP
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # Numerics / runtime.
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True

    # Source citation for the assigned-architecture pool.
    source: str = ""

    def __post_init__(self):
        # Resolve head_dim.
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        # Resolve repeats.
        if self.repeats == 0:
            if len(self.pattern) != 1:
                raise ValueError(f"{self.name}: repeats=0 needs len(pattern)==1")
            object.__setattr__(self, "repeats", self.num_layers - len(self.suffix))
        n = len(self.pattern) * self.repeats + len(self.suffix)
        if n != self.num_layers:
            raise ValueError(
                f"{self.name}: pattern*repeats+suffix = {n} != num_layers "
                f"{self.num_layers}"
            )
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads {self.num_heads} not divisible "
                             f"by kv heads {self.num_kv_heads}")
        if self.mrope_sections and sum(self.mrope_sections) != self.head_dim // 2:
            raise ValueError(f"{self.name}: mrope sections must sum to head_dim/2")

    # -- derived ----------------------------------------------------------
    @property
    def layer_defs(self) -> Tuple[LayerDef, ...]:
        return self.pattern * self.repeats + self.suffix

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_attention_window(self, window: int) -> "ModelConfig":
        """SWA override used by the long_500k variant for full-attention archs."""

        def w(ld: LayerDef) -> LayerDef:
            if ld.kind != "attn":
                return ld
            if ld.window is not None and ld.window <= window:
                return ld
            return dataclasses.replace(ld, window=window)

        return dataclasses.replace(
            self,
            pattern=tuple(w(ld) for ld in self.pattern),
            suffix=tuple(w(ld) for ld in self.suffix),
        )

    # -- parameter counting (analytic; used by partitioner & roofline) ----
    def param_count(self) -> int:
        from repro.core.costmodel import model_param_count

        return model_param_count(self)

    def active_param_count(self) -> int:
        from repro.core.costmodel import model_active_param_count

        return model_active_param_count(self)


# ---------------------------------------------------------------------------
# CNN config (the paper's own test models)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLayerDef:
    """One CNN layer; drives both the model and the paper's Eq.5 cost model.

    kind: conv | dwconv | linear | pool | act | bn
    """

    kind: str
    cin: int = 0
    cout: int = 0
    k: int = 1
    stride: int = 1


@dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: Tuple[ConvLayerDef, ...]
    num_classes: int = 1000
    input_size: int = 224
    input_channels: int = 3
    source: str = ""

    def with_overrides(self, **kw) -> "CNNConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
