from repro.configs.base import (
    CNNConfig,
    ConvLayerDef,
    INPUT_SHAPES,
    InputShape,
    LayerDef,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
)

__all__ = [
    "CNNConfig",
    "ConvLayerDef",
    "INPUT_SHAPES",
    "InputShape",
    "LayerDef",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "XLSTMConfig",
]
