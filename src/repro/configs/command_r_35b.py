"""Command-R 35B [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        arch_type="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        head_dim=128,
        qkv_bias=False,
        norm_type="layernorm",
        tie_embeddings=True,
        rope_theta=8e6,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
