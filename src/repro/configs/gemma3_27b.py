"""Gemma3-27B [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
Pattern: 10 x (5 local(window=1024) + 1 global) + 2 local = 62 layers.
Gemma3 uses qk-norm and logit softcapping.
"""
from repro.configs.base import LayerDef, ModelConfig

_LOCAL = LayerDef("attn", window=1024)
_GLOBAL = LayerDef("attn", window=None)


def make_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        arch_type="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        head_dim=128,
        pattern=tuple([_LOCAL] * 5 + [_GLOBAL]),
        repeats=10,
        suffix=(_LOCAL, _LOCAL),
        qk_norm=True,
        attn_logit_softcap=50.0,
        act="gelu",
        rope_theta=1e6,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    )
