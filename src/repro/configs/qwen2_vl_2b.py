"""Qwen2-VL-2B [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The ViT vision
encoder + projector is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (batch, 256, d_model) which the model prepends
to the token sequence. M-RoPE uses (temporal, height, width) sections of
(16, 24, 24) over head_dim/2 = 64.
"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        head_dim=128,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        vision_tokens=256,
        rope_theta=1e6,
        tie_embeddings=True,
        source="arXiv:2409.12191",
    )
