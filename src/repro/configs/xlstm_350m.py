"""xLSTM-350M [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304. d_ff=0: xLSTM blocks
carry their own internal up/down projections (mLSTM 2x, sLSTM 4/3x ffn),
there is no separate transformer FFN. Pattern follows the paper's
xLSTM[7:1] ratio: 3 x (7 mLSTM + 1 sLSTM) = 24 layers.
"""
from repro.configs.base import LayerDef, ModelConfig, XLSTMConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        arch_type="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        pattern=tuple([LayerDef("mlstm")] * 7 + [LayerDef("slstm")]),
        repeats=3,
        xlstm=XLSTMConfig(num_heads=4),
        pos_emb="none",           # xLSTM needs no positional embedding
        mlp_gated=False,
        tie_embeddings=True,
        source="arXiv:2405.04517",
    )
