"""Snowflake Arctic 480B [moe] — 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic's dense-MoE hybrid: every layer has a small dense residual MLP in
parallel with the 128-expert top-2 MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        arch_type="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        head_dim=128,
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            expert_ff=4864,
            dense_residual_ff=4864,
        ),
        source="hf:Snowflake/snowflake-arctic-base",
    )
