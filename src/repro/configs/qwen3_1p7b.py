"""Qwen3-1.7B [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        arch_type="dense",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=6144,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
        qkv_bias=False,
        rope_theta=1e6,
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B",
    )
