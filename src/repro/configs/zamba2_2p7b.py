"""Zamba2-2.7B [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Pattern: 9 x (5 Mamba2 + 1 attention) = 54 layers — approximates Zamba2's
periodic shared-attention placement with the exact layer count.
"""
from repro.configs.base import LayerDef, ModelConfig, SSMConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        pattern=tuple([LayerDef("mamba2")] * 5 + [LayerDef("attn")]),
        repeats=9,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
        source="arXiv:2411.15242",
    )
