"""Architecture registry: --arch <id> resolution + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.configs.base import (
    LayerDef,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
)

from repro.configs import (  # noqa: E402
    arctic_480b,
    command_r_35b,
    gemma3_27b,
    qwen1p5_4b,
    qwen2_moe_a2p7b,
    qwen2_vl_2b,
    qwen3_1p7b,
    whisper_base,
    xlstm_350m,
    zamba2_2p7b,
)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {
    "xlstm-350m": xlstm_350m.make_config,
    "arctic-480b": arctic_480b.make_config,
    "zamba2-2.7b": zamba2_2p7b.make_config,
    "command-r-35b": command_r_35b.make_config,
    "qwen1.5-4b": qwen1p5_4b.make_config,
    "gemma3-27b": gemma3_27b.make_config,
    "whisper-base": whisper_base.make_config,
    "qwen2-moe-a2.7b": qwen2_moe_a2p7b.make_config,
    "qwen3-1.7b": qwen3_1p7b.make_config,
    "qwen2-vl-2b": qwen2_vl_2b.make_config,
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]()


def list_archs():
    return list(ARCH_IDS)


# ---------------------------------------------------------------------------
# Reduced variants for CPU smoke tests: <=2-ish layers (one of each block
# kind in the family), d_model<=512, <=4 experts, small vocab.
# ---------------------------------------------------------------------------


def reduced_config(arch: str) -> ModelConfig:
    cfg = get_config(arch)
    # Keep one instance of every distinct layer kind (max 2 layers).
    kinds = []
    pat = []
    for ld in cfg.layer_defs:
        key = (ld.kind, ld.window is None)
        if key not in kinds:
            kinds.append(key)
            pat.append(LayerDef(ld.kind, window=64 if ld.window else None))
        if len(pat) == 2:
            break
    if len(pat) == 1:
        pat = pat * 2  # always 2 layers
    d_model = 256
    num_heads = 4
    num_kv = max(1, num_heads // cfg.q_per_kv) if cfg.num_kv_heads < cfg.num_heads else num_heads
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            num_experts=4,
            top_k=min(2, cfg.moe.top_k),
            expert_ff=128,
            num_shared_experts=min(2, cfg.moe.num_shared_experts),
            dense_residual_ff=128 if cfg.moe.dense_residual_ff else 0,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4,
                        chunk_size=32)
    xl = None
    if cfg.xlstm is not None:
        xl = XLSTMConfig(num_heads=2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=len(pat),
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=64,
        d_ff=0 if cfg.d_ff == 0 else 512,
        vocab_size=512,
        pattern=tuple(pat),
        repeats=1,
        suffix=(),
        moe=moe,
        ssm=ssm,
        xlstm=xl,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=32 if cfg.encoder_seq else 0,
        vision_tokens=16 if cfg.vision_tokens else 0,
        mrope_sections=(8, 12, 12) if cfg.mrope_sections else (),
        max_position=1 << 14,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
