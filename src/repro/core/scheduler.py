"""Carbon-Aware Scheduling Algorithm (paper §III.C–D, Algorithm 1).

S_total = w_R*S_R + w_L*S_L + w_P*S_P + w_B*S_B + w_C*S_C         (Eq. 3)
S_C     = 1 / (1 + I_carbon * E_est),  E_est = P*T_avg/3.6e6      (Eq. 4)

Three operational modes (Table I) plus a continuous weight-sweep
interpolation used by Fig. 3.

The scheduling *engines* live in core/policy.py + core/api.py (DESIGN.md):
this module keeps the Eq. 3/4 component math (``scores``/``vector_scores``,
which back the scalar oracle and the vectorized/Pallas policies) plus thin
deprecation shims for the seed's entry points (``select_node``,
``run_workload``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.cluster import EdgeCluster, NodeState


@dataclass(frozen=True)
class Weights:
    w_r: float
    w_l: float
    w_p: float
    w_b: float
    w_c: float

    def as_array(self) -> np.ndarray:
        return np.array([self.w_r, self.w_l, self.w_p, self.w_b, self.w_c])


# Paper Table I.
MODES: Dict[str, Weights] = {
    "performance": Weights(0.25, 0.25, 0.30, 0.15, 0.05),
    "green": Weights(0.15, 0.15, 0.10, 0.10, 0.50),
    "balanced": Weights(0.20, 0.20, 0.15, 0.15, 0.30),
}


def sweep_weights(w_c: float) -> Weights:
    """Fig. 3 interpolation: carbon weight w_c, the rest scaled from the
    Performance-mode ratios (normalised by that mode's non-carbon sum, so
    w_c == MODES["performance"].w_c reproduces the mode exactly)."""
    base = MODES["performance"]
    non_carbon = base.w_r + base.w_l + base.w_p + base.w_b
    s = (1.0 - w_c) / non_carbon
    return Weights(base.w_r * s, base.w_l * s, base.w_p * s, base.w_b * s, w_c)


@dataclass(frozen=True)
class Task:
    cpu: float = 0.1
    mem_mb: float = 64.0
    base_latency_ms: float = 250.0


# ---------------------------------------------------------------------------
# Score components (Algorithm 1 lines 7-11)
# ---------------------------------------------------------------------------


def resource_score(st: NodeState, task: Task) -> float:
    free_cpu = st.spec.cpu * (1.0 - st.load)
    free_mem = st.spec.mem_mb - st.mem_used_mb
    s_cpu = min(1.0, free_cpu / task.cpu) if task.cpu > 0 else 1.0
    s_mem = min(1.0, free_mem / task.mem_mb) if task.mem_mb > 0 else 1.0
    return 0.5 * s_cpu + 0.5 * s_mem


def scores(st: NodeState, task: Task, host_power_w: float,
           intensity: Optional[float] = None) -> np.ndarray:
    """Eq. 3 components. ``intensity`` comes from a CarbonIntensityProvider
    (core/api.py); None falls back to the node's static regional value."""
    if intensity is None:
        intensity = st.spec.carbon_intensity
    s_r = resource_score(st, task)
    s_l = 1.0 - st.load
    s_p = 1.0 / (1.0 + st.avg_time_ms / 1000.0)
    s_b = 1.0 / (1.0 + st.running * 2.0)
    e_est = st.power_w(host_power_w) * st.avg_time_ms / 3.6e6  # Eq. 4 units
    s_c = 1.0 / (1.0 + intensity * e_est)
    return np.array([s_r, s_l, s_p, s_b, s_c])


def has_sufficient_resources(st: NodeState, task: Task) -> bool:
    return (st.spec.cpu * (1.0 - st.load) >= task.cpu
            and st.spec.mem_mb - st.mem_used_mb >= task.mem_mb)


# Algorithm 1 line 3 load cut-off — the single definition every scheduling
# path (scalar oracle, featurize, deferral planning) filters against.
LOAD_THRESHOLD = 0.8


def node_feasible(st: NodeState, task: Task) -> bool:
    """Algorithm 1 lines 3-5 sans the latency filter (which is a policy
    parameter): overload cut-off plus resource sufficiency."""
    return st.load <= LOAD_THRESHOLD and has_sufficient_resources(st, task)


def select_node(cluster: EdgeCluster, task: Task, weights: Weights,
                latency_threshold_ms: float = 5000.0) -> Optional[str]:
    """Algorithm 1: Carbon-Aware Node Selection.

    Deprecated shim — the loop now lives in
    :class:`repro.core.policy.WeightedScoringPolicy` (the parity oracle);
    batched scheduling goes through :class:`repro.core.api.CarbonEdgeEngine`.
    """
    from repro.core.policy import WeightedScoringPolicy

    return WeightedScoringPolicy(latency_threshold_ms).select(
        cluster, task, weights)


def score_table(cluster: EdgeCluster, task: Task) -> Dict[str, np.ndarray]:
    return {name: scores(st, task, cluster.host_power_w)
            for name, st in cluster.nodes.items()}


# ---------------------------------------------------------------------------
# Vectorised scorer (fleet scale) — oracle for kernels/node_score.py
# ---------------------------------------------------------------------------


def vector_scores(features: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """features: (N, 6) = [cpu_free_frac, mem_free_frac, load, avg_time_s,
    running, intensity_x_e_est]; returns (N,) total scores.

    Same math as `scores` with task-sufficiency folded into features.
    """
    s_r = 0.5 * np.minimum(1.0, features[:, 0]) + 0.5 * np.minimum(1.0, features[:, 1])
    s_l = 1.0 - features[:, 2]
    s_p = 1.0 / (1.0 + features[:, 3])
    s_b = 1.0 / (1.0 + features[:, 4] * 2.0)
    s_c = 1.0 / (1.0 + features[:, 5])
    comp = np.stack([s_r, s_l, s_p, s_b, s_c], axis=-1)
    return comp @ weights


def vector_select(features: np.ndarray, weights: np.ndarray,
                  valid: np.ndarray) -> int:
    total = np.where(valid, vector_scores(features, weights), -np.inf)
    return int(np.argmax(total))


# ---------------------------------------------------------------------------
# Driver: run a workload through the scheduler (benchmarks use this)
# ---------------------------------------------------------------------------


def run_workload(cluster: EdgeCluster, task: Task, weights: Weights,
                 iterations: int = 50, policy=None) -> Dict:
    """50-inference workload (paper §IV.A.4).

    Deprecated shim — delegates to :class:`repro.core.api.CarbonEdgeEngine`,
    whose default VectorizedPolicy scores the whole batch against all nodes
    in one call (the Pallas kernel on TPU). Pass
    ``policy=WeightedScoringPolicy()`` to force the scalar oracle.
    """
    from repro.core.api import CarbonEdgeEngine

    engine = CarbonEdgeEngine(cluster, weights=weights, policy=policy)
    rep = engine.run(task=task, iterations=iterations)
    return {"totals": rep["totals"], "distribution": rep["distribution"]}
