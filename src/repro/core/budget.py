"""Multi-tenant carbon budgets — deprecated shim over ``repro.tenancy``.

The real subsystem lives in :mod:`repro.tenancy` (DESIGN.md §7):
:class:`~repro.tenancy.TenantPolicy` expresses what this module's
``BudgetedRouter`` did by swapping router weights — budget-pressure mode
escalation, admission control and a greenest-placement fallback — as a
composable, batched policy wrapper the engine and the closed-loop sim
share. ``BudgetedRouter`` survives as a thin, deprecation-warning shim
whose decisions are produced by that policy (the parity test in
tests/test_tenancy.py pins them bit-exactly to the original semantics).

The shim also fixes the original's period-rollover accounting bug: with a
finite ``period_hours``, escalation thresholds are evaluated against the
*current* period's spend only (``TenantRegistry.roll``), not the lifetime
total.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core import energy
from repro.core.energy import RooflineTerms
from repro.core.router import GreenRouter
from repro.core.scheduler import Task
from repro.tenancy import (ADMIT, MODE_ORDER, TenantPolicy, TenantRegistry,
                           TenantSpec, TenantTask)

# Budget-pressure escalation thresholds (fraction of allowance spent) —
# re-exported for callers that imported the ladder from here; the live
# definition is repro.tenancy.spec.ESCALATION_BOUNDS.
_ESCALATION = ((0.5, "performance"), (0.8, "balanced"), (1.01, "green"))


class TenantBudget:
    """Per-tenant budget view over a :class:`TenantRegistry` slot.

    Keeps the original dataclass's *read* API, with ``spent_g`` also
    writable (tests and operators poke it directly); the counters are
    read-only views and the state lives in the registry's vectorized
    columns. Construct via ``BudgetedRouter.register_tenant``, not
    directly.
    """

    def __init__(self, registry: TenantRegistry, tenant: str):
        self._registry = registry
        self._i = registry.index[tenant]
        self.tenant = tenant

    @property
    def allowance_g(self) -> float:
        return float(self._registry.allowance_g[self._i])

    @property
    def spent_g(self) -> float:
        return float(self._registry.spent_g[self._i])

    @spent_g.setter
    def spent_g(self, value: float) -> None:
        self._registry.spent_g[self._i] = value

    @property
    def admitted(self) -> int:
        return int(self._registry.admitted[self._i])

    @property
    def denied(self) -> int:
        return int(self._registry.rejected[self._i])

    @property
    def remaining_g(self) -> float:
        return max(self.allowance_g - self.spent_g, 0.0)

    @property
    def utilisation(self) -> float:
        return (self.spent_g / self.allowance_g if self.allowance_g
                else 1.0)


@dataclass
class AdmissionResult:
    admitted: bool
    pod: Optional[str] = None
    mode: str = "green"
    expected_carbon_g: float = 0.0
    reason: str = ""


class BudgetedRouter:
    """GreenRouter + per-tenant carbon accounting and admission control.

    .. deprecated:: use :class:`repro.tenancy.TenantPolicy` with a
       :class:`~repro.core.api.CarbonEdgeEngine` (or any router) — this
       shim forwards every decision to that policy.
    """

    def __init__(self, router: GreenRouter):
        warnings.warn(
            "BudgetedRouter is deprecated: wrap your scheduling policy in "
            "repro.tenancy.TenantPolicy instead (DESIGN.md §7)",
            DeprecationWarning, stacklevel=2)
        self.router = router
        self.registry = TenantRegistry()
        self.tenants: Dict[str, TenantBudget] = {}
        self._terms: Optional[RooflineTerms] = None
        self.policy = TenantPolicy(inner=router.policy,
                                   registry=self.registry,
                                   energy_model=self._roofline_energy)

    def register_tenant(self, tenant: str, allowance_g: float,
                        period_hours: float = float("inf")):
        self.registry.register(TenantSpec(
            tenant, allowance_g=allowance_g, period_hours=period_hours,
            mode="performance", defer_over_reject=False))
        self.tenants[tenant] = TenantBudget(self.registry, tenant)

    # -- the original's expected-carbon model -------------------------------
    def _roofline_energy(self, cluster, tasks, names) -> np.ndarray:
        """Step energy per pod from the admit() call's roofline terms —
        node-dependent (chips x chip power), shape (B, N)."""
        t = self._terms
        if t is None:
            return np.zeros((len(tasks), len(names)))
        e = np.array([energy.step_energy_kwh(t, self.router.pods[n].chips,
                                             self.router.pods[n].chip_power_w)
                      for n in names])
        return np.broadcast_to(e, (len(tasks), e.size))

    def _expected_carbon(self, pod_name: str, terms: RooflineTerms) -> float:
        pod = self.router.pods[pod_name]
        e = energy.step_energy_kwh(terms, pod.chips, pod.chip_power_w)
        return energy.carbon_g(e, pod.carbon_intensity)

    def admit(self, tenant: str, terms: RooflineTerms,
              task: Optional[Task] = None,
              hour: float = 0.0) -> AdmissionResult:
        self.tenants[tenant]                 # unknown tenant: KeyError
        self._terms = terms
        t = task or Task(cpu=0.0, mem_mb=0.0)
        tt = TenantTask(cpu=t.cpu, mem_mb=t.mem_mb,
                        base_latency_ms=t.base_latency_ms, tenant=tenant)
        plan = self.policy.plan(self.router.cluster, [tt],
                                provider=self.router.provider, now_hour=hour)
        mode = (MODE_ORDER[plan.modes[0]] if plan.modes[0] >= 0
                else "green")
        if plan.actions[0] != ADMIT:
            return AdmissionResult(False, None, mode,
                                   float(plan.expected_g[0]),
                                   "carbon budget exhausted")
        choices = self.policy.select_admitted(
            self.router.cluster, [tt], plan, self.router.weights,
            provider=self.router.provider, now_hour=hour)
        pod = choices[0]
        if pod is None:
            raise RuntimeError("no feasible pod")
        return AdmissionResult(True, pod, mode,
                               self._expected_carbon(pod, terms))

    def commit(self, tenant: str, pod: str, terms: RooflineTerms,
               hour: float = 0.0) -> float:
        carbon = self.router.commit(pod, terms, hour=hour)
        self.policy.charge(np.array([self.registry.index[tenant]]),
                           np.array([carbon]), now_hour=hour)
        return carbon

    def report(self) -> Dict[str, Dict[str, float]]:
        return {t: {"allowance_g": b.allowance_g, "spent_g": b.spent_g,
                    "remaining_g": b.remaining_g, "admitted": b.admitted,
                    "denied": b.denied, "utilisation": b.utilisation}
                for t, b in self.tenants.items()}
