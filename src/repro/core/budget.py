"""Multi-tenant carbon budgets — paper §V future work
("multi-tenant optimization with carbon budgets").

Each tenant holds a periodic carbon allowance; the BudgetedRouter admits a
request only if the tenant's remaining budget covers the cheapest feasible
placement's expected emissions, charges actual emissions on commit, and
escalates a tenant's effective mode (performance -> balanced -> green) as
its budget depletes, so heavy users are pushed toward low-carbon placements
before being throttled.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.energy import RooflineTerms
from repro.core.router import GreenRouter
from repro.core.scheduler import MODES, Task


@dataclass
class TenantBudget:
    tenant: str
    allowance_g: float                   # per accounting period
    spent_g: float = 0.0
    denied: int = 0
    admitted: int = 0

    @property
    def remaining_g(self) -> float:
        return max(self.allowance_g - self.spent_g, 0.0)

    @property
    def utilisation(self) -> float:
        return self.spent_g / self.allowance_g if self.allowance_g else 1.0


# Budget-pressure escalation thresholds (fraction of allowance spent).
_ESCALATION = ((0.5, "performance"), (0.8, "balanced"), (1.01, "green"))


@dataclass
class AdmissionResult:
    admitted: bool
    pod: Optional[str] = None
    mode: str = "green"
    expected_carbon_g: float = 0.0
    reason: str = ""


class BudgetedRouter:
    """GreenRouter + per-tenant carbon accounting and admission control."""

    def __init__(self, router: GreenRouter):
        self.router = router
        self.tenants: Dict[str, TenantBudget] = {}

    def register_tenant(self, tenant: str, allowance_g: float):
        self.tenants[tenant] = TenantBudget(tenant, allowance_g)

    def _mode_for(self, b: TenantBudget) -> str:
        for frac, mode in _ESCALATION:
            if b.utilisation < frac:
                return mode
        return "green"

    def _expected_carbon(self, pod_name: str, terms: RooflineTerms) -> float:
        pod = self.router.pods[pod_name]
        from repro.core import energy

        e = energy.step_energy_kwh(terms, pod.chips, pod.chip_power_w)
        return energy.carbon_g(e, pod.carbon_intensity)

    def admit(self, tenant: str, terms: RooflineTerms,
              task: Optional[Task] = None) -> AdmissionResult:
        b = self.tenants[tenant]
        mode = self._mode_for(b)
        prev = self.router.weights
        self.router.weights = MODES[mode]
        try:
            pod = self.router.route(task)
        finally:
            self.router.weights = prev
        expected = self._expected_carbon(pod, terms)
        if expected > b.remaining_g:
            # try the absolute greenest feasible pod before denying
            greenest = min(self.router.pods.values(),
                           key=lambda p: p.carbon_intensity)
            expected_g = self._expected_carbon(greenest.name, terms)
            if expected_g > b.remaining_g:
                b.denied += 1
                return AdmissionResult(False, None, mode, expected_g,
                                       "carbon budget exhausted")
            pod, expected = greenest.name, expected_g
        b.admitted += 1
        return AdmissionResult(True, pod, mode, expected)

    def commit(self, tenant: str, pod: str, terms: RooflineTerms) -> float:
        carbon = self.router.commit(pod, terms)
        self.tenants[tenant].spent_g += carbon
        return carbon

    def report(self) -> Dict[str, Dict[str, float]]:
        return {t: {"allowance_g": b.allowance_g, "spent_g": b.spent_g,
                    "remaining_g": b.remaining_g, "admitted": b.admitted,
                    "denied": b.denied, "utilisation": b.utilisation}
                for t, b in self.tenants.items()}
