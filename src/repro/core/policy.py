"""Scheduling policies (DESIGN.md §1.2): one scoring rule, three engines.

``featurize`` is the single source of the (N, 8) feature-matrix layout the
Pallas ``node_score`` kernel, the numpy scorer, and the scalar oracle all
share — the paper's Eq. 3/4 components are computed from these columns and
nowhere else:

  0 cpu_free_frac   free_cpu / task.cpu        (min(.,1) -> half of S_R)
  1 mem_free_frac   free_mem / task.mem_mb     (min(.,1) -> half of S_R)
  2 load            -> S_L = 1 - load
  3 avg_time_s      -> S_P = 1 / (1 + t)
  4 running         -> S_B = 1 / (1 + 2r)
  5 intensity*E_est -> S_C = 1 / (1 + I*E)     (Eq. 4)
  6 valid           feasibility filter (Algorithm 1 lines 3-5)
  7 padding

Policies:

- :class:`WeightedScoringPolicy` — the scalar Python loop (Algorithm 1
  verbatim). Survives as the parity oracle.
- :class:`VectorizedPolicy` — batched (B, N) scoring in one call; numpy on
  CPU hosts, the Pallas ``node_score`` kernel on TPU. The engine default.
- :class:`TemporalPolicy` — deferral as a (slot x node) grid where the
  Eq. 4 column is time-indexed through the intensity provider; min-carbon
  placement with the weighted score as tie-breaker.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import (CarbonIntensityProvider, StaticProvider)
from repro.core.cluster import EdgeCluster
from repro.core.scheduler import (LOAD_THRESHOLD, Task, Weights,
                                  node_feasible, scores, vector_scores)

# Scores below this are "invalid" sentinels (the Pallas kernel emits -1e30,
# the numpy path -inf).
_NEG_SENTINEL = -1e29

FEATURE_DIM = 8
(COL_CPU_FREE, COL_MEM_FREE, COL_LOAD, COL_TIME_S,
 COL_RUNNING, COL_IXE, COL_VALID, COL_PAD) = range(FEATURE_DIM)


def featurize(cluster: EdgeCluster, tasks: Sequence[Task],
              provider: Optional[CarbonIntensityProvider] = None,
              now_hour: float = 0.0,
              latency_threshold_ms: float = 5000.0,
              dtype=np.float64) -> Tuple[np.ndarray, List[str]]:
    """Extract the (B, N, 8) feature tensor for B tasks against N nodes.

    Grid intensity is read exclusively through ``provider`` (defaulting to
    the cluster's static regional values). Returns (features, node_names)
    with node order matching the cluster's insertion order, so an argmax
    over scores indexes ``node_names`` directly.
    """
    names = list(cluster.nodes)
    B, N = len(tasks), len(names)
    # Only the resource columns depend on the task, so the task dimension is
    # pure numpy broadcasting — the Python cost of a batched step is O(N+B),
    # not O(N*B).
    task_cpu = np.array([t.cpu for t in tasks], dtype)
    task_mem = np.array([t.mem_mb for t in tasks], dtype)
    F = np.zeros((B, N, FEATURE_DIM), dtype)
    for j, name in enumerate(names):
        st = cluster.nodes[name]
        free_cpu = st.spec.cpu * (1.0 - st.load)
        free_mem = st.spec.mem_mb - st.mem_used_mb
        node_ok = (st.load <= LOAD_THRESHOLD
                   and st.avg_time_ms <= latency_threshold_ms)
        feasible = node_ok & (free_cpu >= task_cpu) & (free_mem >= task_mem)
        # Query the provider only when some task can actually use the node
        # (like the scalar oracle, which filters before reading intensity):
        # a masked column's Eq. 4 value is irrelevant, and a
        # partial-coverage provider must not fail on unusable nodes.
        # No provider => the node's static regional value, without building
        # a throwaway StaticProvider per call (this is the hot path).
        if not feasible.any():
            intensity = 0.0
        elif provider is not None:
            intensity = provider.intensity(name, now_hour)
        else:
            intensity = st.spec.carbon_intensity
        e_est = st.power_w(cluster.host_power_w) * st.avg_time_ms / 3.6e6
        cpu_frac = np.ones(B, dtype)
        np.divide(free_cpu, task_cpu, out=cpu_frac, where=task_cpu > 0)
        mem_frac = np.ones(B, dtype)
        np.divide(free_mem, task_mem, out=mem_frac, where=task_mem > 0)
        F[:, j, COL_CPU_FREE] = cpu_frac
        F[:, j, COL_MEM_FREE] = mem_frac
        F[:, j, COL_LOAD] = st.load
        F[:, j, COL_TIME_S] = st.avg_time_ms / 1000.0
        F[:, j, COL_RUNNING] = st.running
        # masked entries carry 0, keeping each batch row independent of its
        # batch-mates (a row equals featurizing that task alone)
        F[:, j, COL_IXE] = np.where(feasible, intensity * e_est, 0.0)
        F[:, j, COL_VALID] = feasible.astype(dtype)
    return F, names


# ---------------------------------------------------------------------------
# Scalar oracle (Algorithm 1 verbatim)
# ---------------------------------------------------------------------------


class WeightedScoringPolicy:
    """Python-loop NSA (paper Algorithm 1) — the parity oracle.

    Identical math to the seed's ``select_node``, with intensity read
    through the provider instead of ``NodeSpec.carbon_intensity``.
    """

    name = "scalar"

    def __init__(self, latency_threshold_ms: float = 5000.0):
        self.latency_threshold_ms = latency_threshold_ms

    def select(self, cluster: EdgeCluster, task: Task, weights: Weights,
               provider: Optional[CarbonIntensityProvider] = None,
               now_hour: float = 0.0) -> Optional[str]:
        best_score, best = 0.0, None
        for name, st in cluster.nodes.items():
            if st.avg_time_ms > self.latency_threshold_ms:
                continue
            if not node_feasible(st, task):
                continue
            comp = scores(st, task, cluster.host_power_w,
                          intensity=provider.intensity(name, now_hour)
                          if provider is not None else None)
            s = float(weights.as_array() @ comp)
            if s > best_score:
                best_score, best = s, name
        return best

    def select_batch(self, cluster, tasks, weights, provider=None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        return [self.select(cluster, t, weights, provider, now_hour)
                for t in tasks]


# ---------------------------------------------------------------------------
# Vectorized / Pallas policy (engine default)
# ---------------------------------------------------------------------------


class VectorizedPolicy:
    """Batched NSA: one scorer call for B tasks x N nodes.

    ``backend``:
      - ``"auto"``   — Pallas kernel on TPU, numpy elsewhere (default);
      - ``"numpy"``  — float64 numpy (bit-matches the scalar oracle);
      - ``"pallas"`` — the ``kernels/node_score`` kernel (interpret mode off
        TPU), float32.
    """

    name = "vectorized"

    def __init__(self, backend: str = "auto",
                 latency_threshold_ms: float = 5000.0):
        if backend not in ("auto", "numpy", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.latency_threshold_ms = latency_threshold_ms

    def _resolved_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "numpy"

    # -- scoring -----------------------------------------------------------
    def score_batch(self, features: np.ndarray, weights: Weights) -> np.ndarray:
        """(B, N, 8) features -> (B, N) total scores; invalid rows get the
        negative sentinel. One kernel launch on the pallas backend."""
        w5 = weights.as_array()
        if self._resolved_backend() == "pallas":
            return self._score_pallas(features, w5)
        return self._score_numpy(features, w5)

    @staticmethod
    def _score_numpy(F: np.ndarray, w5: np.ndarray) -> np.ndarray:
        flat = F.reshape(-1, FEATURE_DIM)
        total = vector_scores(flat[:, :6], w5)
        total = np.where(flat[:, COL_VALID] > 0.5, total, -np.inf)
        return total.reshape(F.shape[0], F.shape[1])

    @staticmethod
    def _score_pallas(F: np.ndarray, w5: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels import ops

        w8 = np.zeros(FEATURE_DIM, np.float32)
        w8[:5] = w5
        out = ops.node_scores_batched(jnp.asarray(F, jnp.float32),
                                      jnp.asarray(w8))
        return np.asarray(out, np.float64)

    # -- selection ---------------------------------------------------------
    def select_batch(self, cluster: EdgeCluster, tasks: Sequence[Task],
                     weights: Weights,
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        F, names = featurize(cluster, tasks, provider, now_hour,
                             self.latency_threshold_ms)
        totals = self.score_batch(F, weights)
        best = np.argmax(totals, axis=1)
        # Algorithm 1 requires a strictly positive score (best_score init 0).
        return [names[b] if totals[i, b] > 0.0 else None
                for i, b in enumerate(best)]

    # Below this fleet size a single-task selection is cheaper through the
    # scalar loop than through featurize + array machinery (measured ~11 us
    # vs ~57 us at N=3); the scalar loop and the numpy backend are
    # float64-identical (parity-tested), so "auto" falls through — but only
    # when it resolves to numpy, so that on TPU select() and select_batch()
    # share the float32 kernel path and cannot split near-ties differently.
    SMALL_FLEET_CUTOFF = 64

    def select(self, cluster, task, weights, provider=None,
               now_hour: float = 0.0) -> Optional[str]:
        if (self.backend == "auto"
                and len(cluster.nodes) <= self.SMALL_FLEET_CUTOFF
                and self._resolved_backend() == "numpy"):
            return WeightedScoringPolicy(self.latency_threshold_ms).select(
                cluster, task, weights, provider, now_hour)
        return self.select_batch(cluster, [task], weights, provider,
                                 now_hour)[0]


# ---------------------------------------------------------------------------
# Temporal policy (deferral over a slot grid)
# ---------------------------------------------------------------------------


@dataclass
class Placement:
    node: str
    start_hour: float
    expected_carbon_g: float
    deferred_hours: float


class TemporalPolicy:
    """Space-time NSA: Algorithm 1 over a (start-slot x node) grid.

    The Eq. 4 column becomes time-indexed — column 5 of the shared feature
    layout is rewritten per slot with ``provider.intensity(node, t_slot)``
    — and the whole grid is scored in one ``VectorizedPolicy`` call.
    Placement minimises expected carbon; exact carbon ties are broken by
    the weighted Eq. 3 score (with a tiny deferral penalty so full ties
    stay at "run now").

    The seed's scheduler had no latency-threshold filter on the temporal
    path, so the default threshold here is +inf for behavioural parity.
    """

    name = "temporal"

    def __init__(self, slot_hours: float = 0.5,
                 scorer: Optional[VectorizedPolicy] = None,
                 latency_threshold_ms: Optional[float] = None,
                 backend: str = "auto"):
        """Prefer ``backend=`` to force a scorer backend. If a prebuilt
        ``scorer`` is supplied its latency threshold governs — passing a
        conflicting explicit ``latency_threshold_ms`` raises, mirroring
        TemporalScheduler's slot_hours conflict check."""
        self.slot_hours = slot_hours
        if scorer is not None:
            if (latency_threshold_ms is not None
                    and latency_threshold_ms != scorer.latency_threshold_ms):
                raise ValueError(
                    f"conflicting latency_threshold_ms: {latency_threshold_ms}"
                    f" vs the supplied scorer's {scorer.latency_threshold_ms}")
            if backend != "auto" and backend != scorer.backend:
                raise ValueError(
                    f"conflicting backend: {backend!r} vs the supplied "
                    f"scorer's {scorer.backend!r}")
            self.scorer = scorer
        else:
            self.scorer = VectorizedPolicy(
                backend=backend,
                latency_threshold_ms=(float("inf")
                                      if latency_threshold_ms is None
                                      else latency_threshold_ms))

    def place(self, cluster: EdgeCluster, task, weights: Weights,
              provider: CarbonIntensityProvider,
              now_hour: float = 0.0) -> Optional[Placement]:
        """``task`` needs ``deadline_hours``/``duration_hours`` on top of the
        base Task fields (see temporal.DeferrableTask); a plain Task is
        treated as urgent (run now, zero-duration energy estimate)."""
        deadline = getattr(task, "deadline_hours", 0.0)
        duration = getattr(task, "duration_hours", 0.0)
        horizon = max(deadline - duration, 0.0)
        n_slots = max(1, int(horizon / self.slot_hours) + 1)
        # For deferrable tasks the Eq. 4 column is rebuilt per slot below,
        # so skip the N provider queries featurize would otherwise spend on
        # a column that gets overwritten.
        F, names = featurize(cluster, [task],
                             None if duration > 0 else provider, now_hour,
                             self.scorer.latency_threshold_ms)
        G = np.repeat(F, n_slots, axis=0)                     # (S, N, 8)
        # per-node task energy (kWh) at its derived power draw
        e_kwh = np.array([cluster.nodes[n].power_w(cluster.host_power_w)
                          * duration / 1000.0 for n in names])
        t0 = now_hour + np.arange(n_slots) * self.slot_hours
        mid = t0 + duration / 2.0
        # Slot-grid intensities only for feasible nodes — masked columns
        # stay 0, a partial-coverage provider must not fail on nodes that
        # can never be selected (same guarantee featurize gives the
        # instantaneous policies) — and only when the task has a duration:
        # at duration == 0 the carbon grid is identically zero and the
        # featurize column already holds the Eq. 4 signal.
        feasible = F[0, :, COL_VALID] > 0.5
        I = np.zeros((n_slots, len(names)))                   # (S, N)
        if duration > 0:
            for j, n in enumerate(names):
                if feasible[j]:
                    I[:, j] = [provider.intensity(n, float(m)) for m in mid]
            G[:, :, COL_IXE] = I * e_kwh[None, :] * 1e3       # time-indexed S_C
        # duration == 0 (plain/urgent task): keep featurize's e_est-based
        # Eq. 4 column so the carbon weight still differentiates nodes; the
        # zero carbon grid below then ties everywhere and the weighted
        # score picks the winner, matching the instantaneous NSA.
        totals = self.scorer.score_batch(G, weights)          # (S, N)
        valid = totals > _NEG_SENTINEL
        if not valid.any():
            return None
        carbon = I * e_kwh[None, :]                           # expected gCO2
        masked = np.where(valid, carbon, np.inf)
        tie = masked <= masked.min() + 1e-12
        penalty = (np.arange(n_slots) * 1e-6)[:, None]        # prefer run-now
        cand = np.where(tie, totals - penalty, -np.inf)
        s_idx, n_idx = np.unravel_index(int(np.argmax(cand)), cand.shape)
        return Placement(names[n_idx], float(t0[s_idx]),
                         float(carbon[s_idx, n_idx]),
                         s_idx * self.slot_hours)

    # SchedulingPolicy interface: instantaneous fallback for urgent tasks.
    def select(self, cluster, task, weights, provider=None,
               now_hour: float = 0.0) -> Optional[str]:
        pl = self.place(cluster, task,
                        weights,
                        provider or StaticProvider.from_cluster(cluster),
                        now_hour)
        return pl.node if pl is not None else None

    def select_batch(self, cluster, tasks, weights, provider=None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        return [self.select(cluster, t, weights, provider, now_hour)
                for t in tasks]
