"""Scheduling policies (DESIGN.md §1.2): one scoring rule, three engines.

``featurize`` is the single source of the (N, 8) feature-matrix layout the
Pallas ``node_score`` kernel, the numpy scorer, and the scalar oracle all
share — the paper's Eq. 3/4 components are computed from these columns and
nowhere else:

  0 cpu_free_frac   free_cpu / task.cpu        (min(.,1) -> half of S_R)
  1 mem_free_frac   free_mem / task.mem_mb     (min(.,1) -> half of S_R)
  2 load            -> S_L = 1 - load
  3 avg_time_s      -> S_P = 1 / (1 + t)
  4 running         -> S_B = 1 / (1 + 2r)
  5 intensity*E_est -> S_C = 1 / (1 + I*E)     (Eq. 4)
  6 valid           feasibility filter (Algorithm 1 lines 3-5)
  7 padding

Policies:

- :class:`WeightedScoringPolicy` — the scalar Python loop (Algorithm 1
  verbatim). Survives as the parity oracle.
- :class:`VectorizedPolicy` — batched (B, N) scoring in one call; numpy on
  CPU hosts, the Pallas ``node_score`` kernel on TPU. The engine default.
- :class:`TemporalPolicy` — deferral as a (slot x node) grid where the
  Eq. 4 column is time-indexed through the intensity provider; min-carbon
  placement with the weighted score as tie-breaker.
"""
from __future__ import annotations

from dataclasses import dataclass
from time import perf_counter
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import (CarbonIntensityProvider, StaticProvider)
from repro.core.cluster import EdgeCluster
from repro.core.scheduler import (LOAD_THRESHOLD, Task, Weights,
                                  node_feasible, scores, vector_scores)

# Scores below this are "invalid" sentinels (the Pallas kernel emits -1e30,
# the numpy path -inf).
_NEG_SENTINEL = -1e29

FEATURE_DIM = 8
(COL_CPU_FREE, COL_MEM_FREE, COL_LOAD, COL_TIME_S,
 COL_RUNNING, COL_IXE, COL_VALID, COL_PAD) = range(FEATURE_DIM)


def featurize(cluster: EdgeCluster, tasks: Sequence[Task],
              provider: Optional[CarbonIntensityProvider] = None,
              now_hour: float = 0.0,
              latency_threshold_ms: float = 5000.0,
              dtype=np.float64) -> Tuple[np.ndarray, List[str]]:
    """Extract the (B, N, 8) feature tensor for B tasks against N nodes.

    Grid intensity is read exclusively through ``provider`` (defaulting to
    the cluster's static regional values). Returns (features, node_names)
    with node order matching the cluster's insertion order, so an argmax
    over scores indexes ``node_names`` directly.
    """
    names = list(cluster.nodes)
    B, N = len(tasks), len(names)
    # Only the resource columns depend on the task, so the task dimension is
    # pure numpy broadcasting — the Python cost of a batched step is O(N+B),
    # not O(N*B).
    task_cpu = np.array([t.cpu for t in tasks], dtype)
    task_mem = np.array([t.mem_mb for t in tasks], dtype)
    F = np.zeros((B, N, FEATURE_DIM), dtype)
    for j, name in enumerate(names):
        st = cluster.nodes[name]
        free_cpu = st.spec.cpu * (1.0 - st.load)
        free_mem = st.spec.mem_mb - st.mem_used_mb
        node_ok = (st.load <= LOAD_THRESHOLD
                   and st.avg_time_ms <= latency_threshold_ms)
        feasible = node_ok & (free_cpu >= task_cpu) & (free_mem >= task_mem)
        # Query the provider only when some task can actually use the node
        # (like the scalar oracle, which filters before reading intensity):
        # a masked column's Eq. 4 value is irrelevant, and a
        # partial-coverage provider must not fail on unusable nodes.
        # No provider => the node's static regional value, without building
        # a throwaway StaticProvider per call (this is the hot path).
        if not feasible.any():
            intensity = 0.0
        elif provider is not None:
            intensity = provider.intensity(name, now_hour)
        else:
            intensity = st.spec.carbon_intensity
        e_est = st.power_w(cluster.host_power_w) * st.avg_time_ms / 3.6e6
        cpu_frac = np.ones(B, dtype)
        np.divide(free_cpu, task_cpu, out=cpu_frac, where=task_cpu > 0)
        mem_frac = np.ones(B, dtype)
        np.divide(free_mem, task_mem, out=mem_frac, where=task_mem > 0)
        F[:, j, COL_CPU_FREE] = cpu_frac
        F[:, j, COL_MEM_FREE] = mem_frac
        F[:, j, COL_LOAD] = st.load
        F[:, j, COL_TIME_S] = st.avg_time_ms / 1000.0
        F[:, j, COL_RUNNING] = st.running
        # masked entries carry 0, keeping each batch row independent of its
        # batch-mates (a row equals featurizing that task alone)
        F[:, j, COL_IXE] = np.where(feasible, intensity * e_est, 0.0)
        F[:, j, COL_VALID] = feasible.astype(dtype)
    return F, names


def featurize_cached(cache, tasks: Sequence[Task],
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0,
                     latency_threshold_ms: float = 5000.0,
                     dtype=np.float64) -> Tuple[np.ndarray, List[str]]:
    """(B, N, 8) feature tensor from a synced
    :class:`~repro.core.featcache.FeatureCache` — same layout and *bit-
    identical values* as :func:`featurize`, without the per-node Python
    loop or the N per-node provider calls (grid intensity is one batched
    read, memoized per (provider, hour), and only feasible nodes are
    queried — the partial-coverage guarantee carries over).
    """
    B, N = len(tasks), cache.n
    task_cpu = np.array([t.cpu for t in tasks], dtype)
    task_mem = np.array([t.mem_mb for t in tasks], dtype)
    F = np.zeros((B, N, FEATURE_DIM), dtype)
    feasible = cache.feasible(task_cpu, task_mem, latency_threshold_ms)
    ints = cache.intensities(provider, now_hour, need=feasible.any(axis=0))
    cpu_frac = np.ones((B, N), dtype)
    np.divide(cache.free_cpu[None, :], task_cpu[:, None], out=cpu_frac,
              where=(task_cpu > 0)[:, None])
    mem_frac = np.ones((B, N), dtype)
    np.divide(cache.free_mem[None, :], task_mem[:, None], out=mem_frac,
              where=(task_mem > 0)[:, None])
    F[:, :, COL_CPU_FREE] = cpu_frac
    F[:, :, COL_MEM_FREE] = mem_frac
    F[:, :, COL_LOAD] = cache.load[None, :]
    F[:, :, COL_TIME_S] = cache.avg_time_s[None, :]
    F[:, :, COL_RUNNING] = cache.running[None, :]
    F[:, :, COL_IXE] = np.where(feasible, (ints * cache.e_est)[None, :], 0.0)
    F[:, :, COL_VALID] = feasible.astype(dtype)
    return F, list(cache.names)


def get_cache(cluster):
    """The cluster's synced FeatureCache, or None for cluster-likes that
    don't carry one (anything without the EdgeCluster topology plumbing).
    Shared by the policies here and :class:`repro.partition.policy.
    PartitionPolicy` (which widens selection to (B, P, N))."""
    fc = getattr(cluster, "feature_cache", None)
    return fc() if callable(fc) else None


# Backwards-compatible alias (pre-partition-subsystem name).
_get_cache = get_cache


class _SelectionMemo:
    """Profile-level selection memo over unchanged feature state
    (DESIGN.md §6).

    Selection is a pure function of (cache columns, provider, hour,
    weights, backend, latency threshold, task (cpu, mem_mb) profile) —
    batch rows are independent of their batch-mates. The cache's
    ``data_rev`` only moves when a column VALUE changes (execution-ledger
    writes re-dirty nodes without moving features), so in steady state a
    repeated request profile resolves to a dict hit instead of an (N,)
    scoring pass. Any epoch drift — feature change, different provider
    object, new hour on a time-varying provider — drops the whole memo.
    Stored on the FeatureCache (``cache._sel_memo``) so it lives and dies
    with the cluster it describes.
    """

    __slots__ = ("rev", "provider", "hour", "map")

    def __init__(self):
        self.rev = None
        self.provider = None
        self.hour = None
        self.map: dict = {}

    def sync_epoch(self, cache, provider, now_hour: float) -> None:
        # A TIME_INVARIANT (or absent) provider answers identically for
        # every hour, so the hour is not part of its epoch.
        hour = (None if provider is None
                or getattr(provider, "TIME_INVARIANT", False) else now_hour)
        if (self.rev != cache.data_rev or self.provider is not provider
                or self.hour != hour):
            self.rev = cache.data_rev
            self.provider = provider
            self.hour = hour
            self.map.clear()


# ---------------------------------------------------------------------------
# Scalar oracle (Algorithm 1 verbatim)
# ---------------------------------------------------------------------------


class WeightedScoringPolicy:
    """Python-loop NSA (paper Algorithm 1) — the parity oracle.

    Identical math to the seed's ``select_node``, with intensity read
    through the provider instead of ``NodeSpec.carbon_intensity``.
    """

    name = "scalar"

    def __init__(self, latency_threshold_ms: float = 5000.0):
        self.latency_threshold_ms = latency_threshold_ms

    def select(self, cluster: EdgeCluster, task: Task, weights: Weights,
               provider: Optional[CarbonIntensityProvider] = None,
               now_hour: float = 0.0) -> Optional[str]:
        best_score, best = 0.0, None
        for name, st in cluster.nodes.items():
            if st.avg_time_ms > self.latency_threshold_ms:
                continue
            if not node_feasible(st, task):
                continue
            comp = scores(st, task, cluster.host_power_w,
                          intensity=provider.intensity(name, now_hour)
                          if provider is not None else None)
            s = float(weights.as_array() @ comp)
            if s > best_score:
                best_score, best = s, name
        return best

    def select_batch(self, cluster, tasks, weights, provider=None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        return [self.select(cluster, t, weights, provider, now_hour)
                for t in tasks]


# ---------------------------------------------------------------------------
# Vectorized / Pallas policy (engine default)
# ---------------------------------------------------------------------------


class VectorizedPolicy:
    """Batched NSA: one scorer call for B tasks x N nodes.

    ``backend``:
      - ``"auto"``   — Pallas kernel on TPU, numpy elsewhere (default);
      - ``"numpy"``  — float64 numpy (bit-matches the scalar oracle);
      - ``"pallas"`` — the ``kernels/node_score`` kernel (interpret mode off
        TPU), float32.

    Fleet-scale fast path (DESIGN.md §3, on by default): features come
    from the cluster's incremental :class:`~repro.core.featcache.
    FeatureCache` (O(changed) per step instead of an O(N) Python rebuild),
    duplicate task resource profiles share one scored row, the task axis
    is chunked to bound peak memory, and Pallas shapes are padded to
    power-of-two buckets so distinct (B, N) stop retriggering jit.
    ``use_cache=False`` forces the fresh ``featurize`` rebuild — the
    parity oracle for all of the above.
    """

    name = "vectorized"

    # Bound on elements per (chunk x nodes) scoring block: ~64 MB of f64
    # features per chunk at FEATURE_DIM=8.
    _CHUNK_ELEMS = 1 << 20

    # Per-config selection-memo size bound: a request mix has a handful of
    # live (cpu, mem_mb) profiles; past this many the keys are effectively
    # continuous and the memo is dropped rather than grown without bound.
    MEMO_MAX_PROFILES = 4096

    def __init__(self, backend: str = "auto",
                 latency_threshold_ms: float = 5000.0,
                 use_cache: bool = True, use_select_memo: bool = True):
        if backend not in ("auto", "numpy", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.latency_threshold_ms = latency_threshold_ms
        self.use_cache = use_cache
        # Steady-state fast path (DESIGN.md §6): memoize per-profile
        # selection while the cache's data_rev / provider / hour epoch
        # holds. False forces a fresh scoring pass every call — what the
        # fleet-scale featurize benchmarks measure.
        self.use_select_memo = use_select_memo
        # Observability hooks (DESIGN.md §9), both no-ops by default: a
        # repro.obs StepProfiler on `profiler` gets featurize/score span
        # timings; `capture_scores = True` additionally publishes the
        # winning and runner-up totals of the last select_batch on
        # `last_scores` ({"score": (B,), "runner_up": (B,)}) without
        # perturbing any choice.
        self.profiler = None
        self.capture_scores = False
        self.last_scores = None

    def _resolved_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "numpy"

    # -- scoring -----------------------------------------------------------
    def score_batch(self, features: np.ndarray, weights: Weights) -> np.ndarray:
        """(B, N, 8) features -> (B, N) total scores; invalid rows get the
        negative sentinel. One kernel launch on the pallas backend."""
        w5 = weights.as_array()
        if self._resolved_backend() == "pallas":
            return self._score_pallas(features, w5)
        return self._score_numpy(features, w5)

    @staticmethod
    def _score_numpy(F: np.ndarray, w5: np.ndarray) -> np.ndarray:
        flat = F.reshape(-1, FEATURE_DIM)
        total = vector_scores(flat[:, :6], w5)
        total = np.where(flat[:, COL_VALID] > 0.5, total, -np.inf)
        return total.reshape(F.shape[0], F.shape[1])

    @staticmethod
    def _bucket(n: int, floor: int = 8) -> int:
        """Next power-of-two shape bucket: padding (B, N) to buckets keeps
        the jit/Mosaic compile count logarithmic in fleet size instead of
        one compile per distinct shape."""
        b = floor
        while b < n:
            b <<= 1
        return b

    @classmethod
    def _pad_to_buckets(cls, F: np.ndarray) -> np.ndarray:
        B, N = F.shape[:2]
        Bp, Np = cls._bucket(B), cls._bucket(N)
        if (Bp, Np) == (B, N):
            return np.asarray(F, np.float32)
        Fp = np.zeros((Bp, Np, FEATURE_DIM), np.float32)
        Fp[:B, :N] = F                 # pad rows: valid=0 -> masked out
        return Fp

    def _score_pallas(self, F: np.ndarray, w5: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels import ops

        B, N = F.shape[:2]
        w8 = np.zeros(FEATURE_DIM, np.float32)
        w8[:5] = w5
        out = ops.node_scores_batched(jnp.asarray(self._pad_to_buckets(F)),
                                      jnp.asarray(w8))
        return np.asarray(out, np.float64)[:B, :N]

    def _select_pallas_fused(self, F: np.ndarray, w5: np.ndarray):
        """Fused score+argmax kernel: ships (B,) winner indices/scores to
        host instead of the full (B, N) score matrix."""
        import jax.numpy as jnp

        from repro.kernels import ops

        B = F.shape[0]
        w8 = np.zeros(FEATURE_DIM, np.float32)
        w8[:5] = w5
        idx, val = ops.select_best_node_fused(
            jnp.asarray(self._pad_to_buckets(F)), jnp.asarray(w8))
        return np.asarray(idx)[:B], np.asarray(val, np.float64)[:B]

    def _select_from_features(self, F: np.ndarray, names: List[str],
                              weights: Weights) -> List[Optional[str]]:
        # Algorithm 1 requires a strictly positive score (best_score init 0).
        if self._resolved_backend() == "pallas":
            idx, val = self._select_pallas_fused(F, weights.as_array())
            if self.capture_scores:
                # winner-only kernel: runner-up not materialized
                self._cap_s.append(np.asarray(val, dtype=float))
                self._cap_r.append(np.full(len(val), np.nan))
            return [names[b] if v > 0.0 else None for b, v in zip(idx, val)]
        totals = self._score_numpy(F, weights.as_array())
        best = np.argmax(totals, axis=1)
        if self.capture_scores:
            self._cap_block(totals, best)
        return [names[b] if totals[i, b] > 0.0 else None
                for i, b in enumerate(best)]

    # -- score capture (repro.obs decision tracing) ------------------------
    def _cap_block(self, totals: np.ndarray, best: np.ndarray) -> None:
        """Stash the winning and runner-up totals of one scored (U, N)
        block. Runner-up = max over the row with the winner cell masked
        (-inf when N < 2), computed on a copy so selection is untouched."""
        U, N = totals.shape
        rows = np.arange(U)
        self._cap_s.append(totals[rows, best])
        if N < 2:
            self._cap_r.append(np.full(U, -np.inf))
            return
        masked = totals.copy()
        masked[rows, best] = -np.inf
        self._cap_r.append(masked.max(axis=1))

    def _cap_finalize(self) -> None:
        """Rep-level capture arrays for the just-scored blocks, in rep
        order (select_batch expands them to task order)."""
        self._cap = {
            "score": (np.concatenate(self._cap_s) if self._cap_s
                      else np.zeros(0)),
            "runner_up": (np.concatenate(self._cap_r) if self._cap_r
                          else np.zeros(0)),
        }

    # -- selection ---------------------------------------------------------
    def select_batch(self, cluster: EdgeCluster, tasks: Sequence[Task],
                     weights: Weights,
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        if not tasks:
            return []
        # Dedupe task resource profiles: the feature rows (and therefore
        # the selection) depend only on (cpu, mem_mb), and batch rows are
        # independent of their batch-mates — B identical tasks cost one
        # scored row, not B.
        keys = [(t.cpu, t.mem_mb) for t in tasks]
        uniq: dict = {}
        reps: List[Task] = []
        for t, key in zip(tasks, keys):
            if key not in uniq:
                uniq[key] = len(reps)
                reps.append(t)
        chosen = self._select_unique(cluster, reps, weights, provider,
                                     now_hour)
        if not self.capture_scores:
            return [chosen[uniq[key]] for key in keys]
        # expand rep-level capture to task order with the same index map;
        # fromiter over map(dict.__getitem__) builds the index at C speed
        # and the object-array gather + tolist replaces the off path's
        # per-task dict-lookup listcomp — capture costs ~the off path
        idx = np.fromiter(map(uniq.__getitem__, keys), np.intp,
                          count=len(keys))
        self.last_scores = {k: np.asarray(v)[idx]
                            for k, v in self._cap.items()}
        return np.asarray(chosen, dtype=object)[idx].tolist()

    # Above this fleet size the numpy backend scores straight from the
    # cache's column arrays (one (N,) task-independent component base per
    # step + an (U, N) S_R/feasibility pass) instead of materializing the
    # (B, N, 8) tensor — ~3-4x less memory traffic, at the cost of a
    # last-ulp different summation order than vector_scores' dot product
    # (argmax-equivalent except on sub-1e-12 score ties). Below it, the
    # featurize_cached + vector_scores path keeps scoring bit-identical
    # to the scalar oracle.
    COLUMN_PATH_MIN_N = 4096

    def _select_unique(self, cluster, reps: Sequence[Task], weights: Weights,
                       provider, now_hour: float) -> List[Optional[str]]:
        cap = self.capture_scores
        if cap:
            self._cap_s, self._cap_r = [], []
            self.last_scores = None
        cache = get_cache(cluster) if self.use_cache else None
        if cache is None:
            prof = self.profiler
            t0 = perf_counter() if prof is not None else 0.0
            F, names = featurize(cluster, reps, provider, now_hour,
                                 self.latency_threshold_ms)
            if prof is not None:
                prof.add("featurize", perf_counter() - t0)
                t0 = perf_counter()
            out = self._select_from_features(F, names, weights)
            if prof is not None:
                prof.add("score", perf_counter() - t0)
            if cap:
                self._cap_finalize()
            return out
        if not self.use_select_memo:
            out = self._select_cached(cache, reps, weights, provider,
                                      now_hour)
            if cap:
                self._cap_finalize()
            return out
        memo = getattr(cache, "_sel_memo", None)
        if memo is None:
            memo = cache._sel_memo = _SelectionMemo()
        memo.sync_epoch(cache, provider, now_hour)
        # `cap` is part of the key: capture-on tables store
        # (choice, score, runner_up) triples, plain tables bare choices
        cfg = (self._resolved_backend(), self.latency_threshold_ms,
               weights.as_array().tobytes(), cap)
        table = memo.map.setdefault(cfg, {})   # hash cfg once, not per key
        keys = [(t.cpu, t.mem_mb) for t in reps]
        missing = [i for i, k in enumerate(keys) if k not in table]
        if missing:
            chosen = self._select_cached(cache, [reps[i] for i in missing],
                                         weights, provider, now_hour)
            if len(table) + len(missing) > self.MEMO_MAX_PROFILES:
                # Continuous-valued profiles never repeat: without a bound
                # a long-lived engine would grow the table one dead entry
                # per task. Dropping it wholesale is cheap — a workload
                # with that many live profiles gets no hits anyway.
                table.clear()
            if cap:
                ms = np.concatenate(self._cap_s) if self._cap_s \
                    else np.zeros(0)
                mr = np.concatenate(self._cap_r) if self._cap_r \
                    else np.zeros(0)
                for j, (i, ch) in enumerate(zip(missing, chosen)):
                    table[keys[i]] = (ch, float(ms[j]), float(mr[j]))
            else:
                for i, ch in zip(missing, chosen):
                    table[keys[i]] = ch
        if not cap:
            return [table[k] for k in keys]
        entries = [table[k] for k in keys]
        self._cap = {
            "score": np.array([e[1] for e in entries]),
            "runner_up": np.array([e[2] for e in entries]),
        }
        return [e[0] for e in entries]

    def _select_cached(self, cache, reps: Sequence[Task], weights: Weights,
                       provider, now_hour: float) -> List[Optional[str]]:
        """One fresh scoring pass over the synced cache columns (no memo)."""
        if (cache.n >= self.COLUMN_PATH_MIN_N
                and self._resolved_backend() == "numpy"):
            return self._select_cached_columns(cache, reps, weights,
                                               provider, now_hour)
        names = cache.names
        chunk = max(1, self._CHUNK_ELEMS // max(cache.n, 1))
        prof = self.profiler
        out: List[Optional[str]] = []
        for lo in range(0, len(reps), chunk):
            t0 = perf_counter() if prof is not None else 0.0
            F, _ = featurize_cached(cache, reps[lo:lo + chunk], provider,
                                    now_hour, self.latency_threshold_ms)
            if prof is not None:
                prof.add("featurize", perf_counter() - t0)
                t0 = perf_counter()
            out.extend(self._select_from_features(F, names, weights))
            if prof is not None:
                prof.add("score", perf_counter() - t0)
        return out

    def _select_cached_columns(self, cache, reps: Sequence[Task],
                               weights: Weights, provider,
                               now_hour: float) -> List[Optional[str]]:
        """Fleet-scale numpy selection straight from cache columns: the
        task-independent components (S_L, S_P, S_B, S_C) are one (N,)
        vector per step; only S_R and feasibility touch (U, N)."""
        w = weights.as_array()
        names = cache.names
        prof = self.profiler
        t0 = perf_counter() if prof is not None else 0.0
        task_cpu = np.array([t.cpu for t in reps], dtype=float)
        task_mem = np.array([t.mem_mb for t in reps], dtype=float)
        feasible = cache.feasible(task_cpu, task_mem,
                                  self.latency_threshold_ms)     # (U, N)
        ints = cache.intensities(provider, now_hour,
                                 need=feasible.any(axis=0))
        base = (w[1] * (1.0 - cache.load)
                + w[2] * (1.0 / (1.0 + cache.avg_time_s))
                + w[3] * (1.0 / (1.0 + cache.running * 2.0))
                + w[4] * (1.0 / (1.0 + ints * cache.e_est)))     # (N,)
        if prof is not None:
            prof.add("featurize", perf_counter() - t0)
            t0 = perf_counter()
        out: List[Optional[str]] = []
        chunk = max(1, self._CHUNK_ELEMS // max(cache.n, 1))
        for lo in range(0, len(reps), chunk):
            tc = task_cpu[lo:lo + chunk, None]
            tm = task_mem[lo:lo + chunk, None]
            cpu_frac = np.ones((tc.shape[0], cache.n))
            np.divide(cache.free_cpu[None, :], tc, out=cpu_frac,
                      where=tc > 0)
            mem_frac = np.ones((tm.shape[0], cache.n))
            np.divide(cache.free_mem[None, :], tm, out=mem_frac,
                      where=tm > 0)
            s_r = (0.5 * np.minimum(1.0, cpu_frac)
                   + 0.5 * np.minimum(1.0, mem_frac))
            totals = np.where(feasible[lo:lo + chunk],
                              w[0] * s_r + base[None, :], -np.inf)
            best = np.argmax(totals, axis=1)
            if self.capture_scores:
                self._cap_block(totals, best)
            out.extend(names[b] if totals[i, b] > 0.0 else None
                       for i, b in enumerate(best))
        if prof is not None:
            prof.add("score", perf_counter() - t0)
        return out

    # Below this fleet size a single-task selection is cheaper through the
    # scalar loop than through featurize + array machinery (measured ~11 us
    # vs ~57 us at N=3); the scalar loop and the numpy backend are
    # float64-identical (parity-tested), so "auto" falls through — but only
    # when it resolves to numpy, so that on TPU select() and select_batch()
    # share the float32 kernel path and cannot split near-ties differently.
    SMALL_FLEET_CUTOFF = 64

    def select(self, cluster, task, weights, provider=None,
               now_hour: float = 0.0) -> Optional[str]:
        if (self.backend == "auto"
                and len(cluster.nodes) <= self.SMALL_FLEET_CUTOFF
                and self._resolved_backend() == "numpy"):
            return WeightedScoringPolicy(self.latency_threshold_ms).select(
                cluster, task, weights, provider, now_hour)
        return self.select_batch(cluster, [task], weights, provider,
                                 now_hour)[0]


# ---------------------------------------------------------------------------
# Temporal policy (deferral over a slot grid)
# ---------------------------------------------------------------------------


@dataclass
class Placement:
    node: str
    start_hour: float
    expected_carbon_g: float
    deferred_hours: float


class TemporalPolicy:
    """Space-time NSA: Algorithm 1 over a (start-slot x node) grid.

    The Eq. 4 column becomes time-indexed — column 5 of the shared feature
    layout is rewritten per slot with ``provider.intensity(node, t_slot)``
    — and the whole grid is scored in one ``VectorizedPolicy`` call.
    Placement minimises expected carbon; exact carbon ties are broken by
    the weighted Eq. 3 score (with a tiny deferral penalty so full ties
    stay at "run now").

    The seed's scheduler had no latency-threshold filter on the temporal
    path, so the default threshold here is +inf for behavioural parity.
    """

    name = "temporal"

    def __init__(self, slot_hours: float = 0.5,
                 scorer: Optional[VectorizedPolicy] = None,
                 latency_threshold_ms: Optional[float] = None,
                 backend: str = "auto"):
        """Prefer ``backend=`` to force a scorer backend. If a prebuilt
        ``scorer`` is supplied its latency threshold governs — passing a
        conflicting explicit ``latency_threshold_ms`` raises, mirroring
        TemporalScheduler's slot_hours conflict check."""
        self.slot_hours = slot_hours
        if scorer is not None:
            if (latency_threshold_ms is not None
                    and latency_threshold_ms != scorer.latency_threshold_ms):
                raise ValueError(
                    f"conflicting latency_threshold_ms: {latency_threshold_ms}"
                    f" vs the supplied scorer's {scorer.latency_threshold_ms}")
            if backend != "auto" and backend != scorer.backend:
                raise ValueError(
                    f"conflicting backend: {backend!r} vs the supplied "
                    f"scorer's {scorer.backend!r}")
            self.scorer = scorer
        else:
            self.scorer = VectorizedPolicy(
                backend=backend,
                latency_threshold_ms=(float("inf")
                                      if latency_threshold_ms is None
                                      else latency_threshold_ms))

    def place(self, cluster: EdgeCluster, task, weights: Weights,
              provider: CarbonIntensityProvider,
              now_hour: float = 0.0) -> Optional[Placement]:
        """``task`` needs ``deadline_hours``/``duration_hours`` on top of the
        base Task fields (see temporal.DeferrableTask); a plain Task is
        treated as urgent (run now, zero-duration energy estimate)."""
        deadline = getattr(task, "deadline_hours", 0.0)
        duration = getattr(task, "duration_hours", 0.0)
        horizon = max(deadline - duration, 0.0)
        n_slots = max(1, int(horizon / self.slot_hours) + 1)
        # For deferrable tasks the Eq. 4 column is rebuilt per slot below,
        # so skip the N provider queries featurize would otherwise spend on
        # a column that gets overwritten.
        slot_provider = None if duration > 0 else provider
        cache = get_cache(cluster) if self.scorer.use_cache else None
        if cache is not None:
            F, names = featurize_cached(cache, [task], slot_provider,
                                        now_hour,
                                        self.scorer.latency_threshold_ms)
        else:
            F, names = featurize(cluster, [task], slot_provider, now_hour,
                                 self.scorer.latency_threshold_ms)
        G = np.repeat(F, n_slots, axis=0)                     # (S, N, 8)
        # per-node task energy (kWh) at its derived power draw
        if cache is not None:
            e_kwh = cache.power * duration / 1000.0
        else:
            e_kwh = np.array([cluster.nodes[n].power_w(cluster.host_power_w)
                              * duration / 1000.0 for n in names])
        t0 = now_hour + np.arange(n_slots) * self.slot_hours
        mid = t0 + duration / 2.0
        # Slot-grid intensities only for feasible nodes — masked columns
        # stay 0, a partial-coverage provider must not fail on nodes that
        # can never be selected (same guarantee featurize gives the
        # instantaneous policies) — and only when the task has a duration:
        # at duration == 0 the carbon grid is identically zero and the
        # featurize column already holds the Eq. 4 signal.
        feasible = F[0, :, COL_VALID] > 0.5
        grid = np.zeros((n_slots, len(names)))                # (S, N)
        if duration > 0:
            idx = np.nonzero(feasible)[0]
            if idx.size:
                # the whole (S, N_feasible) slot grid in one batched read
                from repro.core.api import intensity_batch
                grid[:, idx] = np.asarray(
                    intensity_batch(provider, [names[j] for j in idx], mid)
                ).reshape(n_slots, idx.size)
            G[:, :, COL_IXE] = grid * e_kwh[None, :] * 1e3    # time-indexed S_C
        # duration == 0 (plain/urgent task): keep featurize's e_est-based
        # Eq. 4 column so the carbon weight still differentiates nodes; the
        # zero carbon grid below then ties everywhere and the weighted
        # score picks the winner, matching the instantaneous NSA.
        totals = self.scorer.score_batch(G, weights)          # (S, N)
        valid = totals > _NEG_SENTINEL
        if not valid.any():
            return None
        carbon = grid * e_kwh[None, :]                        # expected gCO2
        masked = np.where(valid, carbon, np.inf)
        tie = masked <= masked.min() + 1e-12
        penalty = (np.arange(n_slots) * 1e-6)[:, None]        # prefer run-now
        cand = np.where(tie, totals - penalty, -np.inf)
        s_idx, n_idx = np.unravel_index(int(np.argmax(cand)), cand.shape)
        return Placement(names[n_idx], float(t0[s_idx]),
                         float(carbon[s_idx, n_idx]),
                         s_idx * self.slot_hours)

    # SchedulingPolicy interface: instantaneous fallback for urgent tasks.
    def select(self, cluster, task, weights, provider=None,
               now_hour: float = 0.0) -> Optional[str]:
        pl = self.place(cluster, task,
                        weights,
                        provider or StaticProvider.from_cluster(cluster),
                        now_hour)
        return pl.node if pl is not None else None

    def select_batch(self, cluster, tasks, weights, provider=None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        return [self.select(cluster, t, weights, provider, now_hour)
                for t in tasks]
