"""Simulated heterogeneous edge cluster (the paper's Docker test-bed).

Deterministic discrete-event model reproducing §IV.A:
- three nodes with cpu/mem quotas and static regional carbon intensities,
- profiled per-node execution history (cpu-quota-scaled) feeding S_P / S_C,
- host-bound measured latency with a distribution overhead,
- serial task execution with full-host-power energy billing (the paper's
  CodeCarbon machine-mode accounting), plus the quota-apportionment path
  for concurrent multi-tenant accounting.

Nodes can equally represent TPU pods / mesh slices with grid regions — the
scheduler only sees NodeSpec/NodeState (see launch/serve.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import energy as energy_mod


@dataclass(frozen=True)
class NodeSpec:
    name: str
    cpu: float                    # quota fraction (paper: --cpus)
    mem_mb: int
    carbon_intensity: float       # gCO2/kWh (static regional scenario)
    power_w: float = 0.0          # 0 -> derived: host_power * cpu
    region: str = ""
    latency_threshold_ms: float = 5000.0


# Paper §IV.A.1 node scenarios.
PAPER_NODES = (
    NodeSpec("node-high", 1.0, 1024, 620.0, region="coal-heavy"),
    NodeSpec("node-medium", 0.6, 512, 530.0, region="cn-average"),
    NodeSpec("node-green", 0.4, 512, 380.0, region="hydro-rich"),
)


@dataclass
class NodeState:
    spec: NodeSpec
    load: float = 0.0             # fraction of cpu quota in use
    mem_used_mb: float = 0.0
    running: int = 0              # currently queued/executing tasks
    completed: int = 0
    avg_time_ms: float = 0.0      # profiled/historical execution time
    energy_kwh: float = 0.0
    carbon_g: float = 0.0
    total_time_ms: float = 0.0

    def power_w(self, host_power_w: float) -> float:
        return self.spec.power_w or host_power_w * self.spec.cpu

    def __setattr__(self, name, value):
        # Change tracking for the incremental feature cache (DESIGN.md §3):
        # any public-field mutation — whether by the engine, the cluster, or
        # a test poking st.load directly — marks this node dirty in its
        # owning cluster, so FeatureCache.sync() refreshes O(changed) rows
        # instead of rebuilding all N.
        object.__setattr__(self, name, value)
        if not name.startswith("_"):
            sink = getattr(self, "_dirty_sink", None)
            if sink is not None:
                sink.add(self.spec.name)


@dataclass(slots=True)
class TaskResult:
    node: str
    latency_ms: float
    energy_kwh: float
    carbon_g: float


class EdgeCluster:
    """Serial discrete-event executor with carbon accounting."""

    def __init__(self, nodes=PAPER_NODES, host_power_w: float = 142.0,
                 distribution_overhead: float = 0.065, pue: float = 1.0):
        self.host_power_w = host_power_w
        self.distribution_overhead = distribution_overhead
        self.pue = pue
        self.nodes: Dict[str, NodeState] = {n.name: NodeState(spec=n) for n in nodes}
        self.log: List[TaskResult] = []
        # Incremental feature cache plumbing (DESIGN.md §3): every NodeState
        # mutation lands its name in _dirty; topology changes bump _topo_rev
        # (full rebuild). Mutating self.nodes directly bypasses both — use
        # add_node() / remove_node(), or call invalidate_features().
        self._dirty: set = set()
        self._topo_rev = 0
        self._feat_cache = None
        for st in self.nodes.values():
            st._dirty_sink = self._dirty

    # -- topology ----------------------------------------------------------
    def add_node(self, spec: NodeSpec) -> NodeState:
        """Register a node after construction (fleet growth). Keeps the
        feature cache honest — direct ``cluster.nodes[...] =`` writes do
        not, and require :meth:`invalidate_features`."""
        st = NodeState(spec=spec)
        st._dirty_sink = self._dirty
        self.nodes[spec.name] = st
        self._topo_rev += 1
        return st

    def remove_node(self, name: str) -> None:
        st = self.nodes.pop(name)
        # Detach from dirty tracking: a late write to the removed state
        # (e.g. an in-flight completion) must not land an unknown name in
        # _dirty, which would demote every sync to a full O(N) rebuild.
        st._dirty_sink = None
        self._dirty.discard(name)
        self._topo_rev += 1

    def invalidate_features(self) -> None:
        """Force a full feature-cache rebuild on next access (escape hatch
        for callers that mutated ``self.nodes`` or node specs directly)."""
        self._topo_rev += 1

    def feature_cache(self):
        """The cluster's incremental per-node feature columns (lazily
        built, synced O(changed) on access) — see core/featcache.py."""
        from repro.core.featcache import FeatureCache

        if self._feat_cache is None:
            self._feat_cache = FeatureCache(self)
        self._feat_cache.sync()
        return self._feat_cache

    # -- profiling ---------------------------------------------------------
    def profile(self, base_latency_ms: float) -> None:
        """Seed per-node execution history: cpu-quota-scaled (container
        CPU path), used by S_P and S_C before any task has run."""
        for st in self.nodes.values():
            st.avg_time_ms = base_latency_ms / st.spec.cpu

    # -- execution ---------------------------------------------------------
    def measured_latency_ms(self, base_latency_ms: float, distributed: bool) -> float:
        """Host-bound execution path: the distribution overhead (schedule +
        activation transfer) is the only latency cost (paper Table II)."""
        if not distributed:
            return base_latency_ms
        return base_latency_ms * (1.0 + self.distribution_overhead)

    def execute(self, node_name: str, base_latency_ms: float,
                distributed: bool = True,
                intensity: Optional[float] = None) -> TaskResult:
        st = self.nodes[node_name]
        lat = self.measured_latency_ms(base_latency_ms, distributed)
        # Serial run: full host power billed to the executing node's region
        # (CodeCarbon machine-mode accounting). ``intensity`` lets a
        # CarbonIntensityProvider (core/api.py) supply the grid signal at
        # execution time; None keeps the static regional value.
        if intensity is None:
            intensity = st.spec.carbon_intensity
        e_kwh = energy_mod.task_energy_kwh(self.host_power_w, lat)
        c_g = energy_mod.carbon_g(e_kwh, intensity, self.pue)
        st.completed += 1
        st.total_time_ms += lat
        st.energy_kwh += e_kwh
        st.carbon_g += c_g
        res = TaskResult(node_name, lat, e_kwh, c_g)
        self.log.append(res)
        return res

    def latency_energy(self, base_latency_ms, distributed: bool = True):
        """(B,) measured latency and billed energy for a batch of base
        latencies — THE single source of the execution cost model's
        elementwise math (`measured_latency_ms` x `energy.task_energy_kwh`),
        shared by :meth:`execute_batch` and the engine's billing path so
        the two cannot drift."""
        base = np.asarray(base_latency_ms, dtype=float)
        if distributed:
            lat = base * (1.0 + self.distribution_overhead)
        else:
            lat = base.astype(float)
        return lat, energy_mod.task_energy_kwh(self.host_power_w, lat)

    def execute_batch(self, node_names: Sequence[str], base_latency_ms,
                      distributed: bool = True, intensities=None,
                      groups=None) -> List[TaskResult]:
        """Execute B placed tasks in one shot (DESIGN.md §6).

        ``node_names`` is the per-task chosen node; ``base_latency_ms`` and
        ``intensities`` are scalars or (B,) arrays (``intensities=None``
        bills each task at its node's static regional value). Latency,
        energy and carbon are computed as (B,) arrays through the same
        elementwise arithmetic as :meth:`execute`, and each node's ledger
        is updated **once** — O(distinct nodes) Python work, with the float
        accumulations folded in strict task order
        (:func:`~repro.core.energy.ledger_add`) so ledgers stay
        bit-identical to B scalar ``execute`` calls. The per-task loop
        survives as the parity oracle (tests/test_exec_batch.py).

        ``groups`` lets a caller that already grouped the batch pass the
        ``np.unique(node_names_as_object_array, return_inverse=True)``
        result so it is not recomputed (the engine shares one grouping
        across execute and billing).

        Atomic: every input (including unknown node names → ``KeyError``)
        is resolved and all arrays are computed *before* the first ledger
        write, so a failure leaves the cluster untouched.
        """
        B = len(node_names)
        if not B:
            return []
        if groups is None:
            groups = np.unique(np.asarray(node_names, dtype=object),
                               return_inverse=True)
        uniq, inverse = groups
        group_states = [self.nodes[n] for n in uniq]   # KeyError before writes
        base = np.broadcast_to(np.asarray(base_latency_ms, dtype=float), (B,))
        lat, e_kwh = self.latency_energy(base, distributed)
        if intensities is None:
            ints = np.array([st.spec.carbon_intensity
                             for st in group_states], dtype=float)[inverse]
        else:
            ints = np.broadcast_to(np.asarray(intensities, dtype=float), (B,))
        c_g = energy_mod.carbon_g(e_kwh, ints, self.pue)
        # Group tasks by node: a stable argsort over the inverse index gives
        # each distinct node a contiguous run of task positions in original
        # task order (what ledger_add's sequential fold requires).
        order = np.argsort(inverse, kind="stable")
        bounds = np.searchsorted(inverse[order], np.arange(len(uniq) + 1))
        for k, st in enumerate(group_states):
            idx = order[bounds[k]:bounds[k + 1]]
            st.completed += int(idx.size)
            st.total_time_ms = energy_mod.ledger_add(st.total_time_ms,
                                                     lat[idx])
            st.energy_kwh = energy_mod.ledger_add(st.energy_kwh, e_kwh[idx])
            st.carbon_g = energy_mod.ledger_add(st.carbon_g, c_g[idx])
        # .tolist() hands back Python floats in one C pass (matching the
        # scalar path's TaskResult field types) and map() iterates the
        # constructor at C speed — this is the only remaining O(B) cost.
        results = list(map(TaskResult, node_names, lat.tolist(),
                           e_kwh.tolist(), c_g.tolist()))
        self.log.extend(results)
        return results

    # -- concurrent accounting (paper §V.A quota apportionment) ------------
    def apportion(self, window_energy_kwh: float) -> Dict[str, float]:
        """Split a host-level energy window across nodes by cpu quota."""
        total = sum(st.spec.cpu for st in self.nodes.values())
        return {name: window_energy_kwh * st.spec.cpu / total
                for name, st in self.nodes.items()}

    # -- aggregates ---------------------------------------------------------
    def totals(self) -> Dict[str, float]:
        n = len(self.log)
        if not n:
            return {"tasks": 0}
        tot_c = sum(r.carbon_g for r in self.log)
        tot_e = sum(r.energy_kwh for r in self.log)
        tot_t = sum(r.latency_ms for r in self.log)
        return {
            "tasks": n,
            "avg_latency_ms": tot_t / n,
            "throughput_rps": 1000.0 * n / tot_t,
            "carbon_g_per_inf": tot_c / n,
            "energy_kwh_per_inf": tot_e / n,
            "carbon_efficiency_inf_per_g": n / tot_c if tot_c else float("inf"),
        }

    def distribution(self) -> Dict[str, float]:
        n = max(1, len(self.log))
        return {name: 100.0 * sum(1 for r in self.log if r.node == name) / n
                for name in self.nodes}
