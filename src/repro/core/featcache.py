"""Incremental feature-state cache (DESIGN.md §3).

``featurize`` (core/policy.py) rebuilds the (B, N, 8) feature tensor with a
Python loop over all N nodes — and one ``provider.intensity`` call per
node — on every engine step. At fleet scale (N >= 10^4) that loop *is* the
scheduling overhead. :class:`FeatureCache` removes it:

- the cluster owns persistent per-node **column arrays** (free cpu/mem,
  load, avg time, running, derived E_est, static intensity);
- every ``NodeState`` field write marks its node dirty (see
  ``NodeState.__setattr__``), so :meth:`sync` refreshes **O(changed)** rows
  — an engine step that executed B tasks re-reads B rows, not N;
- grid intensity is fetched through the **batched provider API**
  (``api.intensity_batch``: one vectorized call, not N Python calls) and
  memoized per (provider, hour) — a ``TIME_INVARIANT`` provider (e.g.
  ``StaticProvider``) is queried at most once per node, ever;
- only nodes some task in the batch could actually use are queried
  (``need`` mask), preserving ``featurize``'s partial-coverage-provider
  guarantee.

Row refreshes use the *same scalar arithmetic* as ``featurize``'s per-node
loop, so cached columns are bit-identical to a fresh featurize — the fresh
path survives as the parity oracle (tests/test_featcache.py).

Invalidation contract:
- ``NodeState`` field writes        -> automatic (dirty set)
- ``EdgeCluster.add_node/remove_node`` -> automatic (topology rev, rebuild)
- direct ``cluster.nodes[...] =`` surgery, ``host_power_w`` or ``NodeSpec``
  replacement -> caller must call ``cluster.invalidate_features()``
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.scheduler import LOAD_THRESHOLD


class FeatureCache:
    """Persistent per-node feature columns for one :class:`EdgeCluster`.

    Obtain via ``cluster.feature_cache()`` (which syncs); do not construct
    one per step.
    """

    def __init__(self, cluster):
        self.cluster = cluster
        # Feature-state revision: bumped only when a refresh actually
        # CHANGES a column value (or the topology rebuilds) — a dirty node
        # whose re-read values are identical leaves it untouched. Consumers
        # (the VectorizedPolicy selection memo, DESIGN.md §6) may reuse any
        # pure function of the columns while data_rev is unchanged.
        self.data_rev = 0
        # Resilience columns (DESIGN.md §10), owned by an attached
        # repro.resilience.FleetHealth: `avail` is the (N,) bool
        # availability mask node_ok() ANDs in, `fail_count` the (N,)
        # cumulative contact-failure counter. Both stay None — literally
        # absent, zero cost, bit-identical — until the health layer has
        # something to say; every mask mutation bumps data_rev.
        self._health = None
        self._rebuild()

    # -- construction / refresh -------------------------------------------
    def _alloc(self, n: int) -> None:
        self.n = n
        for col in ("cpu", "mem_mb", "load", "mem_used", "free_cpu",
                    "free_mem", "avg_time_ms", "avg_time_s", "running",
                    "power", "e_est", "carbon_static"):
            setattr(self, col, np.zeros(n))
        self.avail = None        # (N,) bool mask, or None = all available
        self.fail_count = None   # (N,) cumulative failures, or None

    def _refresh_row(self, i: int, st) -> bool:
        # Scalar per-row math, in exactly featurize's evaluation order, so
        # cached columns bit-match the fresh per-node loop. Returns whether
        # any column value actually changed (ledger-only mutations — e.g. a
        # batch of executions — re-dirty a node without moving its
        # features; those must not bump data_rev).
        spec = st.spec
        p = st.power_w(self.cluster.host_power_w)
        changed = not (self.cpu[i] == spec.cpu
                       and self.mem_mb[i] == spec.mem_mb
                       and self.load[i] == st.load
                       and self.mem_used[i] == st.mem_used_mb
                       and self.avg_time_ms[i] == st.avg_time_ms
                       and self.running[i] == st.running
                       and self.power[i] == p
                       and self.carbon_static[i] == spec.carbon_intensity)
        if not changed:
            return False
        self.cpu[i] = spec.cpu
        self.mem_mb[i] = spec.mem_mb
        self.load[i] = st.load
        self.mem_used[i] = st.mem_used_mb
        self.free_cpu[i] = spec.cpu * (1.0 - st.load)
        self.free_mem[i] = spec.mem_mb - st.mem_used_mb
        self.avg_time_ms[i] = st.avg_time_ms
        self.avg_time_s[i] = st.avg_time_ms / 1000.0
        self.running[i] = st.running
        self.power[i] = p
        self.e_est[i] = p * st.avg_time_ms / 3.6e6
        self.carbon_static[i] = spec.carbon_intensity
        return True

    def _rebuild(self) -> None:
        cl = self.cluster
        self.names: List[str] = list(cl.nodes)
        self.index = {n: i for i, n in enumerate(self.names)}
        self._alloc(len(self.names))
        for i, st in enumerate(cl.nodes.values()):
            # Adopt states inserted by direct cluster.nodes surgery (the
            # invalidate_features() escape hatch): without a dirty sink
            # their future mutations would go untracked.
            if getattr(st, "_dirty_sink", None) is not cl._dirty:
                st._dirty_sink = cl._dirty
            self._refresh_row(i, st)
        cl._dirty.clear()
        self._topo_seen = cl._topo_rev
        self.data_rev += 1
        self._reset_intensity_cache()
        self._part_blocks = {}
        if self._health is not None:
            # re-project the health mask onto the new topology — a rebuild
            # must not silently unmask a blocked node (DESIGN.md §10)
            self._health.push(self)

    def sync(self) -> None:
        """Bring columns up to date: O(changed) row refreshes, or a full
        rebuild when the fleet's membership changed."""
        cl = self.cluster
        if self._topo_seen != cl._topo_rev or self.n != len(cl.nodes):
            self._rebuild()
            return
        if cl._dirty:
            nodes = cl.nodes
            index = self.index
            changed = False
            for name in cl._dirty:
                i = index.get(name)
                if i is None:          # name we never indexed: stale topo
                    self._rebuild()
                    return
                changed |= self._refresh_row(i, nodes[name])
            cl._dirty.clear()
            if changed:
                self.data_rev += 1

    # -- intensity memoization --------------------------------------------
    def _reset_intensity_cache(self) -> None:
        self._int_provider = None
        self._int_hour = None
        self._int_vals = np.zeros(self.n)
        self._int_have = np.zeros(self.n, dtype=bool)

    def intensities(self, provider, now_hour: float,
                    need: Optional[np.ndarray] = None) -> np.ndarray:
        """(N,) per-node grid intensity; entries are valid where ``need``
        (all nodes when None). ``provider=None`` returns the static
        regional column. Nodes already fetched under the current
        (provider, hour) key — or under the provider alone when it declares
        ``TIME_INVARIANT`` — are served from cache; the rest go through one
        ``api.intensity_batch`` call.
        """
        if provider is None:
            return self.carbon_static
        invariant = getattr(provider, "TIME_INVARIANT", False)
        if provider is not self._int_provider or (
                not invariant and now_hour != self._int_hour):
            self._int_provider = provider
            self._int_vals = np.zeros(self.n)
            self._int_have = np.zeros(self.n, dtype=bool)
        self._int_hour = now_hour
        missing = ~self._int_have if need is None else (need & ~self._int_have)
        if missing.any():
            from repro.core.api import intensity_batch

            idx = np.nonzero(missing)[0]
            vals = intensity_batch(provider, [self.names[i] for i in idx],
                                   now_hour)
            self._int_vals[idx] = np.asarray(vals, dtype=float)
            self._int_have[idx] = True
        return self._int_vals

    # -- joint partition columns (repro.partition, DESIGN.md §8) -----------
    # Bound on live per-profile blocks: a deployment schedules a handful of
    # model profiles; past this the keys are churning and the dict is
    # dropped wholesale rather than grown without bound.
    _PART_BLOCK_MAX = 64

    def partition_block(self, key, remote_frac: np.ndarray,
                        comm_s: np.ndarray):
        """(P, N) joint time/energy columns for one cut profile:

        ``t[p, n] = avg_time_s[n] * remote_frac[p] + comm_s[p]`` (seconds)
        ``e[p, n] = power[n] * (t * 1e3) / 3.6e6``        (kWh, Eq. 4)

        Cached per ``key`` (the policy passes its hashable (CutProfile,
        link speed) pair) and recomputed only when ``data_rev`` moves, so
        the joint scorer stays on the incremental O(changed) path — a
        steady fleet pays zero per-step column work regardless of P.
        """
        blk = self._part_blocks.get(key)
        if blk is not None and blk[0] == self.data_rev:
            return blk[1], blk[2]
        if len(self._part_blocks) >= self._PART_BLOCK_MAX:
            self._part_blocks.clear()
        t = (self.avg_time_s[None, :] * np.asarray(remote_frac)[:, None]
             + np.asarray(comm_s)[:, None])
        e = self.power[None, :] * (t * 1000.0) / 3.6e6
        self._part_blocks[key] = (self.data_rev, t, e)
        return t, e

    # -- masks -------------------------------------------------------------
    def node_ok(self, latency_threshold_ms: float = float("inf")) -> np.ndarray:
        """(N,) Algorithm-1 line-3 filter: overload cut-off plus the
        policy's latency threshold, ANDed with the resilience availability
        mask when one is attached (DESIGN.md §10) — so every cached scorer
        path (tensor, column, Pallas, partition) masks down/broken nodes
        vectorized, never by Python filtering."""
        ok = self.load <= LOAD_THRESHOLD
        if latency_threshold_ms != float("inf"):
            ok = ok & (self.avg_time_ms <= latency_threshold_ms)
        if self.avail is not None:
            ok = ok & self.avail
        return ok

    def feasible(self, task_cpu: np.ndarray, task_mem: np.ndarray,
                 latency_threshold_ms: float = float("inf")) -> np.ndarray:
        """(B, N) feasibility for B tasks given as (B,) cpu/mem arrays —
        the vectorized ``node_feasible`` (+ latency filter)."""
        return (self.node_ok(latency_threshold_ms)[None, :]
                & (self.free_cpu[None, :] >= np.asarray(task_cpu)[:, None])
                & (self.free_mem[None, :] >= np.asarray(task_mem)[:, None]))
