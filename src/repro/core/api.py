"""CarbonEdge public API (DESIGN.md §1): providers, policies, engine.

Three abstractions unify what the seed implemented four divergent times:

- :class:`CarbonIntensityProvider` — the *only* way schedulers, routers and
  the CarbonMonitor read grid intensity. :class:`StaticProvider` wraps the
  per-node regional constants (paper §IV.A static scenario),
  :class:`TraceProvider` wraps diurnal :class:`~repro.core.temporal.IntensityTrace`
  signals, and :class:`ForecastProvider` composes over any base provider
  (persistence lead + smoothing — an Electricity Maps-style forecast feed).

- :class:`SchedulingPolicy` (protocol) — one scoring rule (paper Eq. 3/4,
  Algorithm 1), three implementations in :mod:`repro.core.policy`:
  ``WeightedScoringPolicy`` (scalar oracle), ``VectorizedPolicy`` (batched
  numpy / Pallas ``node_score`` kernel — the default), and
  ``TemporalPolicy`` (slot-grid deferral as a time-indexed feature column).

- :class:`CarbonEdgeEngine` — the facade: ``submit``/``step``/``run``/
  ``report``. ``step`` scores B pending tasks against N nodes in a single
  scorer call (one Pallas kernel launch on TPU) instead of one Python loop
  per task.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.carbon import CarbonMonitor
from repro.core.cluster import EdgeCluster, TaskResult
from repro.core.scheduler import MODES, Task, Weights


# ---------------------------------------------------------------------------
# Carbon intensity providers
# ---------------------------------------------------------------------------


@runtime_checkable
class CarbonIntensityProvider(Protocol):
    """Single source of grid carbon intensity (gCO2/kWh) per node/region."""

    def intensity(self, node: str, hour: float = 0.0) -> float:
        ...


@dataclass(frozen=True)
class StaticProvider:
    """Time-invariant regional intensities (paper §IV.A scenario)."""

    table: Mapping[str, float]
    default: Optional[float] = None

    def intensity(self, node: str, hour: float = 0.0) -> float:
        v = self.table.get(node, self.default)
        if v is None:
            raise KeyError(f"no carbon intensity registered for {node!r}")
        return v

    @classmethod
    def from_cluster(cls, cluster: EdgeCluster) -> "StaticProvider":
        return cls({name: st.spec.carbon_intensity
                    for name, st in cluster.nodes.items()})

    @classmethod
    def from_pods(cls, pods: Sequence) -> "StaticProvider":
        return cls({p.name: p.carbon_intensity for p in pods})


@dataclass(frozen=True)
class TraceProvider:
    """Diurnal per-node traces (anything with ``.at(hour)``), falling back
    to another provider for nodes without a trace."""

    traces: Mapping[str, object]          # node -> IntensityTrace-like
    fallback: Optional[CarbonIntensityProvider] = None

    def intensity(self, node: str, hour: float = 0.0) -> float:
        tr = self.traces.get(node)
        if tr is not None:
            return tr.at(hour)
        if self.fallback is not None:
            return self.fallback.intensity(node, hour)
        raise KeyError(f"no trace or fallback intensity for {node!r}")


@dataclass(frozen=True)
class FallbackProvider:
    """Try ``primary``, fall back to ``fallback`` for uncovered nodes —
    e.g. a partial trace feed over the fleet's static regional values."""

    primary: CarbonIntensityProvider
    fallback: CarbonIntensityProvider

    def intensity(self, node: str, hour: float = 0.0) -> float:
        try:
            return self.primary.intensity(node, hour)
        except KeyError:
            return self.fallback.intensity(node, hour)


@dataclass(frozen=True)
class ForecastProvider:
    """Composable forecast view over any base provider.

    ``lead_hours`` shifts the query time (persistence forecast for a
    deferral decision made now about time t+lead); ``smoothing_hours``
    averages the base signal over a centred window, modelling forecast
    uncertainty flattening out short-lived dips.
    """

    base: CarbonIntensityProvider
    lead_hours: float = 0.0
    smoothing_hours: float = 0.0
    samples: int = 5

    def intensity(self, node: str, hour: float = 0.0) -> float:
        t = hour + self.lead_hours
        if self.smoothing_hours <= 0.0:
            return self.base.intensity(node, t)
        half = self.smoothing_hours / 2.0
        ts = np.linspace(t - half, t + half, max(2, self.samples))
        return float(np.mean([self.base.intensity(node, float(x)) for x in ts]))

    def window(self, node: str, start_hour: float, end_hour: float,
               step_hours: float = 0.5) -> np.ndarray:
        """Forecast series over [start, end) — used for deferral planning."""
        ts = np.arange(start_hour, end_hour, step_hours)
        return np.array([self.intensity(node, float(t)) for t in ts])


# ---------------------------------------------------------------------------
# Scheduling policy protocol (implementations: repro/core/policy.py)
# ---------------------------------------------------------------------------


@runtime_checkable
class SchedulingPolicy(Protocol):
    """One scoring rule (Eq. 3/4), pluggable execution strategy."""

    name: str

    def select(self, cluster: EdgeCluster, task: Task, weights: Weights,
               provider: Optional[CarbonIntensityProvider] = None,
               now_hour: float = 0.0) -> Optional[str]:
        ...

    def select_batch(self, cluster: EdgeCluster, tasks: Sequence[Task],
                     weights: Weights,
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        ...


# ---------------------------------------------------------------------------
# Engine facade
# ---------------------------------------------------------------------------


class NoFeasibleNodeError(RuntimeError):
    """A task in the batch had no feasible placement.

    ``executed`` holds the TaskResults of batch tasks that completed (and
    were billed) before the failure; the failing task and the unexecuted
    tail are back at the head of the engine queue.
    """

    def __init__(self, executed: List[TaskResult]):
        super().__init__("no feasible node")
        self.executed = executed


class CarbonEdgeEngine:
    """Batched carbon-aware scheduling engine (DESIGN.md §1.3).

    Owns a cluster, a policy, an intensity provider and a CarbonMonitor.
    ``step()`` drains up to ``batch_size`` pending tasks, scoring the whole
    batch against all N nodes in one vectorised/Pallas call, then executes
    placements and bills energy per region through the provider.
    """

    def __init__(self, cluster: EdgeCluster, *, mode: str = "green",
                 weights: Optional[Weights] = None,
                 policy: Optional[SchedulingPolicy] = None,
                 provider: Optional[CarbonIntensityProvider] = None,
                 monitor: Optional[CarbonMonitor] = None,
                 batch_size: Optional[int] = None):
        self.cluster = cluster
        self.weights = weights if weights is not None else MODES[mode]
        self.provider = provider or StaticProvider.from_cluster(cluster)
        if policy is None:
            from repro.core.policy import VectorizedPolicy
            policy = VectorizedPolicy()
        self.policy = policy
        self.batch_size = batch_size
        self.queue: List[Task] = []
        self.monitor = monitor or CarbonMonitor(provider=self.provider)
        if self.monitor.provider is None:
            # Caller-supplied provider-less monitor: adopt the engine's
            # provider so both ledgers (cluster execution and monitor
            # billing) read the same, possibly time-varying, signal.
            self.monitor.provider = self.provider
        elif self.monitor.provider is not self.provider:
            # A monitor wired to a DIFFERENT provider would silently bill
            # from the wrong grid signal; that is only sound if every
            # cluster region is pre-registered with a pinned intensity.
            for name in cluster.nodes:
                acc = self.monitor.regions.get(name)
                if acc is None or not acc.pinned:
                    raise ValueError(
                        "caller-supplied monitor is wired to a different "
                        f"CarbonIntensityProvider and region {name!r} is "
                        "not pinned; share the engine's provider or pin "
                        "every cluster region explicitly")
        for name in cluster.nodes:
            if name not in self.monitor.regions:
                # same PUE as the cluster's execution ledger, so totals and
                # per_region carbon agree
                self.monitor.register_region(name, pue=cluster.pue)

    # -- request lifecycle -------------------------------------------------
    def submit(self, task: Task) -> "CarbonEdgeEngine":
        self.queue.append(task)
        return self

    def submit_many(self, tasks: Sequence[Task]) -> "CarbonEdgeEngine":
        self.queue.extend(tasks)
        return self

    def peek(self, limit: Optional[int] = None) -> List[Task]:
        """The tasks the next :meth:`step` would drain, without dequeuing —
        a public inspection hook for drivers and operators (the bundled
        sim driver mirrors the queue itself and steps with ``limit``)."""
        b = limit if limit is not None else (self.batch_size or len(self.queue))
        return list(self.queue[:b])

    def step(self, now_hour: float = 0.0,
             limit: Optional[int] = None) -> List[TaskResult]:
        """Place and execute one batch of pending tasks.

        Selection for the whole batch is a single ``select_batch`` call —
        with the default VectorizedPolicy that is one (B, N, 8) featurize
        plus one kernel/scorer invocation, not B Python loops. ``limit``
        overrides ``batch_size`` for this call (partial drain — the sim
        driver steps exactly the tasks whose arrival events have fired).
        """
        if not self.queue:
            return []
        b = limit if limit is not None else (self.batch_size or len(self.queue))
        batch, self.queue = self.queue[:b], self.queue[b:]
        results: List[TaskResult] = []
        try:
            choices = self.policy.select_batch(
                self.cluster, batch, self.weights, provider=self.provider,
                now_hour=now_hour)
            for task, node in zip(batch, choices):
                if node is None:
                    # Already-executed results travel on the exception; the
                    # infeasible task and the tail are requeued below.
                    raise NoFeasibleNodeError(results)
                st = self.cluster.nodes[node]
                # Resolve every billing input BEFORE executing, so a
                # provider/monitor lookup failure cannot leave a task
                # executed in the cluster ledger yet requeued for a retry
                # (which would double-execute it).
                exec_intensity = self.provider.intensity(node, now_hour)
                self.monitor.billing_intensity(node, now_hour)
                st.running += 1
                try:
                    res = self.cluster.execute(
                        node, task.base_latency_ms, distributed=True,
                        intensity=exec_intensity)
                finally:
                    st.running -= 1
                self.monitor.record_energy(node, res.energy_kwh,
                                           hour=now_hour)
                results.append(res)
        except BaseException:
            # On ANY failure (infeasible node, provider KeyError, execution
            # error) put everything not successfully executed back at the
            # head of the queue, so submitted work is never silently lost.
            self.queue = list(batch[len(results):]) + self.queue
            raise
        return results

    def run(self, tasks: Optional[Sequence[Task]] = None, *,
            task: Optional[Task] = None, iterations: int = 1,
            now_hour: float = 0.0) -> Dict:
        """Submit ``tasks`` (or ``iterations`` copies of ``task``, default
        one), drain the queue in batched steps, and return :meth:`report`.

        .. deprecated:: the whole queue is drained at a single frozen
           ``now_hour``, which silently mis-bills time-varying providers
           (every batch reads the grid at the submission instant, however
           long the drain takes). With a non-static provider prefer
           :meth:`run_until` (minimal time-advancing drain) or the full
           event-driven :class:`repro.sim.AsyncEngineDriver`; this shim
           stays exact for the static paper scenarios.
        """
        if not isinstance(self.provider, StaticProvider):
            warnings.warn(
                "CarbonEdgeEngine.run drains the queue at one frozen "
                "now_hour; with a time-varying CarbonIntensityProvider use "
                "run_until() or repro.sim.AsyncEngineDriver so billing "
                "tracks simulated time", DeprecationWarning, stacklevel=2)
        if tasks is not None:
            self.submit_many(tasks)
        if task is not None:
            self.submit_many([task] * iterations)
        while self.queue:
            self.step(now_hour)
        return self.report()

    def run_until(self, end_hour: float, *, start_hour: float = 0.0,
                  limit: Optional[int] = None) -> Dict:
        """Drain the queue in batched steps while *advancing simulated
        time*: each batch is billed at the hour the previous batches'
        measured service time has accumulated to (the cluster is a serial
        executor, so a batch of total latency L ms advances the clock by
        L / 3.6e6 hours). Stops when the queue is empty or the clock
        passes ``end_hour`` (the remainder stays queued). Returns
        :meth:`report` plus the final clock under ``"end_hour"``.

        This is the minimal time-advancing replacement for :meth:`run`;
        arrival dynamics, deferral and queueing metrics live in the full
        event-driven :class:`repro.sim.AsyncEngineDriver`.
        """
        now = start_hour
        while self.queue and now < end_hour:
            results = self.step(now, limit=limit)
            if not results:
                # zero-size limit or a step that drained nothing: no
                # progress is possible, bail instead of spinning forever
                break
            now += sum(r.latency_ms for r in results) / 3.6e6
        rep = self.report()
        rep["end_hour"] = now
        return rep

    # -- reporting ---------------------------------------------------------
    def report(self) -> Dict:
        return {
            "totals": self.cluster.totals(),
            "distribution": self.cluster.distribution(),
            "policy": self.policy.name,
            "per_region": self.monitor.report(),
        }
