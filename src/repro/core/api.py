"""CarbonEdge public API (DESIGN.md §1): providers, policies, engine.

Three abstractions unify what the seed implemented four divergent times:

- :class:`CarbonIntensityProvider` — the *only* way schedulers, routers and
  the CarbonMonitor read grid intensity. :class:`StaticProvider` wraps the
  per-node regional constants (paper §IV.A static scenario),
  :class:`TraceProvider` wraps diurnal :class:`~repro.core.temporal.IntensityTrace`
  signals, and :class:`ForecastProvider` composes over any base provider
  (persistence lead + smoothing — an Electricity Maps-style forecast feed).

- :class:`SchedulingPolicy` (protocol) — one scoring rule (paper Eq. 3/4,
  Algorithm 1), three implementations in :mod:`repro.core.policy`:
  ``WeightedScoringPolicy`` (scalar oracle), ``VectorizedPolicy`` (batched
  numpy / Pallas ``node_score`` kernel — the default), and
  ``TemporalPolicy`` (slot-grid deferral as a time-indexed feature column).

- :class:`CarbonEdgeEngine` — the facade: ``submit``/``step``/``run``/
  ``report``. ``step`` scores B pending tasks against N nodes in a single
  scorer call (one Pallas kernel launch on TPU) instead of one Python loop
  per task.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from time import perf_counter
from typing import Dict, List, Mapping, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.carbon import CarbonMonitor
from repro.core.cluster import EdgeCluster, TaskResult
from repro.core.energy import carbon_g
from repro.core.scheduler import MODES, Task, Weights


# ---------------------------------------------------------------------------
# Carbon intensity providers
# ---------------------------------------------------------------------------


@runtime_checkable
class CarbonIntensityProvider(Protocol):
    """Single source of grid carbon intensity (gCO2/kWh) per node/region.

    Providers *may* additionally implement the batched form
    ``intensity_batch(names, hours)`` (see :func:`intensity_batch` for the
    contract); callers go through the module-level helper, which falls back
    to per-name ``intensity`` calls for providers that don't.
    """

    def intensity(self, node: str, hour: float = 0.0) -> float:
        ...


def intensity_batch(provider: CarbonIntensityProvider,
                    names: Sequence[str], hours) -> np.ndarray:
    """Batched provider read — the fleet-scale hot path (DESIGN.md §3).

    ``hours`` is a scalar or an (S,) array; returns ``(N,)`` respectively
    ``(S, N)`` gCO2/kWh for the N ``names``. Dispatches to the provider's
    vectorized ``intensity_batch`` when it has one (all bundled providers
    do); any custom provider is served by a per-name/per-hour fallback loop
    with identical semantics — including raising ``KeyError`` for uncovered
    nodes, so partial-coverage masking stays with the caller.
    """
    fn = getattr(provider, "intensity_batch", None)
    if fn is not None:
        return fn(names, hours)
    h = np.asarray(hours, dtype=float)
    if h.ndim == 0:
        return np.array([provider.intensity(n, float(h)) for n in names])
    return np.array([[provider.intensity(n, float(t)) for n in names]
                     for t in h])


def intensity_interval_batch(provider: CarbonIntensityProvider,
                             names: Sequence[str], hours,
                             coverage: float = 0.9):
    """Batched ``(lo, hi)`` conformal intensity interval read (DESIGN.md
    §8): each array shaped like :func:`intensity_batch`'s result.

    Dispatches to the provider's ``intensity_interval_batch`` when it has
    one (all bundled providers do — measured signals answer zero-width
    intervals, a calibrated :class:`ForecastProvider` answers its
    split-conformal band); any other provider degrades to the degenerate
    point interval ``lo == hi == intensity_batch(...)``, which keeps every
    risk-bounded caller exact-but-risk-blind rather than failing.
    """
    fn = getattr(provider, "intensity_interval_batch", None)
    if fn is not None:
        return fn(names, hours, coverage=coverage)
    v = np.asarray(intensity_batch(provider, names, hours), dtype=float)
    return v, v.copy()


def _point_interval(vals):
    v = np.asarray(vals, dtype=float)
    return v, v.copy()


@dataclass(frozen=True)
class StaticProvider:
    """Time-invariant regional intensities (paper §IV.A scenario)."""

    table: Mapping[str, float]
    default: Optional[float] = None

    # Hour-independent: the FeatureCache may reuse answers across steps.
    TIME_INVARIANT = True

    def intensity(self, node: str, hour: float = 0.0) -> float:
        v = self.table.get(node, self.default)
        if v is None:
            raise KeyError(f"no carbon intensity registered for {node!r}")
        return v

    def intensity_batch(self, names: Sequence[str], hours) -> np.ndarray:
        vals = np.array([self.intensity(n) for n in names], dtype=float)
        h = np.asarray(hours, dtype=float)
        if h.ndim == 0:
            return vals
        return np.broadcast_to(vals, (h.size, len(names))).copy()

    def intensity_interval_batch(self, names: Sequence[str], hours,
                                 coverage: float = 0.9):
        # Registered constants are exact: zero-width interval.
        return _point_interval(self.intensity_batch(names, hours))

    def covers(self, node: str) -> bool:
        return self.default is not None or node in self.table

    @classmethod
    def from_cluster(cls, cluster: EdgeCluster) -> "StaticProvider":
        return cls({name: st.spec.carbon_intensity
                    for name, st in cluster.nodes.items()})

    @classmethod
    def from_pods(cls, pods: Sequence) -> "StaticProvider":
        return cls({p.name: p.carbon_intensity for p in pods})


@dataclass(frozen=True)
class TraceProvider:
    """Diurnal per-node traces (anything with ``.at(hour)``), falling back
    to another provider for nodes without a trace."""

    traces: Mapping[str, object]          # node -> IntensityTrace-like
    fallback: Optional[CarbonIntensityProvider] = None

    def intensity(self, node: str, hour: float = 0.0) -> float:
        tr = self.traces.get(node)
        if tr is not None:
            return tr.at(hour)
        if self.fallback is not None:
            return self.fallback.intensity(node, hour)
        raise KeyError(f"no trace or fallback intensity for {node!r}")

    def intensity_batch(self, names: Sequence[str], hours) -> np.ndarray:
        from repro.core.temporal import IntensityTrace

        h = np.asarray(hours, dtype=float)
        hs = h.reshape(-1)
        out = np.empty((hs.size, len(names)))
        missing = []
        rows, row_cols = [], []
        for j, n in enumerate(names):
            tr = self.traces.get(n)
            if tr is None:
                missing.append(j)
                continue
            # Joint interpolation only for genuine IntensityTrace semantics
            # (a user trace with a .values table but its own .at must keep
            # its own sampling — batch must stay bit-identical to scalar).
            if type(tr).at is IntensityTrace.at:
                rows.append(tr.values)     # hourly table: joint interpolation
                row_cols.append(j)
            else:
                # a user-supplied trace type: sample through its .at —
                # array-aware when it accepts arrays, per hour otherwise
                try:
                    out[:, j] = tr.at(hs)
                except (TypeError, ValueError):
                    out[:, j] = [tr.at(float(t)) for t in hs]
        if rows:
            # one joint interpolation over all (name, hour) pairs, through
            # the same arithmetic IntensityTrace.at evaluates
            from repro.core.temporal import interp_hourly

            V = np.asarray(rows, dtype=float)              # (M, 24)
            out[:, row_cols] = interp_hourly(V, hs).T      # (M, S) -> (S, M)
        if missing:
            if self.fallback is None:
                raise KeyError(
                    f"no trace or fallback intensity for {names[missing[0]]!r}")
            sub = intensity_batch(self.fallback,
                                  [names[j] for j in missing], hs)
            out[:, missing] = np.asarray(sub).reshape(hs.size, len(missing))
        return out[0] if h.ndim == 0 else out

    def intensity_interval_batch(self, names: Sequence[str], hours,
                                 coverage: float = 0.9):
        # Traces are the measured ground-truth signal: zero-width for
        # traced nodes; untraced nodes get the fallback's intervals.
        h = np.asarray(hours, dtype=float)
        hs = h.reshape(-1)
        lo = np.empty((hs.size, len(names)))
        hi = np.empty((hs.size, len(names)))
        have = [j for j, n in enumerate(names) if n in self.traces]
        miss = [j for j in range(len(names)) if j not in set(have)]
        if have:
            v = np.asarray(self.intensity_batch([names[j] for j in have],
                                                hs)).reshape(hs.size,
                                                             len(have))
            lo[:, have] = v
            hi[:, have] = v
        if miss:
            if self.fallback is None:
                raise KeyError(
                    f"no trace or fallback intensity for {names[miss[0]]!r}")
            sub_lo, sub_hi = intensity_interval_batch(
                self.fallback, [names[j] for j in miss], hs,
                coverage=coverage)
            lo[:, miss] = np.asarray(sub_lo).reshape(hs.size, len(miss))
            hi[:, miss] = np.asarray(sub_hi).reshape(hs.size, len(miss))
        return (lo[0], hi[0]) if h.ndim == 0 else (lo, hi)

    def covers(self, node: str) -> bool:
        if node in self.traces:
            return True
        cov = getattr(self.fallback, "covers", None)
        return bool(cov(node)) if cov is not None else self.fallback is not None

    @classmethod
    def from_csv(cls, source: str, *,
                 node_zones: Optional[Mapping[str, str]] = None,
                 fallback: Optional[CarbonIntensityProvider] = None,
                 zone_column: Optional[str] = None,
                 value_column: Optional[str] = None,
                 time_column: Optional[str] = None) -> "TraceProvider":
        """Build a provider from an ElectricityMaps-style regional CSV.

        ``node_zones`` maps node names onto CSV zones so a fleet can share
        a handful of regional feeds; omitted, the zones themselves are the
        keys (nodes named after their zone resolve directly).
        """
        zones = load_intensity_csv(source, zone_column=zone_column,
                                   value_column=value_column,
                                   time_column=time_column)
        if node_zones is None:
            traces: Dict[str, object] = dict(zones)
        else:
            traces = {}
            for node, zone in node_zones.items():
                if zone not in zones:
                    raise KeyError(
                        f"zone {zone!r} for node {node!r} not in CSV "
                        f"(zones: {sorted(zones)})")
                traces[node] = zones[zone]
        return cls(traces=traces, fallback=fallback)


_CSV_TIME_COLS = ("datetime", "timestamp", "hour", "time")
_CSV_ZONE_COLS = ("zone", "zone_name", "zone_key", "zone_id", "region")


def _csv_hour(text: str) -> float:
    """A CSV timestamp as simulator hours: numeric hours pass through;
    ISO datetimes become hours elapsed since midnight of the first day
    (callers subtract a common base, so only differences matter)."""
    try:
        return float(text)
    except ValueError:
        pass
    from datetime import datetime, timezone

    dt = datetime.fromisoformat(text.strip().replace("Z", "+00:00"))
    if dt.tzinfo is None:
        dt = dt.replace(tzinfo=timezone.utc)
    return dt.timestamp() / 3600.0


def load_intensity_csv(source: str, *,
                       zone_column: Optional[str] = None,
                       value_column: Optional[str] = None,
                       time_column: Optional[str] = None) -> Dict[str, object]:
    """Parse a regional carbon-intensity CSV (ElectricityMaps export
    shape: one row per (timestamp, zone)) into per-zone
    :class:`~repro.core.temporal.SeriesTrace` signals.

    ``source`` is a path, or the CSV text itself when it contains a
    newline. Columns are auto-detected unless named explicitly: time from
    ``datetime``/``timestamp``/``hour``/``time``, zone from ``zone``/
    ``zone_name``/``zone_key``/``zone_id``/``region`` (a single-zone CSV
    may omit it — zone ``""``), value from the first header mentioning
    ``carbon_intensity`` then ``intensity``. Rows per zone are sorted by
    time and must be uniformly spaced; ISO datetimes are rebased so the
    earliest stamp in the file is hour-of-day of that stamp (a midnight-
    started day trace lands on hours 0..23, matching ``IntensityTrace``).
    """
    import csv
    import io

    from repro.core.temporal import SeriesTrace

    if "\n" in source:
        fh = io.StringIO(source)
    else:
        fh = open(source, newline="")
    try:
        reader = csv.DictReader(fh)
        headers = [h.strip() for h in (reader.fieldnames or [])]
        low = {h.lower(): h for h in headers}

        def pick(explicit, candidates, what, required=True):
            if explicit is not None:
                if explicit not in headers:
                    raise KeyError(f"{what} column {explicit!r} not in CSV "
                                   f"header {headers}")
                return explicit
            for c in candidates:
                if c in low:
                    return low[c]
            if required:
                raise KeyError(f"no {what} column found in CSV header "
                               f"{headers}")
            return None

        tcol = pick(time_column, _CSV_TIME_COLS, "time")
        zcol = pick(zone_column, _CSV_ZONE_COLS, "zone", required=False)
        if value_column is not None:
            vcol = pick(value_column, (), "value")
        else:
            vcol = next((h for h in headers
                         if "carbon_intensity" in h.lower()),
                        None) or next((h for h in headers
                                       if "intensity" in h.lower()), None)
            if vcol is None:
                raise KeyError(
                    f"no carbon-intensity column found in CSV header "
                    f"{headers}")

        rows: Dict[str, List[tuple]] = {}
        iso_seen = False
        for rec in reader:
            t_text = (rec.get(tcol) or "").strip()
            v_text = (rec.get(vcol) or "").strip()
            if not t_text or not v_text:
                continue      # ElectricityMaps exports gap rows as blanks
            try:
                float(t_text)
            except ValueError:
                iso_seen = True
            zone = (rec.get(zcol) or "").strip() if zcol else ""
            rows.setdefault(zone, []).append((_csv_hour(t_text),
                                              float(v_text)))
        if not rows:
            raise ValueError("CSV contains no intensity rows")

        if iso_seen:
            # Rebase absolute epoch-hours so the file's earliest stamp
            # keeps its hour-of-day and everything else is relative to it.
            t0 = min(t for series in rows.values() for t, _ in series)
            base = t0 - (t0 % 24.0)
            rows = {z: [(t - base, v) for t, v in series]
                    for z, series in rows.items()}

        out: Dict[str, object] = {}
        for zone, series in rows.items():
            series.sort(key=lambda tv: tv[0])
            hours = [t for t, _ in series]
            values = [v for _, v in series]
            if len(hours) > 1:
                steps = np.diff(np.asarray(hours, dtype=float))
                step = float(steps[0])
                if step <= 0 or not np.allclose(steps, step, rtol=1e-6,
                                                atol=1e-9):
                    raise ValueError(
                        f"zone {zone!r}: rows are not uniformly spaced "
                        f"in time (steps {sorted(set(steps.tolist()))[:4]})")
            else:
                step = 1.0
            out[zone] = SeriesTrace(region=zone, values=tuple(values),
                                    start_hour=float(hours[0]),
                                    step_hours=step)
        return out
    finally:
        fh.close()


@dataclass(frozen=True)
class FallbackProvider:
    """Try ``primary``, fall back to ``fallback`` for uncovered nodes —
    e.g. a partial trace feed over the fleet's static regional values."""

    primary: CarbonIntensityProvider
    fallback: CarbonIntensityProvider

    def intensity(self, node: str, hour: float = 0.0) -> float:
        try:
            return self.primary.intensity(node, hour)
        except KeyError:
            return self.fallback.intensity(node, hour)

    def intensity_batch(self, names: Sequence[str], hours) -> np.ndarray:
        # Fast split when the primary can report coverage (all bundled
        # providers can): two batched calls, no per-name machinery.
        cov = getattr(self.primary, "covers", None)
        if cov is not None:
            try:
                covered = [j for j, n in enumerate(names) if cov(n)]
                if len(covered) == len(names):
                    return np.asarray(intensity_batch(self.primary, names,
                                                      hours))
                h = np.asarray(hours, dtype=float)
                hs = h.reshape(-1)
                out = np.empty((hs.size, len(names)))
                uncovered = [j for j in range(len(names))
                             if j not in set(covered)]
                if covered:
                    sub = intensity_batch(self.primary,
                                          [names[j] for j in covered], hs)
                    out[:, covered] = np.asarray(sub).reshape(hs.size,
                                                              len(covered))
                sub = intensity_batch(self.fallback,
                                      [names[j] for j in uncovered], hs)
                out[:, uncovered] = np.asarray(sub).reshape(hs.size,
                                                            len(uncovered))
                return out[0] if h.ndim == 0 else out
            except KeyError:
                pass      # optimistic covers(): degrade to per-name below
        else:
            try:
                return np.asarray(intensity_batch(self.primary, names,
                                                  hours))
            except KeyError:
                pass
        # Coverage-opaque primary: resolve per name (each name still
        # batched over all hours).
        h = np.asarray(hours, dtype=float)
        hs = h.reshape(-1)
        cols = []
        for n in names:
            try:
                col = intensity_batch(self.primary, [n], hs)
            except KeyError:
                col = intensity_batch(self.fallback, [n], hs)
            cols.append(np.asarray(col).reshape(hs.size))
        out = np.stack(cols, axis=1)
        return out[0] if h.ndim == 0 else out

    def intensity_interval_batch(self, names: Sequence[str], hours,
                                 coverage: float = 0.9):
        # Planning-path read (not per-step hot): resolve per name so each
        # node gets ITS provider's interval — primary when covered,
        # fallback otherwise — with the same KeyError-degradation rule as
        # the point read above.
        h = np.asarray(hours, dtype=float)
        hs = h.reshape(-1)
        lo = np.empty((hs.size, len(names)))
        hi = np.empty((hs.size, len(names)))
        cov = getattr(self.primary, "covers", None)
        for j, n in enumerate(names):
            use_primary = bool(cov(n)) if cov is not None else True
            sub = None
            if use_primary:
                try:
                    sub = intensity_interval_batch(self.primary, [n], hs,
                                                   coverage=coverage)
                except KeyError:
                    sub = None
            if sub is None:
                sub = intensity_interval_batch(self.fallback, [n], hs,
                                               coverage=coverage)
            lo[:, j] = np.asarray(sub[0]).reshape(hs.size)
            hi[:, j] = np.asarray(sub[1]).reshape(hs.size)
        return (lo[0], hi[0]) if h.ndim == 0 else (lo, hi)


@dataclass(frozen=True)
class ForecastProvider:
    """Composable forecast view over any base provider.

    ``lead_hours`` shifts the query time (persistence forecast for a
    deferral decision made now about time t+lead); ``smoothing_hours``
    averages the base signal over a centred window, modelling forecast
    uncertainty flattening out short-lived dips.

    ``conformal`` optionally attaches a split-conformal residual
    calibrator (anything with ``quantile(coverage) -> float``, e.g.
    :class:`repro.partition.uncertainty.SplitConformal` built by
    ``calibrate_intensity``): ``intensity_interval_batch`` then answers
    the symmetric conformal band around the forecast instead of a
    zero-width point interval.
    """

    base: CarbonIntensityProvider
    lead_hours: float = 0.0
    smoothing_hours: float = 0.0
    samples: int = 5
    conformal: Optional[object] = None

    def intensity(self, node: str, hour: float = 0.0) -> float:
        t = hour + self.lead_hours
        if self.smoothing_hours <= 0.0:
            return self.base.intensity(node, t)
        half = self.smoothing_hours / 2.0
        ts = np.linspace(t - half, t + half, max(2, self.samples))
        return float(np.mean([self.base.intensity(node, float(x)) for x in ts]))

    def intensity_batch(self, names: Sequence[str], hours) -> np.ndarray:
        h = np.asarray(hours, dtype=float)
        t = h + self.lead_hours
        if self.smoothing_hours <= 0.0:
            return np.asarray(intensity_batch(self.base, names,
                                              t if t.ndim else float(t)))
        half = self.smoothing_hours / 2.0
        # np.linspace over array endpoints evaluates the exact scalar-path
        # sample times per hour; mean over the sample axis matches the
        # scalar np.mean ordering, keeping batch == scalar bit-identical.
        ts = np.linspace(t - half, t + half, max(2, self.samples))  # (K, ...)
        ts2 = ts.reshape(ts.shape[0], -1)                           # (K, S)
        grids = [np.asarray(intensity_batch(self.base, names, ts2[k]))
                 for k in range(ts2.shape[0])]
        out = np.mean(grids, axis=0)                                # (S, N)
        return out[0] if h.ndim == 0 else out

    def intensity_interval_batch(self, names: Sequence[str], hours,
                                 coverage: float = 0.9):
        pred = np.asarray(self.intensity_batch(names, hours), dtype=float)
        if self.conformal is None:
            return pred, pred.copy()
        q = float(self.conformal.quantile(coverage))
        # Intensities are non-negative physical quantities: clip the lower
        # band at zero rather than promising a negative grid.
        return np.maximum(pred - q, 0.0), pred + q

    def window(self, node: str, start_hour: float, end_hour: float,
               step_hours: float = 0.5) -> np.ndarray:
        """Forecast series over [start, end) — used for deferral planning."""
        ts = np.arange(start_hour, end_hour, step_hours)
        return np.array([self.intensity(node, float(t)) for t in ts])


# ---------------------------------------------------------------------------
# Scheduling policy protocol (implementations: repro/core/policy.py)
# ---------------------------------------------------------------------------


@runtime_checkable
class SchedulingPolicy(Protocol):
    """One scoring rule (Eq. 3/4), pluggable execution strategy."""

    name: str

    def select(self, cluster: EdgeCluster, task: Task, weights: Weights,
               provider: Optional[CarbonIntensityProvider] = None,
               now_hour: float = 0.0) -> Optional[str]:
        ...

    def select_batch(self, cluster: EdgeCluster, tasks: Sequence[Task],
                     weights: Weights,
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        ...


# ---------------------------------------------------------------------------
# Engine facade
# ---------------------------------------------------------------------------


class NoFeasibleNodeError(RuntimeError):
    """A task in the batch had no feasible placement.

    ``executed`` holds the TaskResults of batch tasks that completed (and
    were billed) before the failure; the failing task and the unexecuted
    tail are back at the head of the engine queue.
    """

    def __init__(self, executed: List[TaskResult]):
        super().__init__("no feasible node")
        self.executed = executed


class CarbonEdgeEngine:
    """Batched carbon-aware scheduling engine (DESIGN.md §1.3).

    Owns a cluster, a policy, an intensity provider and a CarbonMonitor.
    ``step()`` drains up to ``batch_size`` pending tasks, scoring the whole
    batch against all N nodes in one vectorised/Pallas call, then executes
    placements and bills energy per region through the provider.
    """

    def __init__(self, cluster: EdgeCluster, *, mode: str = "green",
                 weights: Optional[Weights] = None,
                 policy: Optional[SchedulingPolicy] = None,
                 provider: Optional[CarbonIntensityProvider] = None,
                 monitor: Optional[CarbonMonitor] = None,
                 batch_size: Optional[int] = None,
                 batch_execute: bool = True,
                 obs=None, resilience=None, max_requeues: int = 5):
        self.cluster = cluster
        # Batched execute+billing fast path (DESIGN.md §6), on by default;
        # False forces the per-task loop — the bit-exact parity oracle
        # (same pattern as featurize vs featurize_cached).
        self.batch_execute = batch_execute
        self.weights = weights if weights is not None else MODES[mode]
        self.provider = provider or StaticProvider.from_cluster(cluster)
        if policy is None:
            from repro.core.policy import VectorizedPolicy
            policy = VectorizedPolicy()
        self.policy = policy
        # Multi-tenant admission protocol (DESIGN.md §7): a policy exposing
        # plan()/charge() (e.g. repro.tenancy.TenantPolicy) gets per-task
        # admit/defer/reject decisions applied before selection, and
        # executed carbon charged back per tenant.
        self._tenancy = (policy if callable(getattr(policy, "plan", None))
                         and callable(getattr(policy, "charge", None))
                         else None)
        self.batch_size = batch_size
        self.queue: List[Task] = []
        # Budget-deferred tasks parked until their tenant's next accounting
        # period: (wake_hour, task) in decision order. Drained by
        # pop_ripe() (the sim driver) or automatically by run_until().
        self.deferred: List[tuple] = []
        # Per-drained-task outcomes of the last step(), for drivers that
        # must track rejected/deferred work: a list of
        # ("done", TaskResult) | ("reject", reason) | ("defer", wake_hour)
        # in drained order — or None, meaning every drained task produced
        # a TaskResult in order (the tenancy-free fast path pays no
        # per-task Python to say so). After a step that raised, entries
        # cover the consumed tasks and None marks requeued ones.
        self.last_outcomes: Optional[List[tuple]] = None
        self.monitor = monitor or CarbonMonitor(provider=self.provider)
        if self.monitor.provider is None:
            # Caller-supplied provider-less monitor: adopt the engine's
            # provider so both ledgers (cluster execution and monitor
            # billing) read the same, possibly time-varying, signal.
            self.monitor.provider = self.provider
        elif self.monitor.provider is not self.provider:
            # A monitor wired to a DIFFERENT provider would silently bill
            # from the wrong grid signal; that is only sound if every
            # cluster region is pre-registered with a pinned intensity.
            for name in cluster.nodes:
                acc = self.monitor.regions.get(name)
                if acc is None or not acc.pinned:
                    raise ValueError(
                        "caller-supplied monitor is wired to a different "
                        f"CarbonIntensityProvider and region {name!r} is "
                        "not pinned; share the engine's provider or pin "
                        "every cluster region explicitly")
        for name in cluster.nodes:
            if name not in self.monitor.regions:
                # same PUE as the cluster's execution ledger, so totals and
                # per_region carbon agree
                self.monitor.register_region(name, pue=cluster.pue)
        # Cheap always-on step accounting (surfaced by report()): steps
        # drained and cumulative done/reject/defer verdict totals ("dead"
        # and "retry" keys appear only once such an outcome occurred, so
        # pre-resilience report consumers see an unchanged dict).
        self._steps = 0
        self._outcome_totals = {"done": 0, "reject": 0, "defer": 0}
        # Requeue-loop guard (DESIGN.md §10): a task failing at the queue
        # head `max_requeues` consecutive times stops re-raising and is
        # consumed as a ("dead", reason) outcome instead — submitted work
        # is never silently lost, but a permanently infeasible/unknown-node
        # task can no longer livelock retrying callers. The first
        # max_requeues-1 failures raise exactly as before.
        if max_requeues < 1:
            raise ValueError("max_requeues must be >= 1")
        self.max_requeues = max_requeues
        self._fail_task = None
        self._fail_count = 0
        self.dead_letters: List[tuple] = []     # (task, reason)
        # Failure-aware scheduling (DESIGN.md §10): a repro.resilience.
        # Resilience attaches the availability mask / circuit breakers to
        # the cluster's FeatureCache, gates every placement against the
        # ground-truth down set (failover re-placement), and converts
        # unplaceable tasks into backoff retries that dead-letter after
        # max_attempts. None (the default) keeps every path bit-identical.
        self.resilience = resilience
        self._attempts: Dict[int, int] = {}     # id(task) -> attempts so far
        if resilience is not None:
            resilience.bind(self)
        # Observability hub (DESIGN.md §9): a repro.obs.Observability with
        # any pillar enabled; None (the default) keeps every path
        # bit-identical at the cost of one `is not None` check per phase.
        self.obs = obs if obs is not None and obs.enabled else None
        self._exec_snapshot = None
        # Per-step execution columns (DESIGN.md §11): after a fully
        # successful batched-execute step, ``(uniq_nodes, inverse,
        # latency_ms, energy_kwh, carbon_g)`` arrays carrying the same
        # floats the step's TaskResults do — the sim driver's columnar
        # record path consumes them instead of re-gathering O(B)
        # attributes. None whenever the last step used the scalar path,
        # partially failed, or went through tenancy admission.
        self.last_exec = None
        # Original-batch positions the resilience gate re-placed off a
        # down/unknown node in the last step (DESIGN.md §12) — the sim
        # driver's JourneyTrace counts failover hops from this. None when
        # the gate did not fire or nothing needed re-placement.
        self.last_failover_pos = None
        if self.obs is not None:
            self._wire_obs()

    def _wire_obs(self) -> None:
        """Attach the enabled obs pillars to the policy's duck-typed hooks
        (`capture_scores` publishes winning/runner-up totals on
        ``policy.last_scores``; `profiler` receives featurize/score
        spans), and resolve the engine's mode index for the trace."""
        obs, pol = self.obs, self.policy
        if obs.trace is not None and hasattr(pol, "capture_scores"):
            pol.capture_scores = True
        if obs.profiler is not None and hasattr(pol, "profiler"):
            pol.profiler = obs.profiler
        # == repro.obs.MODE_LABELS == repro.tenancy.spec.MODE_ORDER
        labels = ("performance", "balanced", "green")
        self._mode_idx = next((i for i, m in enumerate(labels)
                               if MODES[m] == self.weights), -1)

    # -- request lifecycle -------------------------------------------------
    def submit(self, task: Task) -> "CarbonEdgeEngine":
        self.queue.append(task)
        return self

    def submit_many(self, tasks: Sequence[Task]) -> "CarbonEdgeEngine":
        self.queue.extend(tasks)
        return self

    def peek(self, limit: Optional[int] = None) -> List[Task]:
        """The tasks the next :meth:`step` would drain, without dequeuing —
        a public inspection hook for drivers and operators (the bundled
        sim driver mirrors the queue itself and steps with ``limit``)."""
        b = limit if limit is not None else (self.batch_size or len(self.queue))
        return list(self.queue[:b])

    def step(self, now_hour: float = 0.0,
             limit: Optional[int] = None) -> List[TaskResult]:
        """Place and execute one batch of pending tasks.

        Selection for the whole batch is a single ``select_batch`` call —
        with the default VectorizedPolicy that is one (B, N, 8) featurize
        plus one kernel/scorer invocation, not B Python loops. ``limit``
        overrides ``batch_size`` for this call (partial drain — the sim
        driver steps exactly the tasks whose arrival events have fired).
        """
        self.last_outcomes = None
        self._exec_snapshot = None
        self.last_exec = None
        self.last_failover_pos = None
        if not self.queue:
            return []
        b = limit if limit is not None else (self.batch_size or len(self.queue))
        batch, self.queue = self.queue[:b], self.queue[b:]
        results: List[TaskResult] = []
        self._steps += 1
        if self._tenancy is not None:
            return self._step_tenancy(batch, now_hour, results)
        obs = self.obs
        prof = obs.profiler if obs is not None else None
        res = self.resilience
        outcomes = exec_pos = None   # set iff the resilience gate fired
        exec_batch: Sequence[Task] = batch
        try:
            if res is not None:
                res.tick(now_hour)
            t0 = perf_counter() if prof is not None else 0.0
            choices = self.policy.select_batch(
                self.cluster, batch, self.weights, provider=self.provider,
                now_hour=now_hour)
            if prof is not None:
                prof.add("select", perf_counter() - t0)
            # Partitioned-execution hook (DESIGN.md §8): a policy exposing
            # execution_latency_ms (e.g. repro.partition.PartitionPolicy)
            # makes the engine execute and bill only the offloaded
            # segment's effective latency. Both execute paths consume the
            # same array, preserving batched/scalar parity.
            eff_fn = getattr(self.policy, "execution_latency_ms", None)
            base_override = eff_fn(batch) if eff_fn is not None else None
            # Failure-aware gate (DESIGN.md §10): only when something is
            # actually wrong — a ground-truth down node or an unplaceable
            # task — otherwise the zero-fault path is untouched.
            if res is not None and (res.down or None in choices):
                outcomes = [None] * len(batch)
                (exec_batch, choices, base_override,
                 exec_pos, _, _) = self._apply_resilience(
                     batch, choices, base_override, now_hour, outcomes,
                     list(range(len(batch))))
            if self.batch_execute:
                self._execute_batched(exec_batch, choices, now_hour,
                                      results, base_override)
            else:
                self._execute_scalar(exec_batch, choices, now_hour, results,
                                     base_override)
            if res is not None:
                if res.health.suspect:
                    res.note_success(set(choices[:len(results)]))
                if self._attempts:
                    for t in exec_batch:
                        self._attempts.pop(id(t), None)
        except BaseException as err:
            tail = list(exec_batch[len(results):])
            self._outcome_totals["done"] += len(results)
            dead = (tail[0] if tail and self._note_failure(tail[0])
                    else None)
            if dead is None:
                # On ANY failure (infeasible node, provider KeyError,
                # execution error) put everything not successfully executed
                # back at the head of the queue, so submitted work is never
                # silently lost.
                self.queue = tail + self.queue
                if outcomes is not None:
                    for j, r in zip(exec_pos, results):
                        outcomes[j] = ("done", r)
                    self.last_outcomes = outcomes
                raise
            # max_requeues-th consecutive failure of the same head task:
            # consume it as a dead letter instead of requeuing it into an
            # infinite raise/requeue loop (DESIGN.md §10)
            reason = f"{type(err).__name__}: {err}"
            self._record_dead(dead, reason)
            if outcomes is None:
                self.queue = tail[1:] + self.queue
                self.last_outcomes = ([("done", r) for r in results]
                                      + [("dead", reason)])
            else:
                # gate-fired step: park the unexecuted survivors as
                # immediate retries so every consumed position carries an
                # outcome (drivers stay aligned with the drained batch)
                for j, r in zip(exec_pos, results):
                    outcomes[j] = ("done", r)
                outcomes[exec_pos[len(results)]] = ("dead", reason)
                for j, t in zip(exec_pos[len(results) + 1:], tail[1:]):
                    self.deferred.append((now_hour, t))
                    self._outcome_totals["retry"] = \
                        self._outcome_totals.get("retry", 0) + 1
                    outcomes[j] = ("retry", now_hour)
                self.last_outcomes = outcomes
            return results
        self._outcome_totals["done"] += len(results)
        if outcomes is not None:
            for j, r in zip(exec_pos, results):
                outcomes[j] = ("done", r)
            self.last_outcomes = outcomes
        if obs is not None:
            # success-only (failed steps requeue and re-trace on retry)
            self._obs_record_step(obs, results, now_hour)
        return results

    def _note_failure(self, task) -> bool:
        """Track the consecutive-failure streak of the task at the failure
        point; True once it has exhausted ``max_requeues`` attempts."""
        if task is self._fail_task:
            self._fail_count += 1
        else:
            self._fail_task = task
            self._fail_count = 1
        if self._fail_count < self.max_requeues:
            return False
        self._fail_task = None
        self._fail_count = 0
        return True

    def _record_dead(self, task, reason: str) -> None:
        self._outcome_totals["dead"] = \
            self._outcome_totals.get("dead", 0) + 1
        self.dead_letters.append((task, reason))
        self._attempts.pop(id(task), None)

    def _apply_resilience(self, tasks, choices, base_override, now_hour,
                          outcomes, pos):
        """The failure-aware gate between selection and execution
        (DESIGN.md §10). Two stages:

        1. **failover**: any task placed onto a ground-truth-down (or
           unknown) node is a *contact failure* — breaker accounting plus
           detection-by-contact masking — and its subset is re-scored in
           one batched ``select_batch`` against the updated availability
           mask. A partition policy re-bills failed-over tasks through
           ``fallback_latency_ms`` (the cut-0 full-offload column): the
           stranded split is discarded and the whole model re-runs on the
           new node.
        2. **retry/dead-letter**: tasks still unplaceable park on
           ``self.deferred`` with capped exponential backoff (a
           ``("retry", wake)`` outcome) until ``max_attempts``, then
           dead-letter.

        ``outcomes`` (full original-batch length) is written in place at
        the removed tasks' ``pos`` entries. Returns the placed subset:
        ``(tasks, choices, base_override, pos, keep, removed)`` with
        ``keep``/``removed`` indexing the *incoming* lists.
        """
        res = self.resilience
        down = res.down
        nodes = self.cluster.nodes
        choices = list(choices)
        bad = [i for i, ch in enumerate(choices)
               if ch is not None and (ch in down or ch not in nodes)]
        if bad:
            self.last_failover_pos = [pos[i] for i in bad]
            for n in {choices[i] for i in bad}:
                res.contact_failure(n, now_hour)
            sub = [tasks[i] for i in bad]
            sub_choices = self.policy.select_batch(
                self.cluster, sub, self.weights, provider=self.provider,
                now_hour=now_hour)
            fb = getattr(self.policy, "fallback_latency_ms", None)
            if base_override is not None:
                base_override = np.array(base_override, dtype=float)
            for k, i in enumerate(bad):
                choices[i] = sub_choices[k]
                if (sub_choices[k] is not None and fb is not None
                        and base_override is not None):
                    base_override[i] = fb(tasks[i])
        keep = list(range(len(tasks)))
        removed: List[int] = []
        if None in choices:
            for i, ch in enumerate(choices):
                if ch is not None:
                    continue
                t = tasks[i]
                attempt = self._attempts.pop(id(t), 0) + 1
                if attempt >= res.max_attempts:
                    reason = f"no feasible node after {attempt} attempts"
                    self._record_dead(t, reason)
                    outcomes[pos[i]] = ("dead", reason)
                else:
                    self._attempts[id(t)] = attempt
                    wake = now_hour + res.backoff_hours(attempt)
                    self.deferred.append((wake, t))
                    self._outcome_totals["retry"] = \
                        self._outcome_totals.get("retry", 0) + 1
                    outcomes[pos[i]] = ("retry", wake)
                removed.append(i)
            keep = [i for i, ch in enumerate(choices) if ch is not None]
            tasks = [tasks[i] for i in keep]
            choices = [choices[i] for i in keep]
            if base_override is not None:
                base_override = np.asarray(base_override, dtype=float)[keep]
            pos = [pos[i] for i in keep]
        return tasks, choices, base_override, pos, keep, removed

    def _step_tenancy(self, batch: Sequence[Task], now_hour: float,
                      results: List[TaskResult]) -> List[TaskResult]:
        """Admission-controlled step (DESIGN.md §7): the tenant policy
        plans admit/defer/reject for the drained batch, rejected tasks
        are dropped (counted in the registry), deferred tasks park on
        ``self.deferred`` until their wake hour, and only the admitted
        subset is placed (mode-escalated), executed and billed — with the
        executed prefix's carbon charged back per tenant even when the
        batch fails mid-way."""
        obs = self.obs
        prof = obs.profiler if obs is not None else None
        res = self.resilience
        try:
            if res is not None:
                res.tick(now_hour)
            t0 = perf_counter() if prof is not None else 0.0
            plan = self.policy.plan(self.cluster, batch,
                                    provider=self.provider,
                                    now_hour=now_hour)
            if prof is not None:
                prof.add("plan", perf_counter() - t0)
        except BaseException:
            # admission itself failed (e.g. a partial-coverage provider
            # KeyError): nothing was consumed, so the whole batch requeues
            # — the same never-silently-lost invariant as the
            # tenancy-free path
            self.queue = list(batch) + self.queue
            raise
        outcomes: List[tuple] = [None] * len(batch)
        if plan.all_admitted:
            aidx = None
            exec_tasks: Sequence[Task] = batch
        else:
            from repro.tenancy.policy import DEFER as _DEFER
            from repro.tenancy.policy import REJECT as _REJECT
            aidx = plan.admitted_index()
            exec_tasks = [batch[i] for i in aidx]
            rej = np.nonzero(plan.actions == _REJECT)[0]
            deferred = np.nonzero(plan.actions == _DEFER)[0]
            for i in rej:
                outcomes[i] = ("reject", "carbon budget exhausted")
            for i in deferred:
                w = float(plan.wake_hour[i])
                self.deferred.append((w, batch[i]))
                outcomes[i] = ("defer", w)
            # rejected/deferred verdicts are consumed whatever happens next
            self._outcome_totals["reject"] += int(rej.size)
            self._outcome_totals["defer"] += int(deferred.size)
        # admitted tenant ids / original-batch positions, kept consistent
        # with exec_tasks through the resilience gate's rewrites
        sel = np.asarray(plan.tenant_idx if aidx is None
                         else plan.tenant_idx[aidx])
        pos = (list(range(len(batch))) if aidx is None
               else [int(i) for i in aidx])
        gate_fired = False
        dead_reason = None
        try:
            t0 = perf_counter() if prof is not None else 0.0
            full = self.policy.select_admitted(
                self.cluster, batch, plan, self.weights,
                provider=self.provider, now_hour=now_hour)
            if prof is not None:
                prof.add("select", perf_counter() - t0)
            choices = (full if aidx is None
                       else [full[i] for i in aidx])
            if res is not None and (res.down or None in choices):
                gate_fired = True
                (exec_tasks, choices, _, pos,
                 keep, removed) = self._apply_resilience(
                     exec_tasks, choices, None, now_hour, outcomes, pos)
                if removed:
                    # retried/dead tasks get re-planned (or never run):
                    # reverse their admitted counting now
                    self.policy.registry.uncount_admitted(sel[removed])
                    sel = sel[keep]
            if self.batch_execute:
                self._execute_batched(exec_tasks, choices, now_hour, results)
            else:
                self._execute_scalar(exec_tasks, choices, now_hour, results)
            if res is not None:
                if res.health.suspect:
                    res.note_success(set(choices[:len(results)]))
                if self._attempts:
                    for t in exec_tasks:
                        self._attempts.pop(id(t), None)
        except BaseException as err:
            requeued = list(exec_tasks[len(results):])
            if requeued:
                # requeued tasks get re-planned (and re-counted) on the
                # retry, so reverse this plan's admitted counting for them
                self.policy.registry.uncount_admitted(sel[len(results):])
            dead = (requeued[0] if requeued
                    and self._note_failure(requeued[0]) else None)
            if dead is None:
                self.queue = requeued + self.queue
                raise
            # attempt cap reached: consume the poisoned head as a dead
            # letter (DESIGN.md §10) and keep the step's results
            dead_reason = f"{type(err).__name__}: {err}"
            self._record_dead(dead, dead_reason)
            # park the unexecuted survivors as immediate retries so every
            # consumed position carries an outcome — admitted positions can
            # precede deferred/rejected ones, so a silent requeue would
            # desynchronize outcome-tracking drivers from the drained batch
            for j, t in zip(pos[len(results) + 1:], requeued[1:]):
                self.deferred.append((now_hour, t))
                self._outcome_totals["retry"] = \
                    self._outcome_totals.get("retry", 0) + 1
                outcomes[j] = ("retry", now_hour)
        finally:
            # charge exactly the executed prefix — on a mid-batch failure
            # that is the same set the cluster/monitor ledgers billed
            if results:
                self.policy.charge(sel[:len(results)],
                                   [r.carbon_g for r in results], now_hour)
            # publish verdicts even when execution raised mid-batch:
            # rejected/deferred tasks were consumed, so a caller tracking
            # per-request state must still see them; None marks the
            # requeued admitted tail
            for j, r in zip(pos, results):
                outcomes[j] = ("done", r)
            if dead_reason is not None:
                outcomes[pos[len(results)]] = ("dead", dead_reason)
            self.last_outcomes = outcomes
            self._outcome_totals["done"] += len(results)
        if dead_reason is not None:
            return results
        if obs is not None:
            # success-only, like the tenancy-free path
            self._obs_record_tenancy(obs, batch, plan, results, now_hour,
                                     aidx,
                                     exec_pos=pos if gate_fired else None)
        return results

    def pop_ripe(self, now_hour: float) -> List[Task]:
        """Remove and return budget-deferred tasks whose wake hour has
        arrived, in park order — the caller resubmits them (the sim
        driver does this on its tenancy DEFER_WAKE event;
        :meth:`run_until` does it automatically)."""
        if not self.deferred:
            return []
        ripe = [t for w, t in self.deferred if w <= now_hour]
        if ripe:
            self.deferred = [(w, t) for w, t in self.deferred
                             if w > now_hour]
        return ripe

    def _execute_scalar(self, batch: Sequence[Task],
                        choices: Sequence[Optional[str]], now_hour: float,
                        results: List[TaskResult],
                        base_override=None) -> None:
        """Per-task execute+bill loop — the parity oracle the batched path
        is bit-identical to (cluster/monitor ledgers, log, requeue state).
        ``base_override`` replaces each task's base latency (the policy's
        partitioned effective latency), same array the batched path uses."""
        for i, (task, node) in enumerate(zip(batch, choices)):
            if node is None:
                # Already-executed results travel on the exception; the
                # infeasible task and the tail are requeued by step().
                raise NoFeasibleNodeError(results)
            st = self.cluster.nodes[node]
            # Resolve every billing input BEFORE executing, so a
            # provider/monitor lookup failure cannot leave a task
            # executed in the cluster ledger yet requeued for a retry
            # (which would double-execute it).
            exec_intensity = self.provider.intensity(node, now_hour)
            self.monitor.billing_intensity(node, now_hour)
            base = (task.base_latency_ms if base_override is None
                    else float(base_override[i]))
            st.running += 1
            try:
                res = self.cluster.execute(
                    node, base, distributed=True,
                    intensity=exec_intensity)
            finally:
                st.running -= 1
            self.monitor.record_energy(node, res.energy_kwh,
                                       hour=now_hour)
            results.append(res)

    def _probe_intensities(self, nodes: Sequence[str], now_hour: float):
        """Scalar-order resolution fallback: probe node-by-node *in first-
        appearance order* so a failure cuts the batch at exactly the task
        the scalar loop would have failed on. Returns
        ``(exec_int, bill_int, n_ok, error)``: dicts covering the nodes of
        the first ``n_ok`` tasks, plus the captured per-node exception."""
        exec_int, bill_int = {}, {}
        for i, n in enumerate(nodes):
            if n in exec_int:
                continue
            try:
                # exactly the scalar loop's resolution order: node lookup,
                # provider read, monitor billing probe
                self.cluster.nodes[n]
                ei = self.provider.intensity(n, now_hour)
                bi = self.monitor.billing_intensity(n, now_hour)
            except Exception as err:
                return exec_int, bill_int, i, err
            exec_int[n] = ei
            bill_int[n] = bi
        return exec_int, bill_int, len(nodes), None

    def _execute_batched(self, batch: Sequence[Task],
                         choices: Sequence[Optional[str]], now_hour: float,
                         results: List[TaskResult],
                         base_override=None) -> None:
        """Vectorized execute+bill (DESIGN.md §6): one
        ``cluster.execute_batch`` + one ``monitor.record_energy_batch`` for
        the feasible prefix — O(distinct nodes) Python work per step
        instead of O(B) — preserving the scalar loop's mid-batch failure
        semantics: tasks before the first infeasible/unresolvable one are
        executed and billed, the rest requeue via step()'s handler.

        Every billing input resolves BEFORE anything executes (the scalar
        loop's commit rule): execution intensity through one batched
        provider read over the distinct chosen nodes, billing intensity
        through one ``monitor.billing_intensity_batch`` — degrading to the
        per-node probe (``_probe_intensities``) when any node is unknown
        or uncovered, so the failing task index matches the scalar loop's.
        """
        # Cut at the first infeasible task: the scalar loop executes
        # everything before it, then raises with those results attached.
        try:
            cut = choices.index(None)
            failure = NoFeasibleNodeError(results)
        except ValueError:
            cut, failure = len(batch), None
        nodes = list(choices[:cut])
        groups = ev = bv = None
        if nodes:
            groups = np.unique(np.asarray(nodes, dtype=object),
                               return_inverse=True)
            uniq, inverse = groups
            try:
                for n in uniq:
                    if n not in self.cluster.nodes:
                        raise KeyError(n)
                ev = np.asarray(intensity_batch(self.provider, list(uniq),
                                                now_hour), dtype=float)
                bv = self.monitor.billing_intensity_batch(list(uniq),
                                                          now_hour)
            except Exception:
                exec_int, bill_int, n_ok, err = self._probe_intensities(
                    nodes, now_hour)
                if err is None:
                    # batch read failed but every per-node probe succeeded
                    # (inconsistent custom provider): use the probed values
                    ev = np.array([exec_int[n] for n in uniq], dtype=float)
                    bv = np.array([bill_int[n] for n in uniq], dtype=float)
                else:
                    cut, failure = n_ok, err
                    nodes = nodes[:cut]
                    if nodes:
                        groups = np.unique(np.asarray(nodes, dtype=object),
                                           return_inverse=True)
                        uniq, inverse = groups
                        ev = np.array([exec_int[n] for n in uniq],
                                      dtype=float)
                        bv = np.array([bill_int[n] for n in uniq],
                                      dtype=float)
        if nodes:
            obs = self.obs
            prof = obs.profiler if obs is not None else None
            base = (np.array([t.base_latency_ms for t in batch[:cut]],
                             dtype=float)
                    if base_override is None
                    else np.asarray(base_override[:cut], dtype=float))
            t0 = perf_counter() if prof is not None else 0.0
            res = self.cluster.execute_batch(nodes, base, distributed=True,
                                             intensities=ev[inverse],
                                             groups=groups)
            if prof is not None:
                prof.add("execute", perf_counter() - t0)
                t0 = perf_counter()
            # The billed energy is recomputed through the cluster's own
            # cost model (the same call execute_batch makes) rather than
            # gathered back out of the B result objects — same floats, no
            # O(B) attribute reads, one source of truth for the math.
            lat_ms, e_kwh = self.cluster.latency_energy(base,
                                                        distributed=True)
            self.monitor.record_energy_batch(
                nodes, e_kwh, hour=now_hour, intensities=bv[inverse],
                groups=groups)
            if prof is not None:
                prof.add("bill", perf_counter() - t0)
            results.extend(res)
            if failure is None:
                # whole batch executed: publish the step's execution
                # columns for the sim driver's columnar record path
                # (DESIGN.md §11). carbon_g here is the same elementwise
                # expression execute_batch evaluated, so the arrays carry
                # the exact floats the TaskResults do.
                self.last_exec = (uniq, inverse, lat_ms, e_kwh,
                                  carbon_g(e_kwh, ev[inverse],
                                           self.cluster.pue))
            if obs is not None and (obs.trace is not None
                                    or obs.metrics is not None
                                    or obs.rollups is not None):
                # stash the already-computed batched arrays so the trace/
                # metrics record after a successful step adds no provider
                # re-reads or O(B) Python (DESIGN.md §9)
                self._exec_snapshot = (uniq, inverse, ev, bv, e_kwh)
        if failure is not None:
            # `results` is the shared list step() requeues against, so the
            # exception's executed-prefix view matches the scalar loop's.
            raise failure

    def run(self, tasks: Optional[Sequence[Task]] = None, *,
            task: Optional[Task] = None, iterations: int = 1,
            now_hour: float = 0.0) -> Dict:
        """Submit ``tasks`` (or ``iterations`` copies of ``task``, default
        one), drain the queue in batched steps, and return :meth:`report`.

        .. deprecated:: the whole queue is drained at a single frozen
           ``now_hour``, which silently mis-bills time-varying providers
           (every batch reads the grid at the submission instant, however
           long the drain takes). With a non-static provider prefer
           :meth:`run_until` (minimal time-advancing drain) or the full
           event-driven :class:`repro.sim.AsyncEngineDriver`; this shim
           stays exact for the static paper scenarios.
        """
        if not isinstance(self.provider, StaticProvider):
            warnings.warn(
                "CarbonEdgeEngine.run drains the queue at one frozen "
                "now_hour; with a time-varying CarbonIntensityProvider use "
                "run_until() or repro.sim.AsyncEngineDriver so billing "
                "tracks simulated time", DeprecationWarning, stacklevel=2)
        if tasks is not None:
            self.submit_many(tasks)
        if task is not None:
            self.submit_many([task] * iterations)
        while self.queue:
            self.step(now_hour)
        if self.deferred:
            # run() freezes the clock, so budget-deferred work can never
            # reach its wake hour here — tell the caller instead of
            # silently dropping it (run_until()/pop_ripe() resume it)
            warnings.warn(
                f"CarbonEdgeEngine.run left {len(self.deferred)} "
                "budget-deferred task(s) parked: the frozen now_hour "
                "never reaches their accounting-period wake; use "
                "run_until() or pop_ripe() to resume them",
                RuntimeWarning, stacklevel=2)
        return self.report()

    def run_until(self, end_hour: float, *, start_hour: float = 0.0,
                  limit: Optional[int] = None) -> Dict:
        """Drain the queue in batched steps while *advancing simulated
        time*: each batch is billed at the hour the previous batches'
        measured service time has accumulated to (the cluster is a serial
        executor, so a batch of total latency L ms advances the clock by
        L / 3.6e6 hours). Stops when the queue is empty or the clock
        passes ``end_hour`` (the remainder stays queued). Returns
        :meth:`report` plus the final clock under ``"end_hour"``.

        This is the minimal time-advancing replacement for :meth:`run`;
        arrival dynamics, deferral and queueing metrics live in the full
        event-driven :class:`repro.sim.AsyncEngineDriver`.
        """
        now = start_hour
        while now < end_hour:
            self.queue[:0] = self.pop_ripe(now)
            if not self.queue:
                # idle but budget-deferred work exists: jump the clock to
                # the earliest wake inside the window
                wake = min((w for w, _ in self.deferred if w < end_hour),
                           default=None)
                if wake is None:
                    break
                now = max(now, wake)
                continue
            qlen = len(self.queue)
            results = self.step(now, limit=limit)
            if not results and len(self.queue) >= qlen:
                # zero-size limit or a step that drained nothing: no
                # progress is possible, bail instead of spinning forever
                break
            now += sum(r.latency_ms for r in results) / 3.6e6
        rep = self.report()
        rep["end_hour"] = now
        return rep

    # -- observability (DESIGN.md §9) --------------------------------------
    def _obs_metrics_nodes(self, metrics, uniq, inverse, carbon) -> None:
        """Per-node task and carbon counters from the step's grouped
        arrays: O(distinct nodes) label interning, scatter-add updates."""
        counts = np.bincount(inverse, minlength=len(uniq))
        csum = np.bincount(inverse, weights=carbon, minlength=len(uniq))
        for name, help_, vals in (
                ("engine_tasks_total", "tasks executed per node", counts),
                ("engine_carbon_g_total",
                 "carbon billed per node (gCO2)", csum)):
            fam = metrics.counter(name, help_, ("node",))
            fam.inc_at(fam.rows([(str(n),) for n in uniq]), vals)

    def _obs_metrics_depths(self, metrics) -> None:
        metrics.gauge("engine_queue_depth",
                      "tasks pending in the engine queue"
                      ).set(float(len(self.queue)))
        metrics.gauge("engine_deferred_depth",
                      "budget-deferred tasks parked"
                      ).set(float(len(self.deferred)))

    def _obs_intervals(self, uniq, inverse, now_hour):
        """Conformal (lo, hi) per task when the provider carries a
        calibrator, else (None, None) — zero-width intervals from plain
        providers carry no information, so skip the extra read."""
        if getattr(self.provider, "conformal", None) is None:
            return None, None
        lo, hi = intensity_interval_batch(self.provider, list(uniq),
                                          now_hour)
        return (np.asarray(lo, dtype=float)[inverse],
                np.asarray(hi, dtype=float)[inverse])

    def _obs_record_step(self, obs, results, now_hour: float) -> None:
        """Trace + metrics for one successful tenancy-free step, fed from
        the batched-execute snapshot (no per-task Python; the scalar
        parity oracle falls back to gathering from its B results)."""
        trace, metrics = obs.trace, obs.metrics
        roll = obs.rollups
        if trace is None and metrics is None and roll is None:
            return
        prof = obs.profiler
        t0 = perf_counter() if prof is not None else 0.0
        B = len(results)
        if B == 0:
            return
        snap = self._exec_snapshot
        if snap is not None:
            uniq, inverse, ev, bv, e_kwh = snap
            ev_t = ev[inverse]
            # same expression execute_batch billed with — identical floats
            carbon = carbon_g(e_kwh, ev_t, self.cluster.pue)
        else:
            uniq, inverse = np.unique(
                np.asarray([r.node for r in results], dtype=object),
                return_inverse=True)
            ev = np.asarray(intensity_batch(self.provider, list(uniq),
                                            now_hour), dtype=float)
            ev_t = ev[inverse]
            bv = np.asarray(self.monitor.billing_intensity_batch(
                list(uniq), now_hour), dtype=float)
            carbon = np.asarray([r.carbon_g for r in results], dtype=float)
            e_kwh = (np.asarray([r.energy_kwh for r in results], dtype=float)
                     if roll is not None else None)
        if roll is not None:
            roll.fold_exec(now_hour, carbon, e_kwh)
            roll.fold_verdicts(now_hour, (B, 0, 0, 0, 0))  # all done
        if trace is not None:
            lo, hi = self._obs_intervals(uniq, inverse, now_hour)
            score = runner = cut = None
            ls = getattr(self.policy, "last_scores", None)
            if ls is not None and ls.get("score") is not None \
                    and len(ls["score"]) == B:
                score, runner = ls["score"], ls.get("runner_up")
                cut = ls.get("cut")
            trace.record_batch(
                step=self._steps, hour=now_hour,
                verdict=np.zeros(B, dtype=np.int8),   # all done
                node=trace.intern_names(uniq)[inverse],
                cut=cut, mode=self._mode_idx,
                score=score, runner_up=runner,
                intensity=ev_t, interval_lo=lo, interval_hi=hi,
                intensity_billed=bv[inverse], carbon_g=carbon)
        if metrics is not None:
            self._obs_metrics_nodes(metrics, uniq, inverse, carbon)
            metrics.counter("engine_outcomes_total",
                            "step outcomes by verdict", ("verdict",)
                            ).inc(B, labels=("done",))
            self._obs_metrics_depths(metrics)
        if prof is not None:
            prof.add("observe", perf_counter() - t0)

    def _obs_record_tenancy(self, obs, batch, plan, results, now_hour,
                            aidx, exec_pos=None) -> None:
        """Trace + metrics for one successful admission-controlled step:
        full-length rows (rejected/deferred tasks get their verdict with
        no placement), executed columns scattered at the admitted
        positions from the batched-execute snapshot. ``exec_pos`` (set
        when the resilience gate rewrote the admitted subset) overrides
        the executed positions and sources verdicts from the published
        outcomes, so retried/dead rows trace as such."""
        trace, metrics = obs.trace, obs.metrics
        roll = obs.rollups
        if trace is None and metrics is None and roll is None:
            return
        prof = obs.profiler
        t0 = perf_counter() if prof is not None else 0.0
        from repro.tenancy.policy import ADMIT as _ADMIT
        from repro.tenancy.policy import REJECT as _REJECT
        B = len(batch)
        if exec_pos is not None:
            from repro.obs.trace import VERDICT_LABELS
            codes = {k: c for c, k in enumerate(VERDICT_LABELS)}
            verdict = np.array([codes[o[0]] for o in self.last_outcomes],
                               dtype=np.int8)
            pos_exec = np.asarray(exec_pos[:len(results)], dtype=int)
        else:
            # explicit action -> trace-verdict map (the two encodings order
            # DEFER/REJECT differently)
            verdict = np.where(
                plan.actions == _ADMIT, 0,
                np.where(plan.actions == _REJECT, 1, 2)).astype(np.int8)
            pos_exec = (np.arange(len(results)) if aidx is None
                        else np.asarray(aidx))
        uniq = inverse = carbon = e_kwh = None
        if results:
            snap = self._exec_snapshot
            if snap is not None:
                uniq, inverse, ev, bv, e_kwh = snap
                ev_t = ev[inverse]
                carbon = carbon_g(e_kwh, ev_t, self.cluster.pue)
            else:
                uniq, inverse = np.unique(
                    np.asarray([r.node for r in results], dtype=object),
                    return_inverse=True)
                ev = np.asarray(intensity_batch(self.provider, list(uniq),
                                                now_hour), dtype=float)
                ev_t = ev[inverse]
                bv = np.asarray(self.monitor.billing_intensity_batch(
                    list(uniq), now_hour), dtype=float)
                carbon = np.asarray([r.carbon_g for r in results],
                                    dtype=float)
                e_kwh = (np.asarray([r.energy_kwh for r in results],
                                    dtype=float)
                         if roll is not None else None)
        if roll is not None:
            if results:
                roll.fold_exec(now_hour, carbon, e_kwh)
                reg = getattr(self.policy, "registry", None)
                index = getattr(reg, "index", None)
                if index:
                    names = np.asarray(sorted(index, key=index.get),
                                       dtype=object)
                    tmap = roll.intern_tenants(names)
                    tidx = np.asarray(plan.tenant_idx)[pos_exec]
                    tagged = tidx >= 0
                    if tagged.any():
                        roll.fold_tenant_spend(now_hour, tmap[tidx[tagged]],
                                               carbon[tagged])
            roll.fold_verdicts(
                now_hour, np.bincount(verdict, minlength=5)[:5])
        if trace is not None:
            node = np.full(B, -1, dtype=np.int32)
            intens = np.full(B, np.nan)
            billed = np.full(B, np.nan)
            carb = np.full(B, np.nan)
            ilo = ihi = None
            if results:
                node[pos_exec] = trace.intern_names(uniq)[inverse]
                intens[pos_exec] = ev_t
                billed[pos_exec] = bv[inverse]
                carb[pos_exec] = carbon
                lo, hi = self._obs_intervals(uniq, inverse, now_hour)
                if lo is not None:
                    ilo = np.full(B, np.nan)
                    ihi = np.full(B, np.nan)
                    ilo[pos_exec] = lo
                    ihi[pos_exec] = hi
            # -1 (untagged / no escalation) means the engine's own mode
            modes = np.where(plan.modes >= 0, plan.modes,
                             self._mode_idx).astype(np.int8)
            tenant = None
            reg = getattr(self.policy, "registry", None)
            index = getattr(reg, "index", None)
            if index:
                names = np.asarray(sorted(index, key=index.get),
                                   dtype=object)
                tmap = trace.intern_names(names, kind="tenant")
                tidx = np.asarray(plan.tenant_idx)
                tenant = np.where(tidx >= 0,
                                  tmap[np.maximum(tidx, 0)],
                                  -1).astype(np.int32)
            score = runner = cut = None
            ls = getattr(self.policy, "last_scores", None)
            if ls is not None and ls.get("score") is not None \
                    and len(ls["score"]) == B:
                score, runner = ls["score"], ls.get("runner_up")
                cut = ls.get("cut")
            trace.record_batch(
                step=self._steps, hour=now_hour, verdict=verdict,
                node=node, cut=cut, mode=modes, tenant=tenant,
                score=score, runner_up=runner,
                intensity=intens, interval_lo=ilo, interval_hi=ihi,
                intensity_billed=billed, carbon_g=carb,
                expected_g=plan.expected_g)
        if metrics is not None:
            if results:
                self._obs_metrics_nodes(metrics, uniq, inverse, carbon)
            fam = metrics.counter("engine_outcomes_total",
                                  "step outcomes by verdict", ("verdict",))
            for code, label in enumerate(
                    ("done", "reject", "defer", "dead", "retry")):
                n = int((verdict == code).sum())
                if n:
                    fam.inc(n, labels=(label,))
            self._obs_metrics_depths(metrics)
        if prof is not None:
            prof.add("observe", perf_counter() - t0)

    # -- reporting ---------------------------------------------------------
    def report(self, deep: bool = False) -> Dict:
        rep = {
            "totals": self.cluster.totals(),
            "distribution": self.cluster.distribution(),
            "policy": self.policy.name,
            "per_region": self.monitor.report(),
            "steps": self._steps,
            "outcomes": dict(self._outcome_totals),
            "deferred_depth": len(self.deferred),
        }
        if self._tenancy is not None:
            rep["tenants"] = self._tenancy.registry.report()
        if self.resilience is not None or self.dead_letters:
            rep["resilience"] = {
                "dead_letters": len(self.dead_letters),
                "retrying": len(self._attempts),
            }
            if self.resilience is not None:
                rep["resilience"].update(self.resilience.report())
        if deep:
            rep["deep"] = self._report_deep()
        return rep

    def _report_deep(self) -> Dict:
        """Structured diagnostics (DESIGN.md §9): obs pillar summaries
        plus partition / deferral / conformal-coverage aggregates. A
        diagnostic call — may do O(retained-trace) work."""
        deep: Dict = {}
        obs = self.obs
        if obs is not None:
            if obs.profiler is not None:
                deep["profiler"] = obs.profiler.summary()
            if obs.trace is not None:
                deep["trace"] = obs.trace.stats()
                deep["conformal"] = obs.trace.conformal_coverage()
                cuts = obs.trace.cut_histogram()
                if cuts:
                    deep["partition"] = {"cut_histogram": cuts}
            if obs.journeys is not None:
                deep["journeys"] = obs.journeys.stats()
            if obs.rollups is not None:
                deep["rollups"] = obs.rollups.stats()
            if obs.alerts is not None:
                deep["alerts"] = obs.alerts.stats()
            if obs.metrics is not None:
                deep["metrics"] = obs.metrics.snapshot()
        deep["deferral"] = {
            "parked": len(self.deferred),
            "deferred_total": self._outcome_totals["defer"],
            "next_wake": (min(w for w, _ in self.deferred)
                          if self.deferred else None),
        }
        # last-batch partition decisions work without tracing too
        decisions = getattr(self.policy, "last_decisions", None)
        if decisions:
            hist: Dict[int, int] = {}
            for d in decisions:
                if d is not None:
                    hist[d.cut_index] = hist.get(d.cut_index, 0) + 1
            deep.setdefault("partition", {})["last_batch_cuts"] = hist
        return deep
