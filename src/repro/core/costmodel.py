"""Layer cost models.

Paper Eq. 5 (CNNs):
    Cost(l) = kh*kw*Cin*Cout   (Conv2D)
              Nin*Nout         (Linear)
              params_count     (others)

plus the transformer/MoE/SSM generalisation that the green partitioner and
the carbon monitor use for the assigned architectures: per-block parameter
counts, FLOPs and boundary-activation bytes.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import CNNConfig, ConvLayerDef, LayerDef, ModelConfig


# ---------------------------------------------------------------------------
# Paper Eq. 5 — CNN layer cost
# ---------------------------------------------------------------------------


def cnn_layer_cost(layer: ConvLayerDef) -> float:
    if layer.kind == "conv":
        return float(layer.k * layer.k * layer.cin * layer.cout)
    if layer.kind == "dwconv":
        # Depthwise = Conv2D with Cout channels of 1-in-group: kh*kw*Cin.
        return float(layer.k * layer.k * layer.cin)
    if layer.kind == "linear":
        return float(layer.cin * layer.cout)
    if layer.kind == "se":
        return float(2 * layer.cin * layer.cout + layer.cin + layer.cout)  # params_count
    return 0.0  # pool / act: negligible ("others" with ~0 params)


def cnn_costs(cfg: CNNConfig) -> List[float]:
    return [cnn_layer_cost(layer) for layer in cfg.layers]


# ---------------------------------------------------------------------------
# Transformer block costs (generalisation for the assigned architectures)
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = D * H * hd + 2 * D * K * hd + H * hd * D
    if cfg.qkv_bias:
        n += H * hd + 2 * K * hd
    if cfg.qk_norm:
        n += 2 * hd
    return n


def _mlp_params(cfg: ModelConfig, d_ff: int, gated: bool = True) -> int:
    return cfg.d_model * d_ff * (3 if gated else 2)


def _moe_params(cfg: ModelConfig, active_only: bool = False) -> int:
    m = cfg.moe
    e = m.top_k if active_only else m.num_experts
    n = e * 3 * cfg.d_model * m.expert_ff + cfg.d_model * m.num_experts
    if m.num_shared_experts:
        n += _mlp_params(cfg, m.num_shared_experts * m.expert_ff) + cfg.d_model
    if m.dense_residual_ff:
        n += _mlp_params(cfg, m.dense_residual_ff)
    return n


def _mamba2_params(cfg: ModelConfig) -> int:
    from repro.models import ssm

    inner, H, conv_dim = ssm.dims(cfg)
    s = cfg.ssm
    proj_out = 2 * inner + 2 * s.num_groups * s.state_dim + H
    return (cfg.d_model * proj_out + s.conv_width * conv_dim + conv_dim
            + 3 * H + inner + inner * cfg.d_model)


def _mlstm_params(cfg: ModelConfig) -> int:
    from repro.models import xlstm

    inner, H, hd = xlstm.mlstm_dims(cfg)
    return (cfg.d_model * 2 * inner + cfg.xlstm.conv_width * inner + inner
            + 3 * inner * inner + 2 * inner * H + 2 * H + inner
            + inner * cfg.d_model)


def _slstm_params(cfg: ModelConfig) -> int:
    from repro.models import xlstm

    H, hd = xlstm.slstm_dims(cfg)
    D = cfg.d_model
    ff = int(cfg.xlstm.slstm_proj_factor * D)
    gates = 4 * (D * H * hd + H * hd * hd + H * hd)
    return gates + D + 3 * D * ff


def block_params(cfg: ModelConfig, ld: LayerDef, active_only: bool = False) -> int:
    D = cfg.d_model
    if ld.kind == "attn":
        n = _attn_params(cfg) + 2 * D  # + norms
        if cfg.cross_attention:
            n += _attn_params(cfg) + D
        if cfg.moe is not None:
            n += _moe_params(cfg, active_only)
        elif cfg.d_ff > 0:
            n += _mlp_params(cfg, cfg.d_ff, cfg.mlp_gated)
        return n
    if ld.kind == "mamba2":
        return _mamba2_params(cfg) + D
    if ld.kind == "mlstm":
        return _mlstm_params(cfg) + D
    if ld.kind == "slstm":
        return _slstm_params(cfg) + D
    raise ValueError(ld.kind)


def model_param_count(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size
    n += sum(block_params(cfg, ld) for ld in cfg.layer_defs)
    if cfg.encoder_layers:
        n += cfg.encoder_layers * (_attn_params(cfg)
                                   + _mlp_params(cfg, cfg.d_ff, cfg.mlp_gated)
                                   + 2 * cfg.d_model)
    return n


def model_active_param_count(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size
    n += sum(block_params(cfg, ld, active_only=True) for ld in cfg.layer_defs)
    if cfg.encoder_layers:
        n += cfg.encoder_layers * (_attn_params(cfg)
                                   + _mlp_params(cfg, cfg.d_ff, cfg.mlp_gated)
                                   + 2 * cfg.d_model)
    return n


def block_flops(cfg: ModelConfig, ld: LayerDef, seq: int, batch: int,
                kind: str = "fwd", kv_len: int = 0) -> float:
    """Approximate forward FLOPs per block.

    kind: "fwd" (full sequence) or "decode" (one token, cache kv_len).
    Matmul FLOPs = 2*m*n*k; attention quadratic term included (window-aware).
    """
    tokens = batch * (1 if kind == "decode" else seq)
    f = 2.0 * tokens * block_params(cfg, ld, active_only=True)
    if ld.kind == "attn":
        ctx = kv_len if kind == "decode" else seq
        if ld.window is not None:
            ctx = min(ctx, ld.window)
        if kind == "decode":
            f += 4.0 * batch * cfg.num_heads * cfg.head_dim * ctx
        else:
            # causal: ~S*ctx/2 scores per head
            eff = ctx if ld.window is not None else seq / 2.0
            f += 4.0 * batch * cfg.num_heads * cfg.head_dim * seq * eff
    elif ld.kind == "mamba2":
        s = cfg.ssm
        inner, H, _ = __import__("repro.models.ssm", fromlist=["dims"]).dims(cfg)
        L = s.chunk_size if kind != "decode" else 1
        f += 2.0 * tokens * H * (L * s.state_dim + 2 * s.state_dim * s.head_dim)
    elif ld.kind == "mlstm":
        from repro.models import xlstm

        inner, H, hd = xlstm.mlstm_dims(cfg)
        ctx = 1 if kind == "decode" else seq / 2.0
        f += 4.0 * tokens * H * hd * ctx if kind != "decode" else 4.0 * batch * H * hd * hd
    return f


def boundary_bytes(cfg: ModelConfig, seq: int, batch: int, dtype_bytes: int = 2) -> int:
    """Activation bytes crossing a partition boundary between blocks."""
    return batch * seq * cfg.d_model * dtype_bytes


# ---------------------------------------------------------------------------
# Analytic HBM traffic model (TPU-fused pipeline)
#
# The CPU-backend cost_analysis() reports *unfused* bytes — every convert /
# broadcast / multiply billed at full tensor size — which overstates HBM
# traffic by ~10-30x vs a fused TPU pipeline. The roofline memory term
# therefore uses this structural model: weights + optimizer traffic,
# fusion-boundary activation tensors, KV/state cache traffic. The HLO
# number is kept alongside as an upper bound.
# ---------------------------------------------------------------------------

_ACT_B = 2          # bf16 activations
_F32_B = 4
_Q_BLOCK = 1024     # attention kv re-read granularity (flash q-block)


def _block_act_bytes(cfg: ModelConfig, ld: LayerDef, tokens: int, seq: int,
                     kind: str) -> float:
    """Fusion-boundary activation traffic (read+write) for one block, fwd."""
    D = cfg.d_model
    b = 0.0
    rw = 2 * _ACT_B  # write + read back
    if ld.kind == "attn":
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        b += tokens * D * rw * 2                 # block in/out residual
        b += tokens * (H + 2 * K) * hd * rw      # q, k, v
        b += tokens * H * hd * rw                # attn out pre-proj
        if kind != "decode":
            ctx = seq if ld.window is None else min(seq, ld.window)
            nb = max(1, seq // _Q_BLOCK)
            b += nb * tokens / max(seq, 1) * ctx * 2 * K * hd * _ACT_B  # kv re-reads
        if cfg.moe is not None:
            m = cfg.moe
            cap = tokens * m.top_k * 1.25
            b += cap * D * rw * 2                # grouped in/out buffers
            b += cap * m.expert_ff * rw          # expert hidden
            if m.num_shared_experts:
                b += tokens * m.num_shared_experts * m.expert_ff * rw
            if m.dense_residual_ff:
                b += tokens * m.dense_residual_ff * rw
        elif cfg.d_ff > 0:
            b += tokens * cfg.d_ff * rw * (2 if cfg.mlp_gated else 1)
    elif ld.kind == "mamba2":
        from repro.models import ssm as ssm_mod

        inner, H, conv_dim = ssm_mod.dims(cfg)
        s = cfg.ssm
        b += tokens * D * rw * 2
        b += tokens * (2 * inner + conv_dim) * rw
        if kind != "decode":
            nc = max(1, seq // s.chunk_size)
            b += (tokens / max(seq, 1)) * nc * H * s.state_dim * s.head_dim * _F32_B * 2
    elif ld.kind == "mlstm":
        from repro.models import xlstm as xl

        inner, H, hd = xl.mlstm_dims(cfg)
        b += tokens * D * rw * 2
        b += tokens * inner * rw * 5              # x_m, z, q, k, v
    elif ld.kind == "slstm":
        H, hd = 0, 0
        ff = int(cfg.xlstm.slstm_proj_factor * D)
        b += tokens * D * rw * 2
        b += tokens * D * 4 * rw                  # gate pre-activations
        b += tokens * ff * rw * 2
    return b


def _cache_bytes(cfg: ModelConfig, seq: int, batch: int) -> float:
    """KV/state cache read+write traffic for one decode step."""
    total = 0.0
    for ld in cfg.layer_defs:
        if ld.kind == "attn":
            ctx = seq if ld.window is None else min(seq, ld.window)
            total += batch * ctx * 2 * cfg.num_kv_heads * cfg.head_dim * _ACT_B
            if cfg.cross_attention:
                total += batch * cfg.encoder_seq * 2 * cfg.num_kv_heads * cfg.head_dim * _ACT_B
        elif ld.kind == "mamba2":
            from repro.models import ssm as ssm_mod

            inner, H, conv_dim = ssm_mod.dims(cfg)
            total += 2 * batch * H * cfg.ssm.state_dim * cfg.ssm.head_dim * _F32_B
            total += 2 * batch * (cfg.ssm.conv_width - 1) * conv_dim * _ACT_B
        elif ld.kind == "mlstm":
            from repro.models import xlstm as xl

            inner, H, hd = xl.mlstm_dims(cfg)
            total += 2 * batch * H * hd * hd * _F32_B
        elif ld.kind == "slstm":
            H, hd = cfg.xlstm.num_heads, cfg.d_model // cfg.xlstm.num_heads
            total += 8 * batch * H * hd * _F32_B
    return total


def step_hbm_bytes(cfg: ModelConfig, seq: int, batch: int, kind: str) -> float:
    """Whole-step analytic HBM bytes (global, all chips combined)."""
    p_act = model_active_param_count(cfg)
    tokens = batch * (1 if kind == "decode" else seq)
    wb = _ACT_B * p_act
    act = sum(_block_act_bytes(cfg, ld, tokens, seq, kind)
              for ld in cfg.layer_defs)
    if cfg.encoder_layers and kind != "decode":
        enc_tokens = batch * cfg.encoder_seq
        from repro.configs.base import LayerDef as LD

        act += cfg.encoder_layers * _block_act_bytes(
            cfg, LD("attn"), enc_tokens, cfg.encoder_seq, kind)
    # lm head / loss logits traffic (chunked: logits written+read once)
    logits = tokens * cfg.vocab_size * _F32_B if kind == "train" else \
        batch * cfg.vocab_size * _F32_B
    if kind == "train":
        p_tot = model_param_count(cfg)
        # fwd + remat + bwd weight reads, grad write/read, AdamW f32 state r/w
        # + f32 master-param r/w.
        weight_traffic = 3 * wb + 2 * wb + 4 * _F32_B * p_tot + 2 * _F32_B * p_tot
        return weight_traffic + 3 * act + 2 * logits
    if kind == "prefill":
        return wb + act + logits + _cache_bytes(cfg, seq, batch)  # cache write
    # decode
    return wb + act + logits + _cache_bytes(cfg, seq, batch)
