"""Green request router: the paper's NSA applied at pod/mesh-slice scale.

Each serving *domain* (a TPU pod or mesh slice in a grid region) is a
NodeSpec; requests are routed with the same Eq. 3 scoring, with E_est
derived from the compiled step's roofline terms instead of wall-clock
history (core/carbon.record_step).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import energy as energy_mod
from repro.core.api import (CarbonIntensityProvider, FallbackProvider,
                            StaticProvider)
from repro.core.carbon import CarbonMonitor
from repro.core.cluster import EdgeCluster, NodeSpec
from repro.core.energy import RooflineTerms
from repro.core.scheduler import MODES, Task


@dataclass(frozen=True)
class PodSpec:
    name: str
    chips: int
    region: str
    carbon_intensity: float
    chip_power_w: float = energy_mod.CHIP_POWER_W


class GreenRouter:
    """Routes inference batches across pods; accounts carbon per region.

    Routing goes through a :class:`~repro.core.api.SchedulingPolicy`
    (default: the vectorized/Pallas path) and an intensity provider — pods'
    static regional values unless a TraceProvider/ForecastProvider is
    injected for time-varying grids.
    """

    def __init__(self, pods: List[PodSpec], mode: str = "green",
                 policy=None,
                 provider: Optional[CarbonIntensityProvider] = None):
        nodes = [
            NodeSpec(p.name, cpu=1.0, mem_mb=1 << 20,
                     carbon_intensity=p.carbon_intensity,
                     power_w=p.chips * p.chip_power_w, region=p.region)
            for p in pods
        ]
        self.pods = {p.name: p for p in pods}
        self.cluster = EdgeCluster(nodes=nodes, host_power_w=0.0)
        self.weights = MODES[mode]
        # An injected provider (e.g. a partial trace feed) falls back to
        # each pod's own static carbon_intensity for uncovered pods.
        static = StaticProvider.from_pods(pods)
        self.provider = (FallbackProvider(provider, static)
                         if provider is not None else static)
        if policy is None:
            from repro.core.policy import VectorizedPolicy
            policy = VectorizedPolicy()
        self.policy = policy
        self.monitor = CarbonMonitor(provider=self.provider)
        for p in pods:
            self.monitor.register_region(p.name)

    def seed_profile(self, step_terms: Dict[str, RooflineTerms]):
        """Seed per-pod history from each pod's compiled roofline step time."""
        for name, terms in step_terms.items():
            self.cluster.nodes[name].avg_time_ms = terms.step_time_s * 1e3

    def route(self, task: Optional[Task] = None, now_hour: float = 0.0) -> str:
        task = task or Task(cpu=0.0, mem_mb=0.0)
        choice = self.policy.select(self.cluster, task, self.weights,
                                    provider=self.provider, now_hour=now_hour)
        if choice is None:
            raise RuntimeError("no feasible pod")
        return choice

    def commit(self, pod_name: str, terms: RooflineTerms,
               hour: float = 0.0) -> float:
        """Account one executed batch on `pod_name`; returns gCO2."""
        pod = self.pods[pod_name]
        c = self.monitor.record_step(pod_name, terms, pod.chips,
                                     pod.chip_power_w, hour=hour)
        st = self.cluster.nodes[pod_name]
        st.completed += 1
        t_ms = terms.step_time_s * 1e3
        # Exponential moving average of history.
        st.avg_time_ms = 0.9 * st.avg_time_ms + 0.1 * t_ms if st.avg_time_ms else t_ms
        return c
