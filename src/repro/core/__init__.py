# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public API (DESIGN.md): providers + policies + engine, lazily re-exported
# so `import repro.core` stays cheap.

_API = {
    "CarbonEdgeEngine": "repro.core.api",
    "CarbonIntensityProvider": "repro.core.api",
    "SchedulingPolicy": "repro.core.api",
    "StaticProvider": "repro.core.api",
    "TraceProvider": "repro.core.api",
    "ForecastProvider": "repro.core.api",
    "FallbackProvider": "repro.core.api",
    "intensity_batch": "repro.core.api",
    "WeightedScoringPolicy": "repro.core.policy",
    "VectorizedPolicy": "repro.core.policy",
    "TemporalPolicy": "repro.core.policy",
    "featurize": "repro.core.policy",
    "featurize_cached": "repro.core.policy",
    "FeatureCache": "repro.core.featcache",
}

__all__ = sorted(_API)


def __getattr__(name):
    if name in _API:
        import importlib

        return getattr(importlib.import_module(_API[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
