"""Green Partitioning Strategy (paper §III.E).

Splits a model's layer list into contiguous segments for heterogeneous
nodes, balancing per-segment cost against node capacity while minimising
boundary (communication) bytes — and, in green mode, weighting capacity by
carbon efficiency so low-carbon nodes receive proportionally more work.

Works over two cost domains:
- CNNs: paper Eq. 5 costs (core/costmodel.cnn_costs) + activation bytes
  (models/cnn.activation_bytes);
- transformers: per-block FLOPs (core/costmodel.block_flops) + boundary
  bytes — this is the pipeline-stage assignment used at pod scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import CNNConfig, ModelConfig
from repro.core import costmodel


@dataclass(frozen=True)
class Partition:
    boundaries: Tuple[int, ...]       # k+1 cut points: [0, b1, ..., L]
    segment_costs: Tuple[float, ...]
    comm_bytes: Tuple[float, ...]     # bytes crossing each internal cut
    node_order: Tuple[str, ...]       # node per segment

    @property
    def num_segments(self) -> int:
        return len(self.boundaries) - 1

    def segments(self) -> List[Tuple[int, int]]:
        return [(self.boundaries[i], self.boundaries[i + 1])
                for i in range(self.num_segments)]


def _imbalance(seg_costs: np.ndarray, weights: np.ndarray) -> float:
    """Max relative overload of any segment vs its node's weighted share."""
    share = weights / weights.sum()
    total = seg_costs.sum()
    with np.errstate(divide="ignore"):
        return float(np.max(seg_costs / (share * total + 1e-12)))


def partition_costs(costs: Sequence[float], node_weights: Sequence[float],
                    boundary_bytes: Optional[Sequence[float]] = None,
                    comm_weight: float = 0.0,
                    node_ids: Optional[Sequence[str]] = None) -> Partition:
    """DP partition of `costs` into len(node_weights) contiguous segments.

    Minimises  max_i (seg_cost_i / share_i + comm_weight * bytes(cut_i))
    where bytes(cut_i) is the activation tensor crossing the cut that
    *starts* segment i (the first segment pays no comm). boundary_bytes[i]
    = bytes crossing a cut before layer i (len == len(costs)+1).

    ``node_ids`` labels segments with the caller's node names (defaults to
    "0".."k-1"); its length must match ``node_weights``. Degenerate inputs
    stay shape-consistent (len(node_order) == num_segments ==
    len(comm_bytes)+1): with fewer layers than nodes only the first
    ``min(L, k)`` nodes receive a segment, and a single-node (or empty)
    model is one whole segment on the first node. ``k == 0`` raises.
    """
    L, k = len(costs), len(node_weights)
    if k <= 0:
        raise ValueError("partition_costs needs at least one node weight")
    if node_ids is None:
        node_ids = tuple(str(i) for i in range(k))
    else:
        node_ids = tuple(str(n) for n in node_ids)
        if len(node_ids) != k:
            raise ValueError(
                f"node_ids length {len(node_ids)} != node_weights length {k}")
    # Fewer layers than nodes: only the first L nodes can receive a
    # (non-empty) segment — partition over that prefix.
    if L < k:
        k = max(L, 1)
        node_weights = list(node_weights)[:k]
        node_ids = node_ids[:k]
    if k == 1:
        return Partition((0, L), (float(sum(costs)),), (), (node_ids[0],))
    costs = np.asarray(costs, dtype=np.float64)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])
    w = np.asarray(node_weights, dtype=np.float64)
    share = w / w.sum()
    total = prefix[-1]
    bb = np.asarray(boundary_bytes if boundary_bytes is not None
                    else np.zeros(L + 1), dtype=np.float64)

    # DP over (segment s, end index j): value = (bottleneck, comm) lexicographic
    # combined as bottleneck + comm_weight*comm.
    INF = np.inf
    dp = np.full((k + 1, L + 1), INF)
    par = np.zeros((k + 1, L + 1), dtype=np.int64)
    dp[0, 0] = 0.0
    for s in range(1, k + 1):
        cap = share[s - 1] * total + 1e-12
        for j in range(s, L + 1):
            # segment is (i, j], previous end i
            lo = s - 1
            best, arg = INF, lo
            for i in range(lo, j):
                if dp[s - 1, i] == INF:
                    continue
                seg = prefix[j] - prefix[i]
                load = seg / cap
                comm = comm_weight * bb[i] if i > 0 else 0.0
                val = max(dp[s - 1, i], load + comm)
                if val < best:
                    best, arg = val, i
            dp[s, j], par[s, j] = best, arg
    # Recover boundaries.
    bounds = [L]
    j = L
    for s in range(k, 0, -1):
        j = int(par[s, j])
        bounds.append(j)
    bounds = tuple(reversed(bounds))
    seg_costs = tuple(float(prefix[b] - prefix[a])
                      for a, b in zip(bounds[:-1], bounds[1:]))
    comm = tuple(float(bb[b]) for b in bounds[1:-1])
    return Partition(bounds, seg_costs, comm, node_ids)


# ---------------------------------------------------------------------------
# Node-weighting policies
# ---------------------------------------------------------------------------


def capacity_weights(cpus: Sequence[float]) -> np.ndarray:
    return np.asarray(cpus, dtype=np.float64)


# Carbon intensities at or below this floor (gCO2/kWh) are clamped before
# inversion: a node reporting zero intensity (co-located renewable, or a
# trace gap) would otherwise turn green_weights into inf/NaN after
# normalisation. At the floor the node simply wins the carbon term outright
# — real grid signals sit orders of magnitude above it.
GREEN_INTENSITY_FLOOR = 1e-6


def green_weights(cpus: Sequence[float], intensities: Sequence[float],
                  carbon_weight: float = 0.5) -> np.ndarray:
    """Blend capacity with inverse carbon intensity (green partitioning):
    w_i = cpu_i^(1-a) * (1/I_i)^a, normalised. Intensities are clamped
    below at :data:`GREEN_INTENSITY_FLOOR` so zero-carbon nodes produce
    finite weights."""
    c = np.asarray(cpus, dtype=np.float64)
    inv_i = 1.0 / np.maximum(np.asarray(intensities, dtype=np.float64),
                             GREEN_INTENSITY_FLOOR)
    w = np.power(c, 1.0 - carbon_weight) * np.power(inv_i / inv_i.max(), carbon_weight)
    return w / w.sum()


# ---------------------------------------------------------------------------
# Front-ends
# ---------------------------------------------------------------------------


def partition_cnn(cfg: CNNConfig, node_weights: Sequence[float],
                  batch: int = 1, comm_weight: float = 0.0,
                  node_ids: Optional[Sequence[str]] = None) -> Partition:
    from repro.models import cnn as cnn_mod

    costs = costmodel.cnn_costs(cfg)
    bb = [cnn_mod.activation_bytes(cfg, i, batch) for i in range(len(costs) + 1)]
    return partition_costs(costs, node_weights, bb, comm_weight,
                           node_ids=node_ids)


def partition_transformer(cfg: ModelConfig, node_weights: Sequence[float],
                          seq: int, batch: int,
                          comm_weight: float = 0.0,
                          node_ids: Optional[Sequence[str]] = None) -> Partition:
    costs = [costmodel.block_flops(cfg, ld, seq, batch)
             for ld in cfg.layer_defs]
    bb = [costmodel.boundary_bytes(cfg, seq, batch)] * (len(costs) + 1)
    return partition_costs(costs, node_weights, bb, comm_weight,
                           node_ids=node_ids)
