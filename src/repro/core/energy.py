"""Energy + roofline model (TPU v5e constants) — the workload-derived
replacement for CodeCarbon's host measurement (DESIGN.md §4).

Three roofline terms per compiled step:
    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * ICI_BW)

The step-time model is max(terms); energy = chips * power * time; carbon =
energy * intensity * PUE (paper Eq. 2).

Every accounting function here is **array-valued** (DESIGN.md §6): pass
scalars and get scalars, pass (B,) arrays and get (B,) arrays computed by
the *same elementwise arithmetic* — this is what lets the batched
execution path (`EdgeCluster.execute_batch`,
`CarbonMonitor.record_energy_batch`) bill a whole batch in one shot while
staying bit-identical to the per-task scalar loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
CHIP_POWER_W = 200.0              # nominal per-chip board power
HOST_OVERHEAD_W = 30.0            # per-chip share of host power


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def step_time_s(self) -> float:
        if any(isinstance(t, np.ndarray)
               for t in (self.compute_s, self.memory_s, self.collective_s)):
            # array-valued terms (batched accounting): elementwise max
            return np.maximum(np.maximum(self.compute_s, self.memory_s),
                              self.collective_s)
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s,
                "step_time_s": self.step_time_s, "bottleneck": self.bottleneck}


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_FLOPS_BF16),
        memory_s=bytes_hbm / (chips * HBM_BW),
        collective_s=bytes_collective / (chips * ICI_BW),
    )


def step_energy_kwh(terms: RooflineTerms, chips: int,
                    chip_power_w: float = CHIP_POWER_W,
                    host_overhead_w: float = HOST_OVERHEAD_W) -> float:
    """Eq. 1 adapted: E = integral P dt = P_total * t_step."""
    p_total = chips * (chip_power_w + host_overhead_w)
    return p_total * terms.step_time_s / 3.6e6


def task_energy_kwh(power_w, latency_ms):
    """Full-host-power task energy (CodeCarbon machine-mode accounting) —
    the serial-execution billing rule ``EdgeCluster.execute`` uses.
    Array-valued: ``latency_ms`` may be a (B,) array, and each element goes
    through exactly the scalar expression."""
    return power_w * (latency_ms / 1000.0) / 3.6e6


def carbon_g(energy_kwh, intensity_g_per_kwh, pue=1.0):
    """Paper Eq. 2: C = E * I * PUE. Array-valued: any argument may be a
    (B,) array; elementwise evaluation order matches the scalar call."""
    return energy_kwh * intensity_g_per_kwh * pue


def ledger_add(start: float, values) -> float:
    """Fold ``values`` into a running float ledger in strict left-to-right
    order: returns ``(((start + v0) + v1) + ...)`` exactly as a scalar
    ``ledger += v`` loop would compute it. ``np.add.accumulate`` evaluates
    sequentially (unlike ``np.sum``'s pairwise reduction), which is what
    keeps batched ledger updates bit-identical to the per-task loop they
    replace (DESIGN.md §6)."""
    vals = np.asarray(values, dtype=float).reshape(-1)
    if vals.size == 0:
        return float(start)
    acc = np.empty(vals.size + 1)
    acc[0] = start
    acc[1:] = vals
    return float(np.add.accumulate(acc)[-1])


def ledger_scatter_add(ledger: np.ndarray, idx, values) -> np.ndarray:
    """Grouped in-place ledger fold: ``ledger[idx[k]] += values[k]`` for
    each ``k`` in order — the scatter counterpart of :func:`ledger_add`.
    ``np.add.at`` applies unbuffered sequential updates, so a cell hit by
    several ``k`` accumulates them in exactly the order a scalar loop
    would (plain fancy-index ``+=`` would silently drop duplicates). Used
    by the obs metrics registry (DESIGN.md §9) for per-label counters."""
    np.add.at(ledger, np.asarray(idx),
              np.asarray(values, dtype=ledger.dtype))
    return ledger
