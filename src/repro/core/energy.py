"""Energy + roofline model (TPU v5e constants) — the workload-derived
replacement for CodeCarbon's host measurement (DESIGN.md §4).

Three roofline terms per compiled step:
    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * ICI_BW)

The step-time model is max(terms); energy = chips * power * time; carbon =
energy * intensity * PUE (paper Eq. 2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
CHIP_POWER_W = 200.0              # nominal per-chip board power
HOST_OVERHEAD_W = 30.0            # per-chip share of host power


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s,
                "step_time_s": self.step_time_s, "bottleneck": self.bottleneck}


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_FLOPS_BF16),
        memory_s=bytes_hbm / (chips * HBM_BW),
        collective_s=bytes_collective / (chips * ICI_BW),
    )


def step_energy_kwh(terms: RooflineTerms, chips: int,
                    chip_power_w: float = CHIP_POWER_W,
                    host_overhead_w: float = HOST_OVERHEAD_W) -> float:
    """Eq. 1 adapted: E = integral P dt = P_total * t_step."""
    p_total = chips * (chip_power_w + host_overhead_w)
    return p_total * terms.step_time_s / 3.6e6


def carbon_g(energy_kwh: float, intensity_g_per_kwh: float,
             pue: float = 1.0) -> float:
    """Paper Eq. 2: C = E * I * PUE."""
    return energy_kwh * intensity_g_per_kwh * pue
