"""Carbon Monitor (paper §III.B).

Tracks energy (Eq. 1: E = ∫ P dt, discretised) and emissions
(Eq. 2: C = E * I * PUE) per node/region, with two power sources:

- ``record_power_sample``: wall-clock x sampled power (the CodeCarbon path;
  on this host we sample a process-CPU proxy),
- ``record_step``: workload-derived — roofline step time x device power
  from the compiled artifact (core/energy.py), which lets the scheduler
  score *before* executing (DESIGN.md §4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import energy as energy_mod
from repro.core.energy import RooflineTerms

RAM_W_PER_GB = 0.375  # paper §III.B.1 DDR4 approximation


@dataclass
class EnergySample:
    t_s: float
    power_w: float


@dataclass
class RegionAccount:
    intensity_g_per_kwh: float
    pue: float = 1.0
    energy_kwh: float = 0.0
    carbon_g: float = 0.0
    tasks: int = 0
    # True when the intensity was pinned explicitly at register_region time;
    # pinned regions are never overridden by the monitor's provider.
    pinned: bool = False


class CarbonMonitor:
    """Per-region energy/emissions ledger.

    Grid intensity is read through a ``CarbonIntensityProvider``
    (core/api.py) when one is given — time-varying billing via the ``hour``
    argument — otherwise through the static value captured at
    ``register_region`` time (equivalent to a StaticProvider snapshot).
    """

    def __init__(self, provider=None):
        self.provider = provider
        self.regions: Dict[str, RegionAccount] = {}
        self._samples: List[EnergySample] = []

    def register_region(self, name: str, intensity: Optional[float] = None,
                        pue: float = 1.0):
        pinned = intensity is not None
        if intensity is None:
            if self.provider is None:
                raise ValueError(
                    f"register_region({name!r}) needs an intensity or a "
                    "CarbonIntensityProvider")
            intensity = self.provider.intensity(name)
        self.regions[name] = RegionAccount(intensity, pue, pinned=pinned)

    # -- Eq. 1: discretised power integration ------------------------------
    def record_power_sample(self, region: str, dt_s: float, p_gpu_w: float = 0.0,
                            p_cpu_w: float = 0.0, ram_gb: float = 0.0,
                            hour: float = 0.0) -> float:
        p = p_gpu_w + p_cpu_w + ram_gb * RAM_W_PER_GB
        e_kwh = p * dt_s / 3.6e6
        self._samples.append(EnergySample(dt_s, p))
        return self._bill(region, e_kwh, hour)

    # -- workload-derived (roofline) ---------------------------------------
    def record_step(self, region: str, terms: RooflineTerms, chips: int,
                    chip_power_w: float = energy_mod.CHIP_POWER_W,
                    hour: float = 0.0) -> float:
        e_kwh = energy_mod.step_energy_kwh(terms, chips, chip_power_w)
        return self._bill(region, e_kwh, hour)

    # -- pre-computed energy (engine path) ---------------------------------
    def record_energy(self, region: str, e_kwh: float,
                      hour: float = 0.0) -> float:
        return self._bill(region, e_kwh, hour)

    def billing_intensity(self, region: str, hour: float = 0.0) -> float:
        """The intensity a `_bill` at ``hour`` would use — side-effect-free,
        so callers can probe billing inputs before committing work."""
        acc = self.regions[region]
        if self.provider is not None and not acc.pinned:
            return self.provider.intensity(region, hour)
        return acc.intensity_g_per_kwh

    def billing_intensity_batch(self, regions: Sequence[str],
                                hour: float = 0.0) -> np.ndarray:
        """(len(regions),) billing intensities at ``hour`` — the batched,
        side-effect-free form of :meth:`billing_intensity` (DESIGN.md §6).
        Provider-driven (non-pinned) regions are resolved through one
        ``api.intensity_batch`` call instead of a per-region Python loop;
        pinned or provider-less regions read their registered value. An
        unregistered region raises ``KeyError`` like the scalar probe."""
        accs = [self.regions[r] for r in regions]     # KeyError like scalar
        out = np.array([a.intensity_g_per_kwh for a in accs], dtype=float)
        if self.provider is not None:
            live = [i for i, a in enumerate(accs) if not a.pinned]
            if live:
                from repro.core.api import intensity_batch

                vals = intensity_batch(self.provider,
                                       [regions[i] for i in live], hour)
                out[live] = np.asarray(vals, dtype=float)
        return out

    def record_energy_batch(self, regions: Sequence[str], e_kwh,
                            hour: float = 0.0, intensities=None,
                            groups=None) -> np.ndarray:
        """Bill B pre-computed task energies in one shot (DESIGN.md §6):
        the batched form of B :meth:`record_energy` calls.

        ``regions`` is the per-task billing region, ``e_kwh`` a scalar or
        (B,) array. ``intensities`` (scalar or (B,) array) supplies
        pre-resolved billing intensities — the engine passes the values it
        probed before executing, so the billed signal is exactly the probed
        one; ``None`` resolves them here via
        :meth:`billing_intensity_batch`. Carbon is one array-valued
        ``energy.carbon_g`` evaluation, and each region's account is
        updated once, with float accumulations folded in strict task order
        (``energy.ledger_add``) — bit-identical to the per-task loop, in
        O(distinct regions) Python work. Returns the (B,) per-task carbon.

        ``groups`` mirrors ``EdgeCluster.execute_batch``: a precomputed
        ``np.unique(..., return_inverse=True)`` over ``regions``.

        Atomic: all inputs resolve before the first account write."""
        B = len(regions)
        if not B:
            return np.zeros(0)
        e = np.broadcast_to(np.asarray(e_kwh, dtype=float), (B,))
        if groups is None:
            groups = np.unique(np.asarray(regions, dtype=object),
                               return_inverse=True)
        uniq, inverse = groups
        if intensities is None:
            per_uniq = self.billing_intensity_batch(list(uniq), hour)
            ints = per_uniq[inverse]
        else:
            ints = np.broadcast_to(np.asarray(intensities, dtype=float), (B,))
        accs = [self.regions[r] for r in uniq]        # KeyError like scalar
        pues = np.array([a.pue for a in accs], dtype=float)[inverse]
        c = energy_mod.carbon_g(e, ints, pues)
        order = np.argsort(inverse, kind="stable")
        bounds = np.searchsorted(inverse[order], np.arange(len(uniq) + 1))
        for k, acc in enumerate(accs):
            idx = order[bounds[k]:bounds[k + 1]]
            acc.energy_kwh = energy_mod.ledger_add(acc.energy_kwh, e[idx])
            acc.carbon_g = energy_mod.ledger_add(acc.carbon_g, c[idx])
            acc.tasks += int(idx.size)
        return c

    def _bill(self, region: str, e_kwh: float, hour: float = 0.0) -> float:
        acc = self.regions[region]
        c = energy_mod.carbon_g(e_kwh, self.billing_intensity(region, hour),
                                acc.pue)
        acc.energy_kwh += e_kwh
        acc.carbon_g += c
        acc.tasks += 1
        return c

    # -- reporting ----------------------------------------------------------
    def total_carbon_g(self) -> float:
        return sum(a.carbon_g for a in self.regions.values())

    def total_energy_kwh(self) -> float:
        return sum(a.energy_kwh for a in self.regions.values())

    def _effective_intensity(self, acc: RegionAccount) -> float:
        """What the region was actually billed at: the energy-weighted mean
        for provider-driven (possibly time-varying) regions with billed
        energy, else the registration-time value."""
        if self.provider is not None and not acc.pinned and acc.energy_kwh:
            return acc.carbon_g / (acc.energy_kwh * acc.pue)
        return acc.intensity_g_per_kwh

    def report(self) -> Dict[str, Dict[str, float]]:
        return {r: {"energy_kwh": a.energy_kwh, "carbon_g": a.carbon_g,
                    "tasks": a.tasks,
                    "intensity": self._effective_intensity(a)}
                for r, a in self.regions.items()}


class WallClockEnergyTracker:
    """Minimal CodeCarbon-style context: samples process time x power."""

    def __init__(self, monitor: CarbonMonitor, region: str, power_w: float):
        self.monitor, self.region, self.power_w = monitor, region, power_w
        self.elapsed_s = 0.0
        self.carbon_g = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed_s = time.perf_counter() - self._t0
        self.carbon_g = self.monitor.record_power_sample(
            self.region, self.elapsed_s, p_cpu_w=self.power_w)
        return False
