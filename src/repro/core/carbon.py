"""Carbon Monitor (paper §III.B).

Tracks energy (Eq. 1: E = ∫ P dt, discretised) and emissions
(Eq. 2: C = E * I * PUE) per node/region, with two power sources:

- ``record_power_sample``: wall-clock x sampled power (the CodeCarbon path;
  on this host we sample a process-CPU proxy),
- ``record_step``: workload-derived — roofline step time x device power
  from the compiled artifact (core/energy.py), which lets the scheduler
  score *before* executing (DESIGN.md §2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import energy as energy_mod
from repro.core.energy import RooflineTerms

RAM_W_PER_GB = 0.375  # paper §III.B.1 DDR4 approximation


@dataclass
class EnergySample:
    t_s: float
    power_w: float


@dataclass
class RegionAccount:
    intensity_g_per_kwh: float
    pue: float = 1.0
    energy_kwh: float = 0.0
    carbon_g: float = 0.0
    tasks: int = 0


class CarbonMonitor:
    def __init__(self):
        self.regions: Dict[str, RegionAccount] = {}
        self._samples: List[EnergySample] = []

    def register_region(self, name: str, intensity: float, pue: float = 1.0):
        self.regions[name] = RegionAccount(intensity, pue)

    # -- Eq. 1: discretised power integration ------------------------------
    def record_power_sample(self, region: str, dt_s: float, p_gpu_w: float = 0.0,
                            p_cpu_w: float = 0.0, ram_gb: float = 0.0) -> float:
        p = p_gpu_w + p_cpu_w + ram_gb * RAM_W_PER_GB
        e_kwh = p * dt_s / 3.6e6
        self._samples.append(EnergySample(dt_s, p))
        return self._bill(region, e_kwh)

    # -- workload-derived (roofline) ---------------------------------------
    def record_step(self, region: str, terms: RooflineTerms, chips: int,
                    chip_power_w: float = energy_mod.CHIP_POWER_W) -> float:
        e_kwh = energy_mod.step_energy_kwh(terms, chips, chip_power_w)
        return self._bill(region, e_kwh)

    def _bill(self, region: str, e_kwh: float) -> float:
        acc = self.regions[region]
        c = energy_mod.carbon_g(e_kwh, acc.intensity_g_per_kwh, acc.pue)
        acc.energy_kwh += e_kwh
        acc.carbon_g += c
        acc.tasks += 1
        return c

    # -- reporting ----------------------------------------------------------
    def total_carbon_g(self) -> float:
        return sum(a.carbon_g for a in self.regions.values())

    def total_energy_kwh(self) -> float:
        return sum(a.energy_kwh for a in self.regions.values())

    def report(self) -> Dict[str, Dict[str, float]]:
        return {r: {"energy_kwh": a.energy_kwh, "carbon_g": a.carbon_g,
                    "tasks": a.tasks, "intensity": a.intensity_g_per_kwh}
                for r, a in self.regions.items()}


class WallClockEnergyTracker:
    """Minimal CodeCarbon-style context: samples process time x power."""

    def __init__(self, monitor: CarbonMonitor, region: str, power_w: float):
        self.monitor, self.region, self.power_w = monitor, region, power_w
        self.elapsed_s = 0.0
        self.carbon_g = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed_s = time.perf_counter() - self._t0
        self.carbon_g = self.monitor.record_power_sample(
            self.region, self.elapsed_s, p_cpu_w=self.power_w)
        return False
