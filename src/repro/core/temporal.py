"""Temporal carbon-aware scheduling — the paper's §V.A future work
("real-time carbon intensity integration ... deferring non-urgent tasks to
low-carbon time periods", §II.E).

Adds to the static-scenario core:

- :class:`IntensityTrace` — a diurnal grid-intensity signal per region
  (synthetic solar/wind-shaped traces, or user-supplied hourly series the
  way an Electricity Maps API feed would provide them);
- :class:`TemporalScheduler` — extends the NSA: for *deferrable* tasks it
  scans the (node x start-slot) grid within the task's deadline and picks
  the slot/node minimising expected carbon, subject to the same Eq. 3
  feasibility filters; urgent tasks fall through to the instantaneous NSA.

This keeps the paper's Eq. 4 scoring intact — S_C simply becomes
time-indexed — so the weight semantics of Table I are unchanged.

The slot-grid search itself lives in
:class:`repro.core.policy.TemporalPolicy` (the Eq. 3 math is *not*
duplicated here); intensity is read through a
:class:`repro.core.api.TraceProvider`. This module keeps the trace types,
the deferrable-task model, and the thin scheduler wrapper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import StaticProvider, TraceProvider
from repro.core.cluster import EdgeCluster
from repro.core.policy import Placement, TemporalPolicy
from repro.core.scheduler import Task, Weights, node_feasible


@dataclass(frozen=True)
class IntensityTrace:
    """Hourly carbon intensity for one region. values[h] in gCO2/kWh."""

    region: str
    values: Tuple[float, ...]              # length 24 (wraps)

    def at(self, hour: float) -> float:
        h = hour % 24.0
        i = int(h) % 24
        j = (i + 1) % 24
        frac = h - int(h)
        return self.values[i] * (1 - frac) + self.values[j] * frac

    @property
    def mean(self) -> float:
        return float(np.mean(self.values))


def synthetic_trace(region: str, base: float, solar_dip: float = 0.35,
                    noise: float = 0.0, seed: int = 0) -> IntensityTrace:
    """Diurnal trace: intensity dips around midday (solar), peaks in the
    evening ramp — the canonical duck-curve shape."""
    rng = np.random.default_rng(seed)
    hours = np.arange(24)
    solar = np.exp(-0.5 * ((hours - 13.0) / 3.0) ** 2)       # midday dip
    evening = 0.15 * np.exp(-0.5 * ((hours - 19.0) / 2.0) ** 2)
    vals = base * (1.0 - solar_dip * solar + evening)
    if noise:
        vals = vals * (1.0 + noise * rng.standard_normal(24))
    return IntensityTrace(region, tuple(float(v) for v in vals))


@dataclass(frozen=True)
class DeferrableTask(Task):
    deadline_hours: float = 0.0            # 0 => not deferrable
    duration_hours: float = 0.1


def plan_wake(provider, cluster: EdgeCluster, task, now_hour: float,
              slot_hours: float = 0.5) -> float:
    """When should a deferrable task wake to minimise expected carbon?

    This is the *driver-routed* deferral path (DESIGN.md §2): instead of
    the eager slot scan executing a placement immediately
    (:meth:`TemporalPolicy.place`), the sim driver calls ``plan_wake`` to
    pick a wake hour, parks the task on a ``DEFER_WAKE`` event, and lets
    the engine's policy choose the node *at wake time* against the
    then-current cluster state — so capacity freed (or consumed) between
    submission and wake is seen, which the eager scan cannot do.

    The wake slot minimises the provider's intensity over the feasible
    nodes' forecast series within ``[now, now + deadline - duration]``
    (a :class:`~repro.core.api.ForecastProvider` answers through
    ``window`` — CarbonCP-style acting-under-forecast; any other provider
    is sampled per slot). Ties prefer the earliest slot (run now). A task
    without deadline slack, or with no feasible node, wakes immediately.
    """
    deadline = getattr(task, "deadline_hours", 0.0)
    duration = getattr(task, "duration_hours", 0.0)
    horizon = max(deadline - duration, 0.0)
    if horizon <= 0.0:
        return now_hour
    n_slots = max(1, int(horizon / slot_hours) + 1)
    # half-slot pad so float fuzz in arange never drops/adds a slot
    end = now_hour + (n_slots - 0.5) * slot_hours
    best_slot, best_val = 0, np.inf
    for name, st in cluster.nodes.items():
        if not node_feasible(st, task):
            continue
        if hasattr(provider, "window"):
            series = np.asarray(provider.window(name, now_hour, end,
                                                slot_hours))[:n_slots]
        else:
            series = np.array([provider.intensity(name, now_hour + k * slot_hours)
                               for k in range(n_slots)])
        if series.size == 0:
            continue
        k = int(np.argmin(series))
        # strict < keeps the earliest slot (and first node) on exact ties
        if series[k] < best_val:
            best_val, best_slot = float(series[k]), k
    return now_hour + best_slot * slot_hours


class TemporalScheduler:
    """Space-time extension of the NSA (Algorithm 1 over a slot grid).

    Thin wrapper: the grid search is
    :meth:`repro.core.policy.TemporalPolicy.place`; the intensity signal is
    a :class:`TraceProvider` over ``traces`` with the cluster's static
    regional values as fallback.
    """

    def __init__(self, cluster: EdgeCluster, traces: Dict[str, IntensityTrace],
                 weights: Weights, slot_hours: Optional[float] = None,
                 policy: Optional[TemporalPolicy] = None, provider=None):
        if (policy is not None and slot_hours is not None
                and slot_hours != policy.slot_hours):
            raise ValueError(
                f"conflicting slot_hours: {slot_hours} vs the supplied "
                f"policy's {policy.slot_hours}")
        self.cluster = cluster
        self.traces = traces
        self.weights = weights
        self.provider = provider or TraceProvider(
            traces, fallback=StaticProvider.from_cluster(cluster))
        self.policy = policy or TemporalPolicy(
            slot_hours=0.5 if slot_hours is None else slot_hours)
        # single source of truth: the policy's grid granularity
        self.slot_hours = self.policy.slot_hours

    def select(self, task: DeferrableTask, now_hour: float = 0.0) -> Optional[Placement]:
        return self.policy.place(self.cluster, task, self.weights,
                                 self.provider, now_hour)

    def run(self, tasks: Sequence[DeferrableTask], now_hour: float = 0.0
            ) -> Tuple[List[Placement], float]:
        placements = []
        total = 0.0
        for t in tasks:
            pl = self.select(t, now_hour)
            if pl is None:
                raise RuntimeError("no feasible placement")
            placements.append(pl)
            total += pl.expected_carbon_g
        return placements, total


def carbon_savings_from_deferral(cluster: EdgeCluster,
                                 traces: Dict[str, IntensityTrace],
                                 weights: Weights,
                                 tasks: Sequence[DeferrableTask],
                                 now_hour: float = 0.0) -> Dict[str, float]:
    """Compare run-now vs deadline-aware placement for the same workload."""
    sched = TemporalScheduler(cluster, traces, weights)
    urgent = [DeferrableTask(t.cpu, t.mem_mb, t.base_latency_ms, 0.0,
                             t.duration_hours) for t in tasks]
    _, now_carbon = sched.run(urgent, now_hour)
    _, deferred_carbon = sched.run(tasks, now_hour)
    return {
        "run_now_g": now_carbon,
        "deferred_g": deferred_carbon,
        "savings_pct": 100.0 * (1 - deferred_carbon / now_carbon)
        if now_carbon else 0.0,
    }
