"""Temporal carbon-aware scheduling — the paper's §V.A future work
("real-time carbon intensity integration ... deferring non-urgent tasks to
low-carbon time periods", §II.E).

Adds to the static-scenario core:

- :class:`IntensityTrace` — a diurnal grid-intensity signal per region
  (synthetic solar/wind-shaped traces, or user-supplied hourly series the
  way an Electricity Maps API feed would provide them);
- :class:`TemporalScheduler` — extends the NSA: for *deferrable* tasks it
  scans the (node x start-slot) grid within the task's deadline and picks
  the slot/node minimising expected carbon, subject to the same Eq. 3
  feasibility filters; urgent tasks fall through to the instantaneous NSA.

This keeps the paper's Eq. 4 scoring intact — S_C simply becomes
time-indexed — so the weight semantics of Table I are unchanged.

The slot-grid search itself lives in
:class:`repro.core.policy.TemporalPolicy` (the Eq. 3 math is *not*
duplicated here); intensity is read through a
:class:`repro.core.api.TraceProvider`. This module keeps the trace types,
the deferrable-task model, and the thin scheduler wrapper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import StaticProvider, TraceProvider, intensity_batch
from repro.core.cluster import EdgeCluster
from repro.core.policy import Placement, TemporalPolicy
from repro.core.scheduler import Task, Weights, node_feasible


def interp_hourly(values: np.ndarray, hours: np.ndarray) -> np.ndarray:
    """Vectorized wrap-around linear interpolation over hourly tables:
    ``values`` is (24,) or (M, 24), ``hours`` (S,); returns (S,) resp.
    (M, S). THE single definition of :meth:`IntensityTrace.at`'s
    arithmetic — the batched provider API interpolates through this same
    function, keeping batch == scalar bit-identical (sim determinism
    depends on it)."""
    h = np.asarray(hours, dtype=float) % 24.0
    i = np.floor(h).astype(np.int64) % 24
    j = (i + 1) % 24
    frac = h - np.floor(h)
    v = np.asarray(values)
    return v[..., i] * (1 - frac) + v[..., j] * frac


@dataclass(frozen=True)
class IntensityTrace:
    """Hourly carbon intensity for one region. values[h] in gCO2/kWh."""

    region: str
    values: Tuple[float, ...]              # length 24 (wraps)

    def at(self, hour):
        """Linear interpolation at ``hour`` (wraps over 24 h). Accepts a
        scalar (returns float) or an array of hours (returns an array) —
        the array form backs the batched provider API and evaluates the
        exact scalar arithmetic elementwise (bit-identical)."""
        if np.ndim(hour) == 0:
            h = hour % 24.0
            i = int(h) % 24
            j = (i + 1) % 24
            frac = h - int(h)
            return self.values[i] * (1 - frac) + self.values[j] * frac
        return interp_hourly(self.values, hour)

    @property
    def mean(self) -> float:
        return float(np.mean(self.values))


def synthetic_trace(region: str, base: float, solar_dip: float = 0.35,
                    noise: float = 0.0, seed: int = 0) -> IntensityTrace:
    """Diurnal trace: intensity dips around midday (solar), peaks in the
    evening ramp — the canonical duck-curve shape."""
    rng = np.random.default_rng(seed)
    hours = np.arange(24)
    solar = np.exp(-0.5 * ((hours - 13.0) / 3.0) ** 2)       # midday dip
    evening = 0.15 * np.exp(-0.5 * ((hours - 19.0) / 2.0) ** 2)
    vals = base * (1.0 - solar_dip * solar + evening)
    if noise:
        vals = vals * (1.0 + noise * rng.standard_normal(24))
    return IntensityTrace(region, tuple(float(v) for v in vals))


@dataclass(frozen=True)
class SeriesTrace:
    """A measured intensity series on a uniform time grid — the shape an
    ElectricityMaps-style regional CSV export has (DESIGN.md §11): one
    value every ``step_hours`` from ``start_hour``, *not* wrapped over
    24 h (a day-long replay ends where the data ends; reads past either
    edge clamp to it).

    ``at`` is one array-aware code path: a scalar hour returns a float, an
    hour array returns an array of the exact same elementwise arithmetic —
    so :meth:`TraceProvider.intensity` and ``intensity_batch`` agree
    bit-for-bit, which the multi-region replay determinism test pins.
    """

    region: str
    values: Tuple[float, ...]
    start_hour: float = 0.0
    step_hours: float = 1.0

    def at(self, hour):
        v = np.asarray(self.values, dtype=float)
        if v.size == 1:
            out = np.full(np.shape(hour), v[0])
            return float(out) if np.ndim(hour) == 0 else out
        pos = (np.asarray(hour, dtype=float) - self.start_hour) \
            / self.step_hours
        pos = np.clip(pos, 0.0, float(v.size - 1))
        i = np.minimum(np.floor(pos).astype(np.int64), v.size - 2)
        frac = pos - i
        out = v[i] * (1 - frac) + v[i + 1] * frac
        return float(out) if np.ndim(hour) == 0 else out

    @property
    def mean(self) -> float:
        return float(np.mean(self.values))


@dataclass(frozen=True)
class DeferrableTask(Task):
    deadline_hours: float = 0.0            # 0 => not deferrable
    duration_hours: float = 0.1


def _wake_slots(task, slot_hours: float) -> int:
    """Number of start slots within ``deadline - duration`` (0 = no slack)."""
    deadline = getattr(task, "deadline_hours", 0.0)
    duration = getattr(task, "duration_hours", 0.0)
    horizon = max(deadline - duration, 0.0)
    if horizon <= 0.0:
        return 0
    return max(1, int(horizon / slot_hours) + 1)


def plan_wake_scalar(provider, cluster: EdgeCluster, task, now_hour: float,
                     slot_hours: float = 0.5) -> float:
    """Scalar nodes x slots Python scan — the parity oracle for
    :func:`plan_wake` (which vectorizes the same decision; the two are
    regression-tested equal, ties included)."""
    n_slots = _wake_slots(task, slot_hours)
    if n_slots == 0:
        return now_hour
    # half-slot pad so float fuzz in arange never drops/adds a slot
    end = now_hour + (n_slots - 0.5) * slot_hours
    best_slot, best_val = 0, np.inf
    for name, st in cluster.nodes.items():
        if not node_feasible(st, task):
            continue
        if hasattr(provider, "window"):
            series = np.asarray(provider.window(name, now_hour, end,
                                                slot_hours))[:n_slots]
        else:
            series = np.array([provider.intensity(name, now_hour + k * slot_hours)
                               for k in range(n_slots)])
        if series.size == 0:
            continue
        k = int(np.argmin(series))
        # strict < keeps the earliest slot (and first node) on exact ties
        if series[k] < best_val:
            best_val, best_slot = float(series[k]), k
    return now_hour + best_slot * slot_hours


def plan_wake(provider, cluster: EdgeCluster, task, now_hour: float,
              slot_hours: float = 0.5) -> float:
    """When should a deferrable task wake to minimise expected carbon?

    This is the *driver-routed* deferral path (DESIGN.md §2): instead of
    the eager slot scan executing a placement immediately
    (:meth:`TemporalPolicy.place`), the sim driver calls ``plan_wake`` to
    pick a wake hour, parks the task on a ``DEFER_WAKE`` event, and lets
    the engine's policy choose the node *at wake time* against the
    then-current cluster state — so capacity freed (or consumed) between
    submission and wake is seen, which the eager scan cannot do.

    The wake slot minimises the provider's intensity over the feasible
    nodes' forecast series within ``[now, now + deadline - duration]``.
    Ties keep the earliest slot, and across nodes the first (insertion-
    order) node's earliest minimum wins — identical to the scalar oracle
    :func:`plan_wake_scalar`. A task without deadline slack, or with no
    feasible node, wakes immediately.

    Fleet-scale fast path (DESIGN.md §3): feasibility comes from the
    cluster's incremental :class:`~repro.core.featcache.FeatureCache`
    columns (duck-typed cluster-likes without one fall back to the scalar
    feasibility filter) and the whole (S, N) slot grid is one batched
    :func:`~repro.core.api.intensity_batch` read — no nodes x slots
    Python loop. Delegates to :func:`plan_wake_batch`.
    """
    return float(plan_wake_batch(provider, cluster, [task], now_hour,
                                 slot_hours)[0])


def plan_wake_batch(provider, cluster: EdgeCluster, tasks, now_hour: float,
                    slot_hours: float = 0.5) -> np.ndarray:
    """Vectorized :func:`plan_wake` for many tasks at once: one (S, N)
    intensity grid over the union of the tasks' feasible nodes, then a
    per-task argmin with the oracle's exact tie-breaks."""
    T = len(tasks)
    wakes = np.full(T, now_hour, dtype=float)
    n_slots = np.array([_wake_slots(t, slot_hours) for t in tasks])
    todo = np.nonzero(n_slots > 0)[0]
    if todo.size == 0:
        return wakes
    fc = getattr(cluster, "feature_cache", None)
    if callable(fc):
        cache = fc()
        all_names = cache.names
        task_cpu = np.array([tasks[i].cpu for i in todo], dtype=float)
        task_mem = np.array([tasks[i].mem_mb for i in todo], dtype=float)
        feas = cache.feasible(task_cpu, task_mem)        # (T', N)
    else:
        # duck-typed cluster-likes without the EdgeCluster cache plumbing:
        # scalar feasibility, still one batched grid read below
        all_names = list(cluster.nodes)
        feas = np.array([[node_feasible(cluster.nodes[n], tasks[i])
                          for n in all_names] for i in todo])
    need = feas.any(axis=0)
    if not need.any():
        return wakes
    cols = np.nonzero(need)[0]
    names = [all_names[j] for j in cols]
    S = int(n_slots[todo].max())
    hours = now_hour + np.arange(S) * slot_hours
    # One batched read for the whole grid. A provider exposing only the
    # legacy ``window`` protocol (and no intensity_batch) keeps its
    # per-node window path so series values stay bit-identical.
    if (not hasattr(provider, "intensity_batch")
            and hasattr(provider, "window")):
        end = now_hour + (S - 0.5) * slot_hours
        grid = np.full((S, len(names)), np.inf)
        for j, name in enumerate(names):
            series = np.asarray(provider.window(name, now_hour, end,
                                                slot_hours))[:S]
            grid[:series.size, j] = series
    else:
        grid = np.asarray(intensity_batch(provider, names, hours))
    grid = grid.reshape(S, len(names))
    # Per-node earliest argmin over its slots, then first node with the
    # strictly smallest value — the scalar oracle's exact tie-breaks.
    for row, ti in enumerate(todo):
        s = int(n_slots[ti])
        sub = grid[:s, :]
        m = np.where(feas[row, cols], sub.min(axis=0), np.inf)
        if not np.isfinite(m).any():
            continue
        j = int(np.argmin(m))
        k = int(np.argmin(sub[:, j]))
        wakes[ti] = now_hour + k * slot_hours
    return wakes


def plan_wake_risk(provider, cluster: EdgeCluster, task, now_hour: float,
                   slot_hours: float = 0.5, coverage: float = 0.9) -> float:
    """Risk-bounded :func:`plan_wake` (scalar front-end); see
    :func:`plan_wake_risk_batch`."""
    return float(plan_wake_risk_batch(provider, cluster, [task], now_hour,
                                      slot_hours, coverage)[0])


def plan_wake_risk_batch(provider, cluster: EdgeCluster, tasks,
                         now_hour: float, slot_hours: float = 0.5,
                         coverage: float = 0.9) -> np.ndarray:
    """Risk-bounded deferral planning over conformal intensity intervals
    (DESIGN.md §8).

    :func:`plan_wake_batch` trusts the provider's point forecast; with a
    noisy forecast that gambles real carbon on a predicted dip. Here the
    grid is read as ``coverage``-level intervals
    (:func:`repro.core.api.intensity_interval_batch`) and a task defers
    only when the deferral wins even under the interval's pessimistic
    view: the candidate future slot is the feasible (slot >= 1, node)
    cell minimising the interval UPPER bound (earliest slot, first node
    on ties), and the task defers to it only if that upper bound strictly
    undercuts the best LOWER bound of executing now (slot 0 over the
    feasible nodes). Since lo <= hi everywhere, a deferral whose lower
    bound loses to executing now can never happen — the acceptance
    invariant regression-tested in tests/test_partition.py. Zero-width
    (point-interval) providers degrade to "defer only on strict
    improvement". Tasks without deadline slack, or with no feasible node,
    wake immediately.
    """
    from repro.core.api import intensity_interval_batch

    T = len(tasks)
    wakes = np.full(T, now_hour, dtype=float)
    n_slots = np.array([_wake_slots(t, slot_hours) for t in tasks])
    todo = np.nonzero(n_slots > 1)[0]      # s == 1 has no future slot
    if todo.size == 0:
        return wakes
    fc = getattr(cluster, "feature_cache", None)
    if callable(fc):
        cache = fc()
        all_names = cache.names
        task_cpu = np.array([tasks[i].cpu for i in todo], dtype=float)
        task_mem = np.array([tasks[i].mem_mb for i in todo], dtype=float)
        feas = cache.feasible(task_cpu, task_mem)        # (T', N)
    else:
        all_names = list(cluster.nodes)
        feas = np.array([[node_feasible(cluster.nodes[n], tasks[i])
                          for n in all_names] for i in todo])
    need = feas.any(axis=0)
    if not need.any():
        return wakes
    cols = np.nonzero(need)[0]
    names = [all_names[j] for j in cols]
    S = int(n_slots[todo].max())
    hours = now_hour + np.arange(S) * slot_hours
    lo, hi = intensity_interval_batch(provider, names, hours,
                                      coverage=coverage)
    lo = np.asarray(lo, dtype=float).reshape(S, len(names))
    hi = np.asarray(hi, dtype=float).reshape(S, len(names))
    for row, ti in enumerate(todo):
        ok = feas[row, cols]
        if not ok.any():
            continue
        s = int(n_slots[ti])
        # optimistic cost of running now: best slot-0 lower bound
        now_opt = float(np.where(ok, lo[0, :], np.inf).min())
        # pessimistic cost of the best deferral candidate (slots 1..s-1)
        sub_hi = np.where(ok[None, :], hi[1:s, :], np.inf)
        if not np.isfinite(sub_hi).any():
            continue
        m = sub_hi.min(axis=0)
        j = int(np.argmin(m))              # first node on exact ties
        k = 1 + int(np.argmin(sub_hi[:, j]))   # earliest slot on ties
        if sub_hi[k - 1, j] < now_opt:
            wakes[ti] = now_hour + k * slot_hours
    return wakes


class TemporalScheduler:
    """Space-time extension of the NSA (Algorithm 1 over a slot grid).

    Thin wrapper: the grid search is
    :meth:`repro.core.policy.TemporalPolicy.place`; the intensity signal is
    a :class:`TraceProvider` over ``traces`` with the cluster's static
    regional values as fallback.
    """

    def __init__(self, cluster: EdgeCluster, traces: Dict[str, IntensityTrace],
                 weights: Weights, slot_hours: Optional[float] = None,
                 policy: Optional[TemporalPolicy] = None, provider=None):
        if (policy is not None and slot_hours is not None
                and slot_hours != policy.slot_hours):
            raise ValueError(
                f"conflicting slot_hours: {slot_hours} vs the supplied "
                f"policy's {policy.slot_hours}")
        self.cluster = cluster
        self.traces = traces
        self.weights = weights
        self.provider = provider or TraceProvider(
            traces, fallback=StaticProvider.from_cluster(cluster))
        self.policy = policy or TemporalPolicy(
            slot_hours=0.5 if slot_hours is None else slot_hours)
        # single source of truth: the policy's grid granularity
        self.slot_hours = self.policy.slot_hours

    def select(self, task: DeferrableTask, now_hour: float = 0.0) -> Optional[Placement]:
        return self.policy.place(self.cluster, task, self.weights,
                                 self.provider, now_hour)

    def run(self, tasks: Sequence[DeferrableTask], now_hour: float = 0.0
            ) -> Tuple[List[Placement], float]:
        placements = []
        total = 0.0
        for t in tasks:
            pl = self.select(t, now_hour)
            if pl is None:
                raise RuntimeError("no feasible placement")
            placements.append(pl)
            total += pl.expected_carbon_g
        return placements, total


def carbon_savings_from_deferral(cluster: EdgeCluster,
                                 traces: Dict[str, IntensityTrace],
                                 weights: Weights,
                                 tasks: Sequence[DeferrableTask],
                                 now_hour: float = 0.0) -> Dict[str, float]:
    """Compare run-now vs deadline-aware placement for the same workload."""
    sched = TemporalScheduler(cluster, traces, weights)
    urgent = [DeferrableTask(t.cpu, t.mem_mb, t.base_latency_ms, 0.0,
                             t.duration_hours) for t in tasks]
    _, now_carbon = sched.run(urgent, now_hour)
    _, deferred_carbon = sched.run(tasks, now_hour)
    return {
        "run_now_g": now_carbon,
        "deferred_g": deferred_carbon,
        "savings_pct": 100.0 * (1 - deferred_carbon / now_carbon)
        if now_carbon else 0.0,
    }
