"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Params carry logical axis names (models/common.ParamSpec.axes); these rules
map them to mesh axes per mode. Training uses FSDP (embed axis sharded over
``data``) so 480B-scale AdamW state is distributed; serving shards params
over ``model`` only and batch/sequence over (pod, data).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# axisname -> mesh axis (None = replicated). Resolution is left-to-right,
# skipping a mapping when the dimension is not divisible by the mesh-axis
# size or the mesh axis is already used — `head_dim -> model` then acts as
# the fallback for narrow KV-head counts (kv=8 on a 16-way model axis).
_BASE_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "expert": "model",
    "inner": "model",
    "head_dim": "model",
    "heads_inner": None,
    "xlstm_heads": None,
    "ssm_heads": "model",
    "state": None,
    "conv": None,
    "norm": None,
    "layers": None,
    None: None,
}


def rules_for(mode: str) -> Dict[str, Optional[str]]:
    r = dict(_BASE_RULES)
    r["embed"] = "data" if mode == "train" else None
    return r


def spec_from_axes(axes: Tuple[Optional[str], ...],
                   shape: Tuple[int, ...], rules, mesh: Mesh) -> P:
    used = set()
    out = []
    for a, dim in zip(axes, shape):
        m = rules.get(a)
        if m is None or m in used or m not in mesh.axis_names or dim % mesh.shape[m]:
            out.append(None)
        else:
            out.append(m)
            used.add(m)
    return P(*out)


def param_pspecs(cfg, mode: str, mesh: Mesh):
    from repro.models import transformer
    from repro.models.common import ParamSpec

    rules = rules_for(mode)
    spec_tree = transformer.model_spec(cfg)
    return jax.tree.map(
        lambda ps: spec_from_axes(ps.axes, ps.shape, rules, mesh),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def opt_pspecs(cfg, mesh: Mesh):
    """AdamW state: mu/nu shard like params, step replicated."""
    from repro.optim.adamw import AdamWState

    p = param_pspecs(cfg, "train", mesh)
    return AdamWState(step=P(), mu=p, nu=p)


def named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activations / batch / cache
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def batch_pspecs(cfg, shape_kind: str, global_batch: int, mesh: Mesh):
    """PartitionSpecs for the input batch dict."""
    dp = batch_axes(mesh)
    b = dp if global_batch % _dp_size(mesh) == 0 else (
        dp[:-1] if len(dp) > 1 and global_batch % mesh.shape[dp[0]] == 0 else ())
    bspec = b if b else None
    specs = {"tokens": P(bspec, None)}
    if shape_kind == "train":
        specs["labels"] = P(bspec, None)
    if cfg.encoder_layers:
        specs["encoder_embeds"] = P(bspec, None, None)
    if cfg.vision_tokens:
        specs["vision_embeds"] = P(bspec, None, None)
    if cfg.mrope_sections:
        specs["mrope_positions"] = P(bspec, None, None)
    return specs


def kv_layout() -> str:
    """Decode KV-cache layout policy: "heads" (baseline: KV heads/head_dim
    on `model`) or "seq" (optimized: KV sequence on `model`, flash-decode
    style distributed softmax — §Perf iteration)."""
    import os

    return os.environ.get("REPRO_DECODE_KV_LAYOUT", "seq")


def decode_kv_plan(batch: int, kv_heads: int, mesh: Mesh, q_heads: int = 0) -> str:
    """Per-case layout under the "seq" policy (§Perf iterations 2-3):

    - batch fills the dp axes  -> shard KV seq over `model` ("seq"):
      measured 1.5-32x on decode_32k, no regressions.
    - batch=1 (long_500k) with kv_heads divisible -> seq is already
      dp-sharded; keep heads on `model` ("heads") — adding model to seq
      regressed gemma3 long_500k 180x.
    - batch=1, kv_heads NOT divisible -> seq over dp+model ("seq"):
      20-39x measured on qwen1.5 / qwen2-moe / whisper long_500k.
    """
    if kv_layout() != "seq" or "model" not in mesh.axis_names:
        return "heads"
    batch_shardable = batch % _dp_size(mesh) == 0
    if batch_shardable:
        return "seq"
    # batch=1: seq is already dp-sharded; if the *query* heads divide the
    # model axis, expanded-heads attention is fully local ("heads"); else
    # add model to the seq sharding ("seq").
    heads = q_heads or kv_heads
    if heads % mesh.shape["model"] == 0:
        return "heads"
    return "seq"


def cache_pspecs(cfg, batch: int, mesh: Mesh):
    """Cache sharding by leaf path: KV seq-sharded when batch can't fill the
    data axes (long_500k batch=1) — context parallelism for decode."""
    from repro.models import transformer

    dp = batch_axes(mesh)
    batch_shardable = batch % _dp_size(mesh) == 0
    bspec: Any = dp if batch_shardable else None
    seq_spec: Any = None if batch_shardable else dp
    if decode_kv_plan(batch, cfg.num_kv_heads, mesh, cfg.num_heads) == "seq":
        seq_spec = ("model",) if seq_spec is None else tuple(seq_spec) + ("model",)

    abstract = transformer.abstract_cache(cfg, batch, 16 * _dp_size(mesh))

    msize = mesh.shape["model"]

    def _div(dim: int) -> Optional[str]:
        return "model" if dim % msize == 0 else None

    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):
            # (layers, B, S, K, hd): heads on model, falling back to head_dim
            # — unless the seq layout owns the model axis.
            s_ax = seq_spec if name in ("k", "v") else None
            seq_has_model = s_ax is not None and "model" in (
                s_ax if isinstance(s_ax, tuple) else (s_ax,))
            k_ax = None if seq_has_model else _div(leaf.shape[3])
            hd_ax = None if seq_has_model or k_ax is not None else _div(leaf.shape[4])
            return P(None, bspec, s_ax, k_ax, hd_ax)
        if name == "conv":
            # (layers, B, width-1, conv_dim)
            return P(None, bspec, None, _div(leaf.shape[3]))
        if name == "ssm":
            # (layers, B, H, N, P)
            return P(None, bspec, _div(leaf.shape[2]), None, None)
        if name == "C":
            return P(None, bspec, None, None, None)
        # n/m/c/h and other small states
        return P(*([None, bspec] + [None] * (nd - 2)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract)
    leaves = [leaf_spec(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)
