"""Activation sharding constraints (MaxText-style ``with_sharding_constraint``).

Without explicit constraints GSPMD may re-shard activations badly — e.g.
replicating the batch dimension inside attention (observed: per-device
attention dots at full global batch, a 16x FLOP overcount). Model code
calls ``constrain(x, "batch", "seq", "heads", ...)`` with *logical* axis
names; mapping respects the active mesh, divisibility, and axis reuse.

No-op outside a mesh context (CPU unit tests).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_MODEL_AXES = {"heads", "kv_heads", "ff", "expert", "inner", "vocab",
               "head_dim", "ssm_heads", "kv_seq"}


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active mesh (0 if absent / no mesh)."""
    m = _current_mesh()
    if m is None or name not in m.axis_names:
        return 0
    return int(m.shape[name])


def _current_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and m.devices.size > 1:
            return m
    except Exception:  # noqa: BLE001
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty and m.size > 1:
            return m
    except Exception:  # noqa: BLE001
        pass
    return None


def constrain(x, *axes: Optional[str]):
    """Constrain array ``x``'s dims to logical axes (None = replicated)."""
    m = _current_mesh()
    if m is None:
        return x
    names = m.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_size = math.prod(m.shape[a] for a in dp) if dp else 1
    used = set()
    spec = []
    for dim, ax in zip(x.shape, axes):
        target = None
        if ax == "batch" and dp and "data" not in used and dim % dp_size == 0:
            target = dp if len(dp) > 1 else dp[0]
            used.update(dp)
        elif ax == "seq" and dp and "data" not in used and dim % dp_size == 0:
            # context parallelism (long-context decode)
            target = dp if len(dp) > 1 else dp[0]
            used.update(dp)
        elif ax in _MODEL_AXES and "model" in names and "model" not in used \
                and dim % m.shape["model"] == 0:
            target = "model"
            used.add("model")
        spec.append(target)
    return jax.lax.with_sharding_constraint(x, P(*spec))
