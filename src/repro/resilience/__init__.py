"""Fault injection & failure-aware scheduling (DESIGN.md §10).

Four pieces, composable with every existing layer:

- :class:`FaultInjector` / :class:`Fault` — seeded deterministic fault
  schedules (node crash/recover with detection lag, provider blackout
  windows, latency stragglers, link flaps), surfaced as
  ``NODE_DOWN``/``NODE_UP``/``PROVIDER_OUTAGE`` sim events;
- :class:`FleetHealth` — the scheduler's availability mask + per-node
  circuit breakers, masked *inside* the batched/Pallas scorer through
  the FeatureCache ``avail`` column;
- :class:`Resilience` — the engine attachment: ground-truth down set,
  failover re-placement, capped-exponential-backoff retry and the
  dead-letter outcome;
- :class:`ResilientProvider` — last-known-good degraded mode for carbon
  feeds, widening conformal intervals with staleness.

Contract: with resilience enabled and a zero-fault schedule, every sim
report is byte-identical to a resilience-free run on both execute paths;
a fixed fault seed reproduces runs byte-identically.
"""
from repro.resilience.faults import Fault, FaultInjector
from repro.resilience.health import FleetHealth
from repro.resilience.policy import Resilience
from repro.resilience.provider import ResilientProvider

__all__ = ["Fault", "FaultInjector", "FleetHealth", "Resilience",
           "ResilientProvider"]
