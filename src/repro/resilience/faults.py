"""Seeded deterministic fault schedules + their runtime application.

A :class:`FaultInjector` holds an immutable, pre-generated list of
:class:`Fault` entries — node crash/recover pairs (with an optional
*detection lag*: the scheduler's availability mask only learns of a crash
``detect_delay_hours`` later, or earlier by contact), carbon-provider
blackout windows, latency-straggler windows (a node's profiled
``avg_time_ms`` is inflated, scoring-visible through the FeatureCache
dirty sink) and link-bandwidth flaps (a partition policy's uplink is
retuned via ``set_link_mbps``). The schedule is a pure function of
``(seed, parameters)`` built from one ``np.random.default_rng(seed)``
stream, so a fixed fault seed reproduces byte-identical runs
(DESIGN.md §10).

The sim driver surfaces each fault as an event — ``NODE_DOWN`` (crash /
detect / straggle / flap), ``NODE_UP`` (recover / window close) or
``PROVIDER_OUTAGE`` (blackout open/close) — and calls
:meth:`FaultInjector.apply` when it fires; engine-only callers (the churn
benchmark's oracle loop) use :meth:`advance` instead. One injector drives
one run: it carries restore state (saved ``avg_time_ms``, saved link
speed), so build a fresh injector (same seed) per repeat.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.sim.events import EventKind

# fault kind -> sim event kind
_EVENT_KIND = {
    "crash": EventKind.NODE_DOWN, "detect": EventKind.NODE_DOWN,
    "straggle": EventKind.NODE_DOWN, "flap": EventKind.NODE_DOWN,
    "recover": EventKind.NODE_UP, "unstraggle": EventKind.NODE_UP,
    "unflap": EventKind.NODE_UP,
    "blackout": EventKind.PROVIDER_OUTAGE,
    "restore": EventKind.PROVIDER_OUTAGE,
}


@dataclass(frozen=True)
class Fault:
    """One scheduled fault transition."""

    hour: float
    kind: str            # key of _EVENT_KIND
    node: str = ""       # empty for provider-wide faults
    factor: float = 1.0  # straggle avg_time multiplier / flap link fraction
    detected: bool = True  # crash only: mask immediately (no detection lag)

    @property
    def event_kind(self) -> EventKind:
        return _EVENT_KIND[self.kind]


@dataclass
class FaultInjector:
    """A deterministic fault schedule plus its runtime application state."""

    schedule: List[Fault] = field(default_factory=list)
    _cursor: int = field(default=0, repr=False)
    _saved_avg: Dict[str, float] = field(default_factory=dict, repr=False)
    _saved_link: Optional[float] = field(default=None, repr=False)
    _flap_depth: int = field(default=0, repr=False)

    def __post_init__(self):
        self.schedule = sorted(self.schedule, key=lambda f: f.hour)

    # -- construction ------------------------------------------------------
    @classmethod
    def scripted(cls, faults: Sequence[Fault]) -> "FaultInjector":
        return cls(list(faults))

    @classmethod
    def generate(cls, nodes: Sequence[str], horizon_hours: float, *,
                 seed: int = 0,
                 crash_rate_per_hour: float = 0.0,
                 mttr_hours: float = 0.2,
                 detect_delay_hours: float = 0.0,
                 outage_rate_per_hour: float = 0.0,
                 outage_hours: float = 0.3,
                 straggle_rate_per_hour: float = 0.0,
                 straggle_hours: float = 0.2,
                 straggle_factor: float = 3.0,
                 flap_rate_per_hour: float = 0.0,
                 flap_hours: float = 0.2,
                 flap_factor: float = 0.25) -> "FaultInjector":
        """Seeded churn: per-node Poisson crash (and straggle) processes,
        a global Poisson blackout/flap process. All windows are
        exponential; repairs may complete past the horizon (the events
        simply fire after the last arrival)."""
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []

        def windows(rate: float, mean_len: float):
            t = 0.0
            while rate > 0.0:
                t += rng.exponential(1.0 / rate)
                if t >= horizon_hours:
                    return
                dur = rng.exponential(mean_len)
                yield t, t + dur
                t += dur

        for node in nodes:
            for t0, t1 in windows(crash_rate_per_hour, mttr_hours):
                detected = detect_delay_hours <= 0.0
                faults.append(Fault(t0, "crash", node, detected=detected))
                if not detected:
                    faults.append(Fault(t0 + detect_delay_hours, "detect",
                                        node))
                faults.append(Fault(t1, "recover", node))
        for node in nodes:
            for t0, t1 in windows(straggle_rate_per_hour, straggle_hours):
                faults.append(Fault(t0, "straggle", node,
                                    factor=straggle_factor))
                faults.append(Fault(t1, "unstraggle", node))
        for t0, t1 in windows(outage_rate_per_hour, outage_hours):
            faults.append(Fault(t0, "blackout"))
            faults.append(Fault(t1, "restore"))
        for t0, t1 in windows(flap_rate_per_hour, flap_hours):
            faults.append(Fault(t0, "flap", factor=flap_factor))
            faults.append(Fault(t1, "unflap"))
        return cls(faults)

    def without_detection_lag(self) -> "FaultInjector":
        """The fault-oracle variant of this schedule: same faults, but
        every crash is detected at onset — the scheduler never places
        onto a dead node, so the delta against the lagged run is pure
        carbon/latency regret of imperfect failure knowledge."""
        return FaultInjector([
            Fault(f.hour, f.kind, f.node, f.factor, True)
            for f in self.schedule if f.kind != "detect"])

    # -- application -------------------------------------------------------
    def apply(self, fault: Fault, engine) -> None:
        """Mutate ground truth / scheduler state for one fault. Crash,
        detect and recover need an engine built with ``resilience=``;
        straggle, flap and blackout degrade any engine."""
        res = getattr(engine, "resilience", None)
        k = fault.kind
        if k == "crash":
            if res is not None:
                res.node_down(fault.node, detected=fault.detected)
        elif k == "detect":
            if res is not None and fault.node in res.down:
                res.detect(fault.node)
        elif k == "recover":
            if res is not None:
                res.node_up(fault.node)
        elif k == "straggle":
            st = engine.cluster.nodes.get(fault.node)
            if st is not None and fault.node not in self._saved_avg:
                self._saved_avg[fault.node] = st.avg_time_ms
                st.avg_time_ms = st.avg_time_ms * fault.factor
        elif k == "unstraggle":
            orig = self._saved_avg.pop(fault.node, None)
            st = engine.cluster.nodes.get(fault.node)
            if st is not None and orig is not None:
                st.avg_time_ms = orig    # bit-exact restore of the profile
        elif k == "flap":
            pol = getattr(engine, "policy", None)
            set_link = getattr(pol, "set_link_mbps", None)
            if set_link is not None:
                if self._flap_depth == 0:
                    self._saved_link = pol.link_mbps
                    set_link(pol.link_mbps * fault.factor)
                self._flap_depth += 1
        elif k == "unflap":
            if self._flap_depth > 0:
                self._flap_depth -= 1
                if self._flap_depth == 0:
                    engine.policy.set_link_mbps(self._saved_link)
        elif k == "blackout":
            begin = getattr(getattr(engine, "provider", None),
                            "begin_blackout", None)
            if begin is not None:
                begin()
        elif k == "restore":
            end = getattr(getattr(engine, "provider", None),
                          "end_blackout", None)
            if end is not None:
                end()
        else:
            raise ValueError(f"unknown fault kind {k!r}")

    def advance(self, now_hour: float, engine) -> int:
        """Apply every not-yet-applied fault with ``hour <= now_hour`` (in
        schedule order); returns how many fired. For engine-only loops —
        the sim driver applies via events instead."""
        fired = 0
        while (self._cursor < len(self.schedule)
               and self.schedule[self._cursor].hour <= now_hour):
            self.apply(self.schedule[self._cursor], engine)
            self._cursor += 1
            fired += 1
        return fired

    # -- schedule statistics ----------------------------------------------
    def crash_windows(self) -> List[tuple]:
        """(node, down_hour, up_hour) per crash (repair possibly > horizon)."""
        open_at: Dict[str, float] = {}
        out = []
        for f in self.schedule:
            if f.kind == "crash":
                open_at[f.node] = f.hour
            elif f.kind == "recover" and f.node in open_at:
                out.append((f.node, open_at.pop(f.node), f.hour))
        return out

    def mttr_hours(self) -> float:
        """Mean time-to-repair over the schedule's crash windows."""
        w = self.crash_windows()
        if not w:
            return 0.0
        return float(np.mean([up - down for _, down, up in w]))

    def fleet_availability(self, n_nodes: int, horizon_hours: float) -> float:
        """1 - (node-down-hours / node-hours) within the horizon."""
        if n_nodes <= 0 or horizon_hours <= 0:
            return 1.0
        down = sum(min(up, horizon_hours) - min(down_h, horizon_hours)
                   for _, down_h, up in self.crash_windows())
        return 1.0 - down / (n_nodes * horizon_hours)
