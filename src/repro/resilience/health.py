"""Scheduler-side fleet health: availability mask + circuit breakers.

:class:`FleetHealth` is the *scheduler's belief* about which nodes are
servable — distinct from ground truth (a crashed node the scheduler has
not detected yet is down in :attr:`repro.resilience.Resilience.down` but
still unmasked here). It owns two FeatureCache columns (DESIGN.md §10):

- ``cache.avail``      — (N,) bool availability mask, ``None`` while every
  node is believed healthy so the zero-fault path pays nothing and stays
  bit-identical (``FeatureCache.node_ok`` ANDs it only when present);
- ``cache.fail_count`` — (N,) cumulative contact-failure counter, ``None``
  until the first failure (observability / benchmark surface only — the
  scorer masks through ``avail``, never filters in Python).

Circuit-breaker state machine (per node):

- **CLOSED**: consecutive contact failures accumulate; at
  ``breaker_threshold`` the breaker OPENS — the node is masked for
  ``cooldown * 2^(trips-1)`` hours, capped at ``cooldown_cap``.
- **OPEN**: masked; :meth:`tick` unmasks it when the cooldown expires.
- **HALF-OPEN** (expired cooldown): the node takes traffic again; one
  successful execution resets the failure streak and trip count
  (CLOSED), one more failure re-opens it with a doubled cooldown.

Detected crashes (``set_manual``) mask the node until the matching
``NODE_UP`` independently of the breaker. Every mask mutation bumps
``cache.data_rev`` so the selection memo and partition blocks recompute.
"""
from __future__ import annotations

from typing import Dict, Set

import numpy as np


class FleetHealth:
    """Availability mask + per-node circuit breakers for one cluster."""

    def __init__(self, breaker_threshold: int = 3,
                 breaker_cooldown_hours: float = 0.25,
                 breaker_cooldown_cap_hours: float = 2.0):
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_hours = float(breaker_cooldown_hours)
        self.breaker_cooldown_cap_hours = float(breaker_cooldown_cap_hours)
        self.blocked: Set[str] = set()        # masked = manual | open breaker
        self.manual: Set[str] = set()         # detected-crash marks
        self.consec: Dict[str, int] = {}      # consecutive contact failures
        self.trips: Dict[str, int] = {}       # breaker open count (backoff)
        self.open_until: Dict[str, float] = {}
        self.fails_total: Dict[str, int] = {} # cumulative, never reset

    # -- cache plumbing ----------------------------------------------------
    def push(self, cache) -> None:
        """(Re)build the mask columns on ``cache`` from current state —
        called on attach and by ``FeatureCache._rebuild`` (topology
        changes must not silently unmask a blocked node)."""
        blocked = self.blocked & set(cache.index)
        if not blocked and not self.fails_total:
            if cache.avail is not None or cache.fail_count is not None:
                cache.avail = None
                cache.fail_count = None
                cache.data_rev += 1
            return
        mask = np.ones(cache.n, dtype=bool)
        fails = np.zeros(cache.n)
        for name in blocked:
            mask[cache.index[name]] = False
        for name, k in self.fails_total.items():
            i = cache.index.get(name)
            if i is not None:
                fails[i] = k
        cache.avail = mask
        cache.fail_count = fails
        cache.data_rev += 1

    def _block(self, name: str, cache) -> None:
        if name in self.blocked:
            return
        self.blocked.add(name)
        i = cache.index.get(name)
        if i is None:
            return
        if cache.avail is None:
            cache.avail = np.ones(cache.n, dtype=bool)
        cache.avail[i] = False
        cache.data_rev += 1

    def _unblock(self, name: str, cache) -> None:
        if name not in self.blocked:
            return
        self.blocked.discard(name)
        if cache.avail is None:
            return
        if not (self.blocked & set(cache.index)):
            cache.avail = None
        else:
            i = cache.index.get(name)
            if i is not None:
                cache.avail[i] = True
        cache.data_rev += 1

    # -- transitions -------------------------------------------------------
    def set_manual(self, name: str, cache) -> None:
        """Mask a node the scheduler now knows is down (fault detection —
        by schedule or by contact)."""
        self.manual.add(name)
        self._block(name, cache)

    def clear_manual(self, name: str, cache, now_hour: float) -> None:
        """A ``NODE_UP`` for a detected crash: unmask unless a breaker
        still holds the node open."""
        self.manual.discard(name)
        if self.open_until.get(name, -np.inf) <= now_hour:
            self.open_until.pop(name, None)
            self._unblock(name, cache)

    def record_failure(self, name: str, now_hour: float, cache) -> None:
        """One contact failure: bump streak + cumulative column; open the
        breaker (capped exponential cooldown) at the threshold."""
        c = self.consec.get(name, 0) + 1
        self.consec[name] = c
        self.fails_total[name] = self.fails_total.get(name, 0) + 1
        if cache.fail_count is None:
            cache.fail_count = np.zeros(cache.n)
        i = cache.index.get(name)
        if i is not None:
            cache.fail_count[i] += 1.0
        if c >= self.breaker_threshold:
            t = self.trips.get(name, 0)
            self.trips[name] = t + 1
            self.open_until[name] = now_hour + min(
                self.breaker_cooldown_hours * (2.0 ** t),
                self.breaker_cooldown_cap_hours)
            self._block(name, cache)

    def record_success(self, name: str, cache) -> None:
        """A half-open node served successfully: close its breaker."""
        if self.consec.pop(name, None) is not None:
            self.trips.pop(name, None)
            if self.open_until.pop(name, None) is not None \
                    and name not in self.manual:
                self._unblock(name, cache)

    def tick(self, now_hour: float, cache) -> None:
        """Expire elapsed breaker cooldowns (OPEN -> HALF-OPEN): unmask
        unless the node is also manually down. O(1) when no breaker is
        open."""
        if not self.open_until:
            return
        expired = [n for n, t in self.open_until.items() if t <= now_hour]
        for n in expired:
            del self.open_until[n]
            if n not in self.manual:
                self._unblock(n, cache)

    @property
    def suspect(self) -> bool:
        """Any node mid-streak or blocked — the engine's cheap guard for
        its success-bookkeeping pass."""
        return bool(self.consec or self.blocked)

    def report(self) -> Dict:
        return {
            "blocked": sorted(self.blocked),
            "manual_down": sorted(self.manual),
            "open_breakers": {n: t for n, t in sorted(self.open_until.items())},
            "consecutive_failures": dict(sorted(self.consec.items())),
            "failures_total": dict(sorted(self.fails_total.items())),
        }
