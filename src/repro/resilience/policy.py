"""Failure-aware scheduling configuration + ground-truth fault state.

:class:`Resilience` is what ``CarbonEdgeEngine(resilience=...)`` takes:
retry/dead-letter knobs plus the two state layers DESIGN.md §10
separates —

- :attr:`down` — **ground truth**: nodes that are actually dead right
  now (mutated by the :class:`~repro.resilience.FaultInjector`). The
  engine consults it at execute time: a placement onto a down node is a
  *contact failure*, detected immediately (detection-by-contact) and
  failed over.
- :attr:`health` — the **scheduler's belief** (:class:`~repro.
  resilience.FleetHealth`): the availability mask + circuit breakers the
  batched/Pallas scorer masks through. With a detection lag the two
  disagree for a window, which is exactly what makes failover, retry
  and the breaker machinery exercisable.

Tasks that still have no feasible node after failover park with capped
exponential backoff (``backoff_base_hours * 2^(attempt-1)``, capped at
``backoff_cap_hours``) and dead-letter after ``max_attempts``.
"""
from __future__ import annotations

from typing import Dict, Iterable, Set

from repro.resilience.health import FleetHealth


class Resilience:
    """Engine-side failure handling: attach via
    ``CarbonEdgeEngine(..., resilience=Resilience())``."""

    def __init__(self, *, max_attempts: int = 4,
                 backoff_base_hours: float = 0.02,
                 backoff_cap_hours: float = 0.5,
                 health: FleetHealth = None):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.max_attempts = int(max_attempts)
        self.backoff_base_hours = float(backoff_base_hours)
        self.backoff_cap_hours = float(backoff_cap_hours)
        self.health = health if health is not None else FleetHealth()
        self.down: Set[str] = set()
        self._engine = None

    def bind(self, engine) -> None:
        """Wire the health mask into the engine cluster's FeatureCache
        (rebuilds re-push it — see ``FeatureCache._rebuild``)."""
        self._engine = engine
        cache = engine.cluster.feature_cache()
        cache._health = self.health
        self.health.push(cache)

    def _cache(self):
        return self._engine.cluster.feature_cache()

    # -- ground-truth transitions (FaultInjector) --------------------------
    def node_down(self, name: str, detected: bool = True) -> None:
        self.down.add(name)
        if detected:
            self.health.set_manual(name, self._cache())

    def detect(self, name: str) -> None:
        """The lagged detection of an earlier crash reached the scheduler."""
        self.health.set_manual(name, self._cache())

    def node_up(self, name: str) -> None:
        self.down.discard(name)
        self.health.clear_manual(name, self._cache(), float("-inf"))

    # -- engine hooks ------------------------------------------------------
    def tick(self, now_hour: float) -> None:
        self.health.tick(now_hour, self._cache())

    def contact_failure(self, name: str, now_hour: float) -> None:
        """The engine placed onto ``name`` and it was dead/unknown:
        breaker accounting + detection-by-contact masking."""
        cache = self._cache()
        self.health.record_failure(name, now_hour, cache)
        if name in self.down:
            self.health.set_manual(name, cache)

    def note_success(self, names: Iterable[str]) -> None:
        """Successful executions close half-open breakers / reset streaks
        (call only when ``health.suspect`` — the zero-fault path skips)."""
        cache = self._cache()
        for n in names:
            self.health.record_success(n, cache)

    def backoff_hours(self, attempt: int) -> float:
        return min(self.backoff_base_hours * (2.0 ** max(0, attempt - 1)),
                   self.backoff_cap_hours)

    def availability(self, n_nodes: int) -> float:
        """Ground-truth fleet availability fraction: the share of
        ``n_nodes`` not currently in :attr:`down`. The sim driver folds
        this into the rollup windows on every fault transition
        (DESIGN.md §12)."""
        if n_nodes <= 0:
            return 1.0
        return 1.0 - len(self.down) / float(n_nodes)

    def report(self) -> Dict:
        return {"down": sorted(self.down), "health": self.health.report()}
