"""Blackout-tolerant carbon provider with staleness-widened intervals.

:class:`ResilientProvider` wraps any :class:`~repro.core.api.
CarbonIntensityProvider` (typically the engine's *outermost* one, so a
``ForecastProvider``'s conformal band rides along). Healthy, every read
delegates bit-identically — the DESIGN.md §10 zero-fault contract — while
a **last-known-good (LKG) cache** records each scalar-hour read. During a
blackout window (``begin_blackout``/``end_blackout``, toggled by the
:class:`~repro.resilience.FaultInjector` on ``PROVIDER_OUTAGE`` events) or
when the base provider itself raises, reads degrade to LKG persistence
values, and ``intensity_interval_batch`` *widens* its band by
``widen_g_per_hour × staleness`` — so every conformal consumer
(``plan_wake_risk``, the tenancy risk-deferral gate, DESIGN.md §8/§7)
automatically prices in how stale the grid signal is, with the lower
band clipped at zero.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


class ResilientProvider:
    """Last-known-good degraded mode over a base provider."""

    def __init__(self, base, widen_g_per_hour: float = 25.0):
        self.base = base
        self.widen_g_per_hour = float(widen_g_per_hour)
        self._outages = 0               # nested blackout windows
        self._lkg: Dict[str, float] = {}
        self._lkg_hour = None           # hour of the newest good read
        self.served_stale = 0           # degraded reads (diagnostics)

    # Unknown attributes (``conformal``, ``window``, ...) delegate to the
    # base so the wrapper is drop-in for planners and the obs layer.
    def __getattr__(self, name):
        return getattr(self.base, name)

    @property
    def TIME_INVARIANT(self) -> bool:  # noqa: N802 (provider protocol attr)
        return getattr(self.base, "TIME_INVARIANT", False)

    @property
    def blackout(self) -> bool:
        return self._outages > 0

    def begin_blackout(self) -> None:
        self._outages += 1

    def end_blackout(self) -> None:
        self._outages = max(0, self._outages - 1)

    def staleness_hours(self, now_hour: float) -> float:
        """How old the LKG snapshot is at ``now_hour`` (0 while healthy)."""
        if not self.blackout or self._lkg_hour is None:
            return 0.0
        return max(0.0, float(now_hour) - self._lkg_hour)

    # -- LKG bookkeeping ---------------------------------------------------
    def _record(self, names: Sequence[str], hour: float, vals) -> None:
        # Scalar-hour reads only (the engine/featcache hot path reads the
        # current hour; array-hour planning reads look into the future and
        # must not advance the snapshot). Keep the newest hour seen.
        if self._lkg_hour is None or hour >= self._lkg_hour:
            self._lkg.update(zip(names, np.atleast_1d(
                np.asarray(vals, dtype=float)).tolist()))
            self._lkg_hour = float(hour)

    def _stale_values(self, names: Sequence[str]) -> np.ndarray:
        vals = np.empty(len(names))
        for j, n in enumerate(names):
            v = self._lkg.get(n)
            if v is None:
                raise KeyError(
                    f"provider blackout and no last-known-good intensity "
                    f"for {n!r}")
            vals[j] = v
        self.served_stale += len(names)
        return vals

    # -- provider protocol -------------------------------------------------
    def intensity(self, node: str, hour: float = 0.0) -> float:
        if not self.blackout:
            try:
                v = self.base.intensity(node, hour)
            except KeyError:
                if node not in self._lkg:
                    raise
                return float(self._stale_values([node])[0])
            self._record([node], float(hour), v)
            return v
        return float(self._stale_values([node])[0])

    def intensity_batch(self, names: Sequence[str], hours) -> np.ndarray:
        from repro.core.api import intensity_batch

        h = np.asarray(hours, dtype=float)
        if not self.blackout:
            try:
                vals = np.asarray(intensity_batch(self.base, names, hours))
            except KeyError:
                if not all(n in self._lkg for n in names):
                    raise
                vals = self._stale_values(names)
                return (vals if h.ndim == 0
                        else np.broadcast_to(vals, (h.size, len(names))
                                             ).copy())
            if h.ndim == 0:
                self._record(names, float(h), vals)
            return vals
        vals = self._stale_values(names)
        if h.ndim == 0:
            return vals
        # persistence: the stale snapshot answers every queried hour
        return np.broadcast_to(vals, (h.size, len(names))).copy()

    def intensity_interval_batch(self, names: Sequence[str], hours,
                                 coverage: float = 0.9):
        from repro.core.api import intensity_interval_batch

        if not self.blackout:
            # healthy: the base's own band, bit-identical
            return intensity_interval_batch(self.base, names, hours,
                                            coverage=coverage)
        h = np.asarray(hours, dtype=float)
        pred = self.intensity_batch(names, hours)
        # base conformal quantile (if calibrated) + staleness widening:
        # queried hours further from the LKG snapshot get wider bands
        q0 = 0.0
        conf = getattr(self.base, "conformal", None)
        if conf is not None:
            q0 = float(conf.quantile(coverage))
        anchor = self._lkg_hour if self._lkg_hour is not None else 0.0
        stale = np.maximum(0.0, h - anchor)
        q = q0 + self.widen_g_per_hour * stale
        if h.ndim != 0:
            q = q[:, None]                          # (S, 1) over (S, N)
        return np.maximum(pred - q, 0.0), pred + q

    def covers(self, node: str) -> bool:
        if self.blackout:
            return node in self._lkg
        cov = getattr(self.base, "covers", None)
        return bool(cov(node)) if cov is not None else True
