"""Mixture-of-experts block: top-k routing with sort-based capacity dispatch.

Two dispatch paths:

1. **shard_map expert-parallel** (production, beyond-paper §Perf change):
   tokens are split across the ``model`` axis, locally sorted into
   per-expert capacity buckets, exchanged with an explicit
   ``jax.lax.all_to_all``, run through the locally-resident expert weights,
   and exchanged back. This replaces GSPMD's handling of the cross-sharded
   scatter/gather — which materialises and all-reduces the *entire*
   (E, C, D) grouped buffer per layer per pass (~200 GB/device/layer
   observed for qwen2-moe train_4k) — with the minimal a2a volume
   (~tokens*k*cf*D bytes). Used when a mesh with a ``model`` axis is
   active, the padded expert count divides it, and the local token count
   divides it.

2. **dense GSPMD path** (oracle + fallback): the original sort + scatter
   into a global (E, C, D) buffer. Used on CPU tests and for tiny decode
   batches.

Expert weights may be padded to ``moe.e_pad`` (qwen2-moe: 60 -> 64) so the
expert axis divides the model axis; padded experts are router-masked to
-inf and unreachable. Capacity factor 1.25, switch-style load-balance aux.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, activation
from repro.models import mlp as mlp_mod
from repro.sharding import constraints
from repro.sharding.constraints import constrain

CAPACITY_FACTOR = 1.25


def moe_spec(cfg: ModelConfig) -> Dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.e_pad, m.expert_ff
    spec = {
        # Larger router init: near-uniform routing makes top-k selection
        # tie-sensitive to e-8 numerics across differently-compiled graphs
        # (prefill vs decode), which shows up as spurious test mismatches.
        "router": ParamSpec((D, E), ("embed", "expert"), scale=0.5),
        "w_gate": ParamSpec((E, D, F), ("expert", "embed", "ff")),
        "w_up": ParamSpec((E, D, F), ("expert", "embed", "ff")),
        "w_down": ParamSpec((E, F, D), ("expert", "ff", "embed")),
    }
    if m.num_shared_experts:
        spec["shared"] = mlp_mod.mlp_spec(cfg, m.num_shared_experts * m.expert_ff, True)
        spec["shared_gate"] = ParamSpec((D, 1), ("embed", None))
    if m.dense_residual_ff:
        spec["dense"] = mlp_mod.mlp_spec(cfg, m.dense_residual_ff, True)
    return spec


def _capacity(tokens: int, top_k: int, num_experts: int) -> int:
    c = int(tokens * top_k * CAPACITY_FACTOR / num_experts)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def route(cfg: ModelConfig, router_w, x_flat) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x_flat: (T, D) -> (weights (T,k), idx (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat, router_w).astype(jnp.float32)
    if m.e_pad > m.num_experts:
        pad_mask = jnp.arange(m.e_pad) >= m.num_experts
        logits = jnp.where(pad_mask[None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * p_e (real experts).
    T = x_flat.shape[0]
    density = jnp.zeros((m.e_pad,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    density = density / (T * m.top_k)
    p_mean = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(density * p_mean) * m.router_aux_weight
    return weights.astype(x_flat.dtype), idx, aux


# ---------------------------------------------------------------------------
# Local dispatch/combine helpers (shared by both paths)
# ---------------------------------------------------------------------------


def _dispatch(x_flat, idx, E_buckets: int, C: int):
    """Sort tokens by expert into an (E_buckets*C+1, D) buffer.

    Returns (buffer_without_drop_row (E_buckets, C, D), dest_tk (T*k,)).
    """
    T, D = x_flat.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    token_of = order // k
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E_buckets), side="left")
    rank = jnp.arange(T * k) - seg_start[sorted_e]
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E_buckets * C)
    buf = jnp.zeros((E_buckets * C + 1, D), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[token_of], mode="drop")
    dest_tk = jnp.zeros((T * k,), jnp.int32).at[order].set(dest.astype(jnp.int32))
    return buf[:-1].reshape(E_buckets, C, D), dest_tk


def _combine(out_grouped, dest_tk, weights):
    """Inverse of _dispatch: gather expert outputs back per (token, k)."""
    EC, D = out_grouped.shape[0] * out_grouped.shape[1], out_grouped.shape[2]
    T, k = weights.shape
    out_flat = out_grouped.reshape(EC, D)
    out_padded = jnp.concatenate([out_flat, jnp.zeros((1, D), out_flat.dtype)])
    safe = jnp.minimum(dest_tk, EC)  # drop bucket -> zero row
    gathered = out_padded[safe].reshape(T, k, D)
    return jnp.einsum("tkd,tk->td", gathered, weights.astype(out_flat.dtype))


def _expert_mlp(cfg, grouped, w_gate, w_up, w_down):
    act = activation(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", grouped, w_up)
    h = h * act(jnp.einsum("ecd,edf->ecf", grouped, w_gate))
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------------------
# Path 1: shard_map expert parallelism
# ---------------------------------------------------------------------------


def _shardmap_viable(cfg: ModelConfig, T: int):
    mesh = constraints._current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    msize = int(mesh.shape["model"])
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = math.prod(int(mesh.shape[a]) for a in dp) if dp else 1
    m = cfg.moe
    if m.e_pad % msize:
        return None
    if T % dp_size or (T // dp_size) % msize:
        return None
    return mesh, dp, dp_size, msize


def _moe_forward_shardmap(cfg: ModelConfig, p, x, mesh, dp, dp_size, msize):
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E_pad, k = m.e_pad, m.top_k
    T_loc = T // dp_size              # tokens per data row
    T_m = T_loc // msize              # tokens per (data, model) shard
    C_m = _capacity(T_m, k, m.num_experts)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def local_fn(x_loc, router_w, w_gate, w_up, w_down):
        # x_loc: (T_loc, D) — identical across the model axis; take our slice.
        mi = jax.lax.axis_index("model")
        xm = jax.lax.dynamic_slice_in_dim(x_loc, mi * T_m, T_m, axis=0)
        weights, idx, aux = route(cfg, router_w, xm)
        buf, dest_tk = _dispatch(xm, idx, E_pad, C_m)        # (E_pad, C_m, D)
        # a2a: send each expert bucket to its owning model shard.
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                  tiled=True)                # (E_loc, msize*C_m, D)
        out = _expert_mlp(cfg, recv, w_gate, w_up, w_down)
        back = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                  tiled=True)                # (E_pad, C_m, D)
        ym = _combine(back, dest_tk, weights)                # (T_m, D)
        y_loc = jax.lax.all_gather(ym, "model", axis=0, tiled=True)  # (T_loc, D)
        aux = jax.lax.pmean(aux, "model")
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y_loc, aux

    in_specs = (
        P(dp_spec, None),                 # x_flat (T, D)
        P(None, None),                    # router (replicated)
        P("model", None, None),           # w_gate
        P("model", None, None),           # w_up
        P("model", None, None),           # w_down
    )
    out_specs = (P(dp_spec, None), P())
    y, aux = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(x.reshape(T, D), p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Path 2: dense GSPMD path (oracle + fallback)
# ---------------------------------------------------------------------------


def _moe_forward_dense(cfg: ModelConfig, p, x):
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    x_flat = constrain(x.reshape(T, D), "batch", None)
    weights, idx, aux = route(cfg, p["router"], x_flat)
    C = _capacity(T, m.top_k, m.num_experts)
    grouped, dest_tk = _dispatch(x_flat, idx, m.e_pad, C)
    grouped = constrain(grouped, "expert", None, None)
    out = _expert_mlp(cfg, grouped, p["w_gate"], p["w_up"], p["w_down"])
    out = constrain(out, "expert", None, None)
    y = _combine(out, dest_tk, weights)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------


def moe_forward(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    viable = _shardmap_viable(cfg, B * S)
    if viable is not None:
        y, aux = _moe_forward_shardmap(cfg, p, x, *viable)
    else:
        y, aux = _moe_forward_dense(cfg, p, x)

    if m.num_shared_experts:
        g = jax.nn.sigmoid(jnp.einsum("bsd,dz->bsz", x, p["shared_gate"]))
        y = y + g * mlp_mod.mlp_forward(cfg, p["shared"], x, gated=True)
    if m.dense_residual_ff:
        y = y + mlp_mod.mlp_forward(cfg, p["dense"], x, gated=True)
    return y, aux
