"""Shared model machinery: param specs, init, norms, positions.

Parameters are plain nested-dict pytrees of jnp arrays. Every model first
builds a *spec tree* of :class:`ParamSpec` (shape + logical axes + init);
from the spec we derive, without duplication:

- ``init_from_spec``      real parameters (seeded, deterministic by path)
- ``abstract_from_spec``  ShapeDtypeStructs for the multi-pod dry-run
- ``axes_from_spec``      logical-axis tree consumed by sharding/rules.py
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small_normal
    scale: float = 0.02

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def map_spec(fn, spec: PyTree) -> PyTree:
    return jax.tree.map(fn, spec, is_leaf=_is_spec)


def init_from_spec(spec: PyTree, key: jax.Array, dtype: jnp.dtype) -> PyTree:
    """Deterministic init: each leaf's key is fold_in(key, hash(path))."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(spec, is_leaf=_is_spec)
    flat, treedef = leaves_with_path

    def init_one(path, p: ParamSpec):
        pathstr = jax.tree_util.keystr(path)
        k = jax.random.fold_in(key, np.uint32(hash(pathstr) & 0x7FFFFFFF))
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        scale = p.scale if p.init == "normal" else p.scale * 0.1
        return (jax.random.normal(k, p.shape, jnp.float32) * scale).astype(dtype)

    leaves = [init_one(path, p) for path, p in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_from_spec(spec: PyTree, dtype: jnp.dtype) -> PyTree:
    return map_spec(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec)


def axes_from_spec(spec: PyTree) -> PyTree:
    return map_spec(lambda p: p.axes, spec)


def stack_spec(spec: PyTree, n: int, axis_name: str = "layers") -> PyTree:
    """Prepend a stacking dimension (for lax.scan over layers)."""
    return map_spec(
        lambda p: ParamSpec((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        spec,
    )


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dt)


def norm_spec(cfg, dim: int) -> Dict[str, ParamSpec]:
    if cfg.norm_type == "layernorm":
        return {
            "scale": ParamSpec((dim,), ("norm",), "zeros"),
            "bias": ParamSpec((dim,), ("norm",), "zeros"),
        }
    return {"scale": ParamSpec((dim,), ("norm",), "zeros")}


def apply_norm(cfg, p, x):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                          # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections: Tuple[int, ...]):
    """M-RoPE (qwen2-vl): positions (B, 3, S); sections sum to D/2.

    Each frequency band uses the position stream of its section
    (temporal / height / width).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                           # (D/2,)
    # section id per frequency index
    sec_id = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    sec_id = jnp.asarray(sec_id)                           # (D/2,)
    # pos_per_freq: (B, S, D/2)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32).transpose(0, 2, 1),  # (B, S, 3)
        jnp.broadcast_to(sec_id[None, None, :],
                         positions.shape[0:1] + (positions.shape[2], d // 2)),
        axis=-1,
    )
    angles = (pos * freqs)[..., None, :]                   # (B, S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions, dim: int):
    """Whisper-style sinusoidal embeddings; positions (...,) -> (..., dim)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
