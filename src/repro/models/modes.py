"""Global scan/unroll switch for roofline accounting.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so scanned layer stacks would be undercounted by ~num_layers in
cost_analysis(). The dry-run accounting pass therefore lowers the step with
all *structural* scans unrolled (layers, attention q-blocks, SSD chunks,
loss chunks) at reduced repeat counts, and extrapolates linearly — see
launch/dryrun.py. sLSTM's time recurrence is never unrolled (32k+ steps);
its per-step cell cost is added analytically.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
import jax.numpy as jnp

_UNROLL = False


def unroll_enabled() -> bool:
    return _UNROLL


@contextmanager
def unroll_scans():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan(body, init, xs, length=None):
    """lax.scan, or a Python loop when unroll mode is on."""
    if not _UNROLL:
        return jax.lax.scan(body, init, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x)
        ys.append(y)
    if not ys or ys[0] is None:
        stacked = None
    else:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
