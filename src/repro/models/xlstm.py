"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) per arXiv:2405.04517.

mLSTM training uses the stabilized parallel form, chunked over query blocks
(flash-attention-style) so the (S, S) gate-decay matrix is never fully
materialised; decode is the O(1) recurrent form with a (head_dim x
head_dim) matrix state per head. sLSTM is inherently sequential
(lax.scan over time) — that is the architecture, not a limitation.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import modes
from repro.sharding.constraints import constrain
from repro.models.common import ParamSpec, rms_norm

NEG_INF = -1e30


def mlstm_dims(cfg: ModelConfig):
    inner = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    H = cfg.xlstm.num_heads
    return inner, H, inner // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    inner, H, hd = mlstm_dims(cfg)
    x = cfg.xlstm
    return {
        "up_proj": ParamSpec((D, 2 * inner), ("embed", "inner")),
        "conv_w": ParamSpec((x.conv_width, inner), ("conv", "inner")),
        "conv_b": ParamSpec((inner,), ("inner",), "zeros"),
        "wq": ParamSpec((inner, inner), ("inner", "heads_inner")),
        "wk": ParamSpec((inner, inner), ("inner", "heads_inner")),
        "wv": ParamSpec((inner, inner), ("inner", "heads_inner")),
        "w_i": ParamSpec((inner, H), ("inner", "xlstm_heads")),
        "b_i": ParamSpec((H,), ("xlstm_heads",), "zeros"),
        "w_f": ParamSpec((inner, H), ("inner", "xlstm_heads")),
        "b_f": ParamSpec((H,), ("xlstm_heads",), "ones"),
        "out_norm": ParamSpec((inner,), ("inner",), "zeros"),
        "down_proj": ParamSpec((inner, D), ("inner", "embed")),
    }


def _causal_conv(x, w, b, width: int):
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out + b)


def _mlstm_qkvif(cfg, p, x_m):
    """x_m: (B,S,inner) -> q,k,v (B,S,H,hd), i,f tilde (B,S,H) fp32."""
    inner, H, hd = mlstm_dims(cfg)
    B, S, _ = x_m.shape
    x_c = _causal_conv(x_m, p["conv_w"], p["conv_b"], cfg.xlstm.conv_width)
    q = jnp.einsum("bsi,ij->bsj", x_c, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsi,ij->bsj", x_c, p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsi,ij->bsj", x_m, p["wv"]).reshape(B, S, H, hd)
    it = (jnp.einsum("bsi,ih->bsh", x_m, p["w_i"]) + p["b_i"]).astype(jnp.float32)
    ft = (jnp.einsum("bsi,ih->bsh", x_m, p["w_f"]) + p["b_f"]).astype(jnp.float32)
    return q, k, v, it, ft


def _mlstm_parallel(q, k, v, it, ft, q_block: int = 1024):
    """Stabilized parallel mLSTM. q,k,v: (B,S,H,hd); it,ft: (B,S,H)."""
    B, S, H, hd = q.shape
    logf = jax.nn.log_sigmoid(ft)                      # (B,S,H)
    F = jnp.cumsum(logf, axis=1)                       # inclusive cumsum

    def block(qb, Fq, start, sq):
        # log D[t,s] = F_t - F_s + logf_s? standard: D = F_t - F_s + i_s with
        # F the cumsum *inclusive of t*, decay product over (s, t] = F_t - F_s.
        logD = (Fq[:, :, None, :] - F[:, None, :, :] + it[:, None, :, :])
        ti = start + jnp.arange(sq)[:, None]
        si = jnp.arange(S)[None, :]
        mask = si <= ti
        logD = jnp.where(mask[None, :, :, None], logD, NEG_INF)
        m = jnp.max(logD, axis=2, keepdims=True)       # (B,sq,1,H)
        m = jnp.maximum(m, -50.0)
        Dmat = jnp.exp(logD - m)                       # (B,sq,S,H)
        scores = jnp.einsum("bthk,bshk->bhts", qb, k).astype(jnp.float32)
        scores = scores * (hd ** -0.5) * Dmat.transpose(0, 3, 1, 2)
        norm = jnp.abs(jnp.sum(scores, axis=-1))       # (B,H,sq)
        norm = jnp.maximum(norm, jnp.exp(-m[:, :, 0, :]).transpose(0, 2, 1))
        out = jnp.einsum("bhts,bshk->bthk", (scores / norm[..., None]).astype(v.dtype), v)
        return out

    if S <= q_block:
        return block(q, F, 0, S)
    nb = S // q_block

    def body(_, i):
        start = i * q_block
        qb = jax.lax.dynamic_slice_in_dim(q, start, q_block, 1)
        Fq = jax.lax.dynamic_slice_in_dim(F, start, q_block, 1)
        return None, block(qb, Fq, start, q_block)

    _, outs = modes.scan(body, None, jnp.arange(nb))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * q_block, H, hd)
    rem = S - nb * q_block
    if rem:
        out = jnp.concatenate(
            [out, block(q[:, -rem:], F[:, -rem:], nb * q_block, rem)], axis=1)
    return out


def mlstm_forward(cfg: ModelConfig, p, xin, return_state: bool = False):
    inner, H, hd = mlstm_dims(cfg)
    B, S, _ = xin.shape
    up = constrain(jnp.einsum("bsd,di->bsi", xin, p["up_proj"]),
                   "batch", None, None)
    x_m, z = jnp.split(up, 2, axis=-1)
    q, k, v, it, ft = _mlstm_qkvif(cfg, p, x_m)
    h = _mlstm_parallel(q, k, v, it, ft)
    h = h.reshape(B, S, inner)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", h, p["down_proj"])
    if not return_state:
        return out
    # Final recurrent state for decode handoff.
    logf = jax.nn.log_sigmoid(ft)
    F = jnp.cumsum(logf, axis=1)
    w_log = F[:, -1:, :] - F + it                      # (B,S,H)
    m_fin = jnp.maximum(jnp.max(w_log, axis=1), -50.0)  # (B,H)
    w = jnp.exp(w_log - m_fin[:, None, :])
    C = jnp.einsum("bshk,bshn->bhkn", (k * w[..., None]).astype(jnp.float32) * (hd ** -0.5),
                   v.astype(jnp.float32))
    n = jnp.einsum("bshk,bsh->bhk", k.astype(jnp.float32) * (hd ** -0.5), w)
    # conv tail
    cw = cfg.xlstm.conv_width - 1
    tail = x_m[:, -cw:] if S >= cw else jnp.pad(x_m, ((0, 0), (cw - S, 0), (0, 0)))
    state = {"C": C, "n": n, "m": m_fin, "conv": tail}
    return out, state


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    inner, H, hd = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -50.0, jnp.float32),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_width - 1, inner), dtype),
    }


def mlstm_decode(cfg: ModelConfig, p, xin, cache):
    """xin: (B,1,D)."""
    inner, H, hd = mlstm_dims(cfg)
    B = xin.shape[0]
    up = jnp.einsum("bsd,di->bsi", xin[:, 0][:, None], p["up_proj"])[:, 0]
    x_m, z = jnp.split(up, 2, axis=-1)
    full = jnp.concatenate([cache["conv"], x_m[:, None]], axis=1)
    x_c = jax.nn.silu(jnp.einsum("bwc,wc->bc", full, p["conv_w"]) + p["conv_b"])
    q = jnp.einsum("bi,ij->bj", x_c, p["wq"]).reshape(B, H, hd)
    k = jnp.einsum("bi,ij->bj", x_c, p["wk"]).reshape(B, H, hd)
    v = jnp.einsum("bi,ij->bj", x_m, p["wv"]).reshape(B, H, hd)
    it = (jnp.einsum("bi,ih->bh", x_m, p["w_i"]) + p["b_i"]).astype(jnp.float32)
    ft = (jnp.einsum("bi,ih->bh", x_m, p["w_f"]) + p["b_f"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + cache["m"], it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + cache["m"] - m_new)
    ks = k.astype(jnp.float32) * (hd ** -0.5)
    C = f_s[..., None, None] * cache["C"] + i_s[..., None, None] * jnp.einsum(
        "bhk,bhn->bhkn", ks, v.astype(jnp.float32))
    n = f_s[..., None] * cache["n"] + i_s[..., None] * ks
    num = jnp.einsum("bhk,bhkn->bhn", q.astype(jnp.float32), C)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n))
    den = jnp.maximum(den, jnp.exp(-m_new))
    h = (num / den[..., None]).astype(xin.dtype).reshape(B, inner)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", h, p["down_proj"])[:, None]
    return out, {"C": C, "n": n, "m": m_new, "conv": full[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_dims(cfg: ModelConfig):
    H = cfg.xlstm.num_heads
    return H, cfg.d_model // H


def slstm_spec(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    H, hd = slstm_dims(cfg)
    ff = int(cfg.xlstm.slstm_proj_factor * D)
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = ParamSpec((D, H, hd), ("embed", "xlstm_heads", "head_dim"))
        gates[f"r_{g}"] = ParamSpec((H, hd, hd), ("xlstm_heads", "head_dim", "head_dim"))
        gates[f"b_{g}"] = ParamSpec((H, hd), ("xlstm_heads", "head_dim"),
                                    "ones" if g == "f" else "zeros")
    return {
        **gates,
        "out_norm": ParamSpec((D,), ("norm",), "zeros"),
        "ffn_up": ParamSpec((D, ff), ("embed", "ff")),
        "ffn_gate": ParamSpec((D, ff), ("embed", "ff")),
        "ffn_down": ParamSpec((ff, D), ("ff", "embed")),
    }


def _slstm_step(p, carry, x_t):
    """carry: (c,n,h,m) each (B,H,hd); x_t pre-projected gates (B,H,hd,4)."""
    c, n, h, m = carry
    rec = lambda g: jnp.einsum("bhk,hkj->bhj", h, p[f"r_{g}"])
    xi, xf, xz, xo = [x_t[..., i] for i in range(4)]
    it = (xi + rec("i")).astype(jnp.float32)
    ft = (xf + rec("f")).astype(jnp.float32)
    zt = jnp.tanh((xz + rec("z")).astype(jnp.float32))
    ot = jax.nn.sigmoid((xo + rec("o")).astype(jnp.float32))
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    h_new = (ot * c_new / jnp.maximum(n_new, 1e-6)).astype(h.dtype)
    return (c_new, n_new, h_new, m_new)


def _slstm_pre(cfg, p, x):
    """Project inputs for all 4 gates: (B,S,H,hd,4)."""
    gs = [jnp.einsum("bsd,dhk->bshk", x, p[f"w_{g}"]) + p[f"b_{g}"]
          for g in ("i", "f", "z", "o")]
    return jnp.stack(gs, axis=-1)


def slstm_forward(cfg: ModelConfig, p, xin, return_state: bool = False):
    H, hd = slstm_dims(cfg)
    B, S, D = xin.shape
    xg = _slstm_pre(cfg, p, xin)                       # (B,S,H,hd,4)

    def body(carry, x_t):
        new = _slstm_step(p, carry, x_t)
        return new, new[2]

    init = (jnp.zeros((B, H, hd), jnp.float32), jnp.zeros((B, H, hd), jnp.float32),
            jnp.zeros((B, H, hd), xin.dtype), jnp.full((B, H, hd), -50.0, jnp.float32))
    final, hs = jax.lax.scan(body, init, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, D)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    ff = jnp.einsum("bsf,fd->bsd",
                    jnp.einsum("bsd,df->bsf", y, p["ffn_up"]) *
                    jax.nn.silu(jnp.einsum("bsd,df->bsf", y, p["ffn_gate"])),
                    p["ffn_down"])
    out = y + ff
    if return_state:
        return out, {"c": final[0], "n": final[1], "h": final[2], "m": final[3]}
    return out


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    H, hd = slstm_dims(cfg)
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(),
            "h": jnp.zeros((batch, H, hd), dtype),
            "m": jnp.full((batch, H, hd), -50.0, jnp.float32)}


def slstm_decode(cfg: ModelConfig, p, xin, cache):
    H, hd = slstm_dims(cfg)
    B, _, D = xin.shape
    xg = _slstm_pre(cfg, p, xin)[:, 0]                 # (B,H,hd,4)
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_step(p, carry, xg)
    y = h.reshape(B, 1, D)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    ff = jnp.einsum("bsf,fd->bsd",
                    jnp.einsum("bsd,df->bsf", y, p["ffn_up"]) *
                    jax.nn.silu(jnp.einsum("bsd,df->bsf", y, p["ffn_gate"])),
                    p["ffn_down"])
    return y + ff, {"c": c, "n": n, "h": h, "m": m}
