"""Mamba2 (SSD) block: chunked-parallel training form + O(1) decode.

TPU adaptation: the chunked state-space-dual algorithm maps onto MXU
einsums — intra-chunk (L x L) score matmuls and inter-chunk state
recurrence via lax.scan. The per-chunk state update is also implemented as
a Pallas kernel (kernels/mamba2_chunk.py); this jnp version is the oracle
and the dry-run path.

Shapes: inner = expand * d_model, H = inner / head_dim(P), groups G share
B/C (GVA). conv_dim = inner + 2*G*N is depthwise-convolved causally.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import modes
from repro.sharding.constraints import constrain
from repro.models.common import ParamSpec, rms_norm


def dims(cfg: ModelConfig):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    H = inner // s.head_dim
    conv_dim = inner + 2 * s.num_groups * s.state_dim
    return inner, H, conv_dim


def mamba2_spec(cfg: ModelConfig) -> Dict:
    s = cfg.ssm
    D = cfg.d_model
    inner, H, conv_dim = dims(cfg)
    G, N = s.num_groups, s.state_dim
    proj_out = 2 * inner + 2 * G * N + H
    return {
        "in_proj": ParamSpec((D, proj_out), ("embed", "inner")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), ("conv", "inner")),
        "conv_b": ParamSpec((conv_dim,), ("inner",), "zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), "ones"),
        "D_skip": ParamSpec((H,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), "zeros"),
        "out_norm": ParamSpec((inner,), ("inner",), "zeros"),
        "out_proj": ParamSpec((inner, D), ("inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, proj):
    s = cfg.ssm
    inner, H, _ = dims(cfg)
    G, N = s.num_groups, s.state_dim
    z, x, Bm, Cm, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + G * N, 2 * inner + 2 * G * N], axis=-1
    )
    return z, x, Bm, Cm, dt


def _causal_conv(x, w, b, width: int):
    """Depthwise causal conv via shifted adds. x: (B,S,C), w: (width, C)."""
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out + b)


def _conv_step(x_new, conv_state, w, b):
    """x_new: (B,C); conv_state: (B, width-1, C) holding previous inputs."""
    full = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # (B,width,C)
    y = jnp.einsum("bwc,wc->bc", full, w) + b
    return jax.nn.silu(y), full[:, 1:]


def mamba2_forward(cfg: ModelConfig, p, xin, return_state: bool = False):
    """Full-sequence forward. xin: (B,S,D)."""
    s = cfg.ssm
    inner, H, conv_dim = dims(cfg)
    G, N, P, L = s.num_groups, s.state_dim, s.head_dim, s.chunk_size
    B_, S, _ = xin.shape

    proj = jnp.einsum("bsd,dp->bsp", xin, p["in_proj"])
    proj = constrain(proj, "batch", None, None)
    z, x, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(jnp.concatenate([x, Bm, Cm], -1), p["conv_w"], p["conv_b"], s.conv_width)
    x, Bm, Cm = jnp.split(xbc, [inner, inner + G * N], axis=-1)

    xh = x.reshape(B_, S, H, P)
    Bg = Bm.reshape(B_, S, G, N)
    Cg = Cm.reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,) negative
    dA = dt * A                                            # (B,S,H) log-decay

    # Pad S to a multiple of chunk L.
    pad = (-S) % L
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bg = jnp.pad(Bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cg = jnp.pad(Cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // L
    rs = lambda t: t.reshape((B_, nc, L) + t.shape[2:]).swapaxes(0, 1)
    xc, Bc, Cc, dtc, dAc = map(rs, (xh, Bg, Cg, dt, dA))   # leading nc for scan

    hg = H // G

    def chunk_body(state, xs):
        x_c, B_c, C_c, dt_c, dA_c = xs                     # (B,L,...)
        cum = jnp.cumsum(dA_c, axis=1)                     # (B,L,H)
        xdt = x_c * dt_c[..., None].astype(x_c.dtype)      # (B,L,H,P)
        # Intra-chunk: scores[t,s] = (C_t . B_s) * exp(cum_t - cum_s), s<=t.
        cb = jnp.einsum("blgn,bsgn->bgls", C_c, B_c)       # (B,G,L,L)
        cb = jnp.repeat(cb, hg, axis=1)                    # (B,H,L,L)
        dec = cum[:, :, None, :] - cum[:, None, :, :]      # (B,L,L,H) t,s
        mask = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.where(mask[None, :, :, None], dec, -jnp.inf)
        scores = cb * jnp.exp(dec).transpose(0, 3, 1, 2)   # (B,H,L,L)
        y = jnp.einsum("bhls,bshp->blhp", scores.astype(x_c.dtype), xdt)
        # Inter-chunk: contribution of carried state.
        Ch = jnp.repeat(C_c, hg, axis=2) if G != H else C_c   # (B,L,H,N)
        y = y + jnp.einsum("blhn,bhnp->blhp",
                           (Ch * jnp.exp(cum)[..., None].astype(Ch.dtype)),
                           state).astype(x_c.dtype)
        # State update.
        last = cum[:, -1]                                   # (B,H)
        w_in = jnp.exp(last[:, None] - cum)                 # (B,L,H)
        Bh = jnp.repeat(B_c, hg, axis=2) if G != H else B_c  # (B,L,H,N)
        s_local = jnp.einsum("blhn,blhp->bhnp",
                             Bh * w_in[..., None].astype(Bh.dtype), xdt)
        state = jnp.exp(last)[..., None, None] * state + s_local.astype(jnp.float32)
        # keep the carry's sharding identical to state0 (scan carry avals
        # include shardings under sharding-in-types)
        state = constrain(state, "batch", "ssm_heads", None, None)
        return state, y

    state0 = constrain(jnp.zeros((B_, H, N, P), jnp.float32),
                       "batch", "ssm_heads", None, None)
    final_state, ys = modes.scan(chunk_body, state0, (xc, Bc, Cc, dtc, dAc))
    y = ys.swapaxes(0, 1).reshape(B_, Sp, H, P)[:, :S]
    y = y + xh[:, :S] * p["D_skip"][None, None, :, None].astype(y.dtype)

    y = y.reshape(B_, S, inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        return out, final_state
    return out


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    inner, H, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, s.state_dim, s.head_dim), jnp.float32),
    }


def mamba2_prefill(cfg: ModelConfig, p, xin):
    """Forward + cache for subsequent decode."""
    s = cfg.ssm
    inner, H, conv_dim = dims(cfg)
    G, N = s.num_groups, s.state_dim
    B_, S, _ = xin.shape
    proj = jnp.einsum("bsd,dp->bsp", xin, p["in_proj"])
    _, x, Bm, Cm, _ = _split_proj(cfg, proj)
    pre_conv = jnp.concatenate([x, Bm, Cm], -1)            # (B,S,conv_dim)
    w = s.conv_width - 1
    tail = pre_conv[:, -w:] if S >= w else jnp.pad(pre_conv, ((0, 0), (w - S, 0), (0, 0)))
    out, state = mamba2_forward(cfg, p, xin, return_state=True)
    return out, {"conv": tail, "ssm": state}


def mamba2_decode(cfg: ModelConfig, p, xin, cache):
    """One step. xin: (B,1,D)."""
    s = cfg.ssm
    inner, H, conv_dim = dims(cfg)
    G, N, P = s.num_groups, s.state_dim, s.head_dim
    B_ = xin.shape[0]
    proj = jnp.einsum("bd,dp->bp", xin[:, 0], p["in_proj"])
    z, x, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc, conv_state = _conv_step(jnp.concatenate([x, Bm, Cm], -1),
                                 cache["conv"], p["conv_w"], p["conv_b"])
    x, Bm, Cm = jnp.split(xbc, [inner, inner + G * N], axis=-1)
    xh = x.reshape(B_, H, P)
    Bg = Bm.reshape(B_, G, N)
    Cg = Cm.reshape(B_, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                    # (B,H)
    hg = H // G
    Bh = jnp.repeat(Bg, hg, axis=1)
    Ch = jnp.repeat(Cg, hg, axis=1)
    upd = jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32),
                     (xh * dt[..., None].astype(xh.dtype)).astype(jnp.float32))
    state = a[..., None, None] * cache["ssm"] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y.astype(xh.dtype) + xh * p["D_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(B_, inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None]
    return out, {"conv": conv_state, "ssm": state}
