"""GQA attention: full / sliding-window / cross, train + prefill + decode.

The full-sequence path is *query-block chunked* (flash-style running
log-sum-exp over KV blocks) so prefill_32k never materialises an (S, S)
score matrix. The same math is implemented as a Pallas TPU kernel in
``repro.kernels.flash_attention``; this jnp version is the oracle and the
CPU/dry-run path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, modes
from repro.sharding.constraints import constrain
from repro.models.common import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_spec(cfg: ModelConfig, cross: bool = False) -> Dict:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), "zeros")
        spec["bk"] = ParamSpec((K, hd), ("kv_heads", "head_dim"), "zeros")
        spec["bv"] = ParamSpec((K, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), ("norm",), "zeros")
        spec["k_norm"] = ParamSpec((hd,), ("norm",), "zeros")
    return spec


def _project_q(cfg: ModelConfig, p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, "batch", None, "heads", "head_dim")
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
    return q


def _project_kv(cfg: ModelConfig, p, x):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    k = constrain(k, "batch", None, "kv_heads", "head_dim")
    v = constrain(v, "batch", None, "kv_heads", "head_dim")
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


def _positions(cfg: ModelConfig, q, k, q_pos, k_pos, mrope_pos):
    if cfg.mrope_sections and mrope_pos is not None:
        q = common.apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = common.apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos_emb == "rope":
        q = common.apply_rope(q, q_pos, cfg.rope_theta)
        k = common.apply_rope(k, k_pos, cfg.rope_theta)
    return q, k


def _softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits


# ---------------------------------------------------------------------------
# Chunked full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def _attend_dense(cfg, q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Sk,K,hd) mask: (Sq,Sk) bool (True=keep).

    TPU layout: KV is expanded to the query-head count so the score einsum
    contracts only the (replicated) head_dim — sharding stays on
    (batch, heads) with zero per-score collectives. When heads don't divide
    the model axis, the KV *sequence* is sharded over `model` instead
    (softmax then needs only small (B,H,Sq) all-reduces for max/sum).
    """
    from repro.sharding.constraints import mesh_axis_size

    B, Sq, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    msize = mesh_axis_size("model")
    heads_ok = msize > 0 and H % msize == 0
    if heads_ok:
        k = constrain(k, "batch", None, "heads", None)
        v = constrain(v, "batch", None, "heads", None)
    else:
        k = constrain(k, "batch", "kv_seq", None, None)
        v = constrain(v, "batch", "kv_seq", None, None)
    logits = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    logits = constrain(logits, "batch", "heads", None, None) if heads_ok \
        else constrain(logits, "batch", None, None, "kv_seq")
    logits = _softcap(logits * (hd ** -0.5), cfg.attn_logit_softcap)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    out = constrain(out, "batch", None, "heads", None)
    return out


def _pallas_attention_viable(q, k) -> bool:
    """Route through the Pallas flash kernel: enabled, single-device (the
    kernel is per-shard; inside pjit the jnp path lowers with GSPMD), and
    MXU-aligned shapes."""
    from repro.kernels import ops
    from repro.sharding.constraints import _current_mesh

    if not ops.use_pallas() or _current_mesh() is not None:
        return False
    B, S, H, hd = q.shape
    K = k.shape[2]
    return S % 128 == 0 and k.shape[1] % 128 == 0 and H % K == 0


def chunked_attention(cfg: ModelConfig, q, k, v, *, causal: bool,
                      window: Optional[int], q_block: int = 1024):
    """Flash-style: scan over query blocks; per block, dense vs full K.

    Memory per block is O(q_block * S); the (S,S) matrix never exists.
    Routes through the Pallas flash-attention kernel when viable.
    """
    B, S, H, hd = q.shape
    if _pallas_attention_viable(q, k):
        from repro.kernels import ops

        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            softcap=cfg.attn_logit_softcap)
        return out.transpose(0, 2, 1, 3)
    if S <= q_block:
        mask = _make_mask(S, S, 0, causal, window)
        return _attend_dense(cfg, q, k, v, mask)
    nb = S // q_block
    rem = S - nb * q_block

    def body(_, qb_idx):
        start = qb_idx * q_block
        qb = jax.lax.dynamic_slice_in_dim(q, start, q_block, axis=1)
        mask = _make_mask_dyn(q_block, S, start, causal, window)
        return None, _attend_dense(cfg, qb, k, v, mask)

    _, outs = modes.scan(body, None, jnp.arange(nb))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * q_block, H, hd)
    if rem:
        qb = q[:, nb * q_block:]
        mask = _make_mask_dyn(rem, S, nb * q_block, causal, window)
        out = jnp.concatenate([out, _attend_dense(cfg, qb, k, v, mask)], axis=1)
    return out


def _make_mask(sq, sk, offset, causal, window):
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def _make_mask_dyn(sq, sk, start, causal, window):
    qi = start + jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


# ---------------------------------------------------------------------------
# Public block entry points
# ---------------------------------------------------------------------------


def attn_forward(cfg: ModelConfig, p, x, *, causal=True, window=None,
                 positions=None, mrope_pos=None):
    """Full-sequence self-attention. x: (B,S,D)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    q, k = _positions(cfg, q, k, positions, positions, mrope_pos)
    out = chunked_attention(cfg, q, k, v, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attn_forward(cfg: ModelConfig, p, x, enc_k, enc_v):
    """Cross-attention against precomputed encoder K/V (no positions)."""
    q = _project_q(cfg, p, x)
    mask = jnp.ones((q.shape[1], enc_k.shape[1]), bool)
    out = _attend_dense(cfg, q, enc_k, enc_v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_kv(cfg: ModelConfig, p, x_enc):
    """Precompute cross-attention K/V from encoder output."""
    return _project_kv(cfg, p, x_enc)


# -- prefill: same as forward but also returns the KV cache ---------------


def attn_prefill(cfg: ModelConfig, p, x, cache_len: int, *, causal=True,
                 window=None, positions=None, mrope_pos=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    q, k = _positions(cfg, q, k, positions, positions, mrope_pos)
    out = chunked_attention(cfg, q, k, v, causal=causal, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    K, hd = cfg.num_kv_heads, cfg.head_dim
    ck = jnp.zeros((B, cache_len, K, hd), k.dtype).at[:, :S].set(k)
    cv = jnp.zeros((B, cache_len, K, hd), v.dtype).at[:, :S].set(v)
    return y, (ck, cv)


# -- decode: one new token against the cache -------------------------------


def attn_decode(cfg: ModelConfig, p, x, cache: Tuple, pos, *, window=None,
                mrope_pos=None):
    """x: (B,1,D); cache (ck, cv): (B,Smax,K,hd); pos: scalar int32.

    Returns (y, new_cache). The attention over the cache is the jnp oracle
    for kernels/decode_attention.
    """
    ck, cv = cache
    B, Smax, K, hd = ck.shape
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    pos_b = jnp.full((B, 1), pos)
    q, k = _positions(cfg, q, k, pos_b, pos_b, mrope_pos)
    from repro.sharding import rules as _rules_upd  # noqa: F401 (registers update rules)
    from repro.sharding.constraints import _current_mesh as _cm

    _mesh_upd = _cm()
    if _mesh_upd is not None:
        # Mask-based update: a dynamic-update-slice at a traced position
        # into a sequence-sharded cache forces GSPMD to replicate the whole
        # cache (observed +134 MB/layer); a where() is elementwise-local.
        sel = (jnp.arange(Smax) == pos)[None, :, None, None]
        ck = jnp.where(sel, k.astype(ck.dtype), ck)
        cv = jnp.where(sel, v.astype(cv.dtype), cv)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
    ki = jnp.arange(Smax)
    valid = ki <= pos
    if window is not None:
        valid &= ki > pos - window
    H = cfg.num_heads
    from repro.sharding.constraints import mesh_axis_size

    from repro.sharding import rules as _rules
    from repro.sharding.constraints import _current_mesh

    # Pallas decode-attention kernel (single-device serving path).
    from repro.kernels import ops as _ops

    if (_ops.use_pallas() and _current_mesh() is None and Smax % 256 == 0
            and H % K == 0 and not cfg.mrope_sections):
        out = _ops.decode_attention(
            q[:, 0], ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3), pos,
            window=window, softcap=cfg.attn_logit_softcap)[:, None]
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, (ck, cv)

    ke, ve = ck, cv
    if K != H:
        ke = jnp.repeat(ck, H // K, axis=2)
        ve = jnp.repeat(cv, H // K, axis=2)
    msize = mesh_axis_size("model")
    mesh = _current_mesh()
    seq_layout = (mesh is not None
                  and _rules.decode_kv_plan(B, K, mesh, H) == "seq")
    heads_ok = (not seq_layout) and msize > 0 and H % msize == 0
    if seq_layout:
        # Flash-decode layout: KV sequence sharded over `model`; softmax
        # max/sum and the (B,H,hd) output are the only cross-shard
        # reductions (§Perf iteration, decode pairs).
        ke = constrain(ke, "batch", "kv_seq", None, None)
        ve = constrain(ve, "batch", "kv_seq", None, None)
    elif heads_ok:
        ke = constrain(ke, "batch", "seq", "heads", None)
        ve = constrain(ve, "batch", "seq", "heads", None)
    qh = q[:, 0]                                        # (B,H,hd)
    logits = jnp.einsum("bhk,bthk->bht", qh, ke).astype(jnp.float32)
    if seq_layout:
        logits = constrain(logits, "batch", None, "kv_seq")
    elif heads_ok:
        logits = constrain(logits, "batch", "heads", None)
    else:
        logits = constrain(logits, "batch", None, "kv_seq")
    logits = _softcap(logits * (hd ** -0.5), cfg.attn_logit_softcap)
    logits = jnp.where(valid[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bht,bthk->bhk", probs, ve)[:, None]  # (B,1,H,hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, (ck, cv)


def cross_attn_decode(cfg: ModelConfig, p, x, enc_kv):
    enc_k, enc_v = enc_kv
    q = _project_q(cfg, p, x)
    mask = jnp.ones((1, enc_k.shape[1]), bool)
    out = _attend_dense(cfg, q, enc_k, enc_v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])
