"""JAX CNN zoo for the paper's own test models (MobileNetV2/V4,
EfficientNet-B0) — used by the faithful-reproduction benchmarks and as the
workload the green partitioner splits (Eq. 5 cost model).

The model executes the same ConvLayerDef list the partitioner costs, so a
partition boundary at layer i is executable: ``forward_range(params, x, i,
j)`` runs layers [i, j) — that is exactly how segments are deployed onto
simulated edge nodes.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig, ConvLayerDef


def param_count(cfg: CNNConfig) -> int:
    n = 0
    for l in cfg.layers:
        if l.kind == "conv":
            n += l.k * l.k * l.cin * l.cout + l.cout
        elif l.kind == "dwconv":
            n += l.k * l.k * l.cin + l.cin
        elif l.kind == "linear":
            n += l.cin * l.cout + l.cout
        elif l.kind == "se":
            n += 2 * l.cin * l.cout + l.cin + l.cout
    return n


def init_params(cfg: CNNConfig, key: jax.Array) -> List[Dict]:
    params = []
    for i, l in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        if l.kind == "conv":
            fan_in = l.k * l.k * l.cin
            w = jax.random.normal(k, (l.k, l.k, l.cin, l.cout)) * np.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((l.cout,))})
        elif l.kind == "dwconv":
            fan_in = l.k * l.k
            w = jax.random.normal(k, (l.k, l.k, 1, l.cin)) * np.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((l.cin,))})
        elif l.kind == "linear":
            w = jax.random.normal(k, (l.cin, l.cout)) * np.sqrt(1.0 / l.cin)
            params.append({"w": w, "b": jnp.zeros((l.cout,))})
        elif l.kind == "se":
            w1 = jax.random.normal(k, (l.cin, l.cout)) * np.sqrt(1.0 / l.cin)
            w2 = jax.random.normal(jax.random.fold_in(k, 1), (l.cout, l.cin)) * np.sqrt(1.0 / l.cout)
            params.append({"w1": w1, "b1": jnp.zeros((l.cout,)),
                           "w2": w2, "b2": jnp.zeros((l.cin,))})
        else:
            params.append({})
    return params


def _apply_layer(l: ConvLayerDef, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if l.kind == "conv":
        pad = (l.k - 1) // 2
        x = jax.lax.conv_general_dilated(
            x, p["w"], (l.stride, l.stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu6(x + p["b"])
    if l.kind == "dwconv":
        pad = (l.k - 1) // 2
        x = jax.lax.conv_general_dilated(
            x, p["w"], (l.stride, l.stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=l.cin)
        return jax.nn.relu6(x + p["b"])
    if l.kind == "se":
        g = jnp.mean(x, axis=(1, 2))
        y = jax.nn.relu(g @ p["w1"] + p["b1"])
        y = jax.nn.sigmoid(y @ p["w2"] + p["b2"])
        return x * y[:, None, None, :]
    if l.kind == "linear":
        return x @ p["w"] + p["b"]
    if l.kind == "pool":
        return jnp.mean(x, axis=(1, 2)) if x.ndim == 4 else x
    return x


def forward_range(cfg: CNNConfig, params, x, start: int, stop: int):
    """Run layers [start, stop). This is the partition-segment executor."""
    for i in range(start, stop):
        x = _apply_layer(cfg.layers[i], params[i], x)
    return x


def forward(cfg: CNNConfig, params, x):
    return forward_range(cfg, params, x, 0, len(cfg.layers))


def activation_bytes(cfg: CNNConfig, boundary: int, batch: int = 1,
                     dtype_bytes: int = 4) -> int:
    """Size of the tensor crossing a partition boundary before layer i —
    the communication cost the green partitioner minimises."""
    size = cfg.input_size
    ch = cfg.input_channels
    flat = False
    for l in cfg.layers[:boundary]:
        if l.kind in ("conv", "dwconv"):
            size = -(-size // l.stride)
            ch = l.cout if l.kind == "conv" else l.cin
        elif l.kind == "pool":
            flat = True
        elif l.kind == "linear":
            flat = True
            ch = l.cout if l.cout != 0 else ch
    n = ch if flat else size * size * ch
    return n * batch * dtype_bytes
