"""JAX CNN zoo for the paper's own test models (MobileNetV2/V4,
EfficientNet-B0) — used by the faithful-reproduction benchmarks and as the
workload the green partitioner splits (Eq. 5 cost model).

The model executes the same ConvLayerDef list the partitioner costs, so a
partition boundary at layer i is executable: ``forward_range(params, x, i,
j)`` runs layers [i, j) — that is exactly how segments are deployed onto
simulated edge nodes.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig, ConvLayerDef


def param_count(cfg: CNNConfig) -> int:
    n = 0
    for ld in cfg.layers:
        if ld.kind == "conv":
            n += ld.k * ld.k * ld.cin * ld.cout + ld.cout
        elif ld.kind == "dwconv":
            n += ld.k * ld.k * ld.cin + ld.cin
        elif ld.kind == "linear":
            n += ld.cin * ld.cout + ld.cout
        elif ld.kind == "se":
            n += 2 * ld.cin * ld.cout + ld.cin + ld.cout
    return n


def init_params(cfg: CNNConfig, key: jax.Array) -> List[Dict]:
    params = []
    for i, ld in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        if ld.kind == "conv":
            fan_in = ld.k * ld.k * ld.cin
            w = jax.random.normal(k, (ld.k, ld.k, ld.cin, ld.cout)) * np.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((ld.cout,))})
        elif ld.kind == "dwconv":
            fan_in = ld.k * ld.k
            w = jax.random.normal(k, (ld.k, ld.k, 1, ld.cin)) * np.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((ld.cin,))})
        elif ld.kind == "linear":
            w = jax.random.normal(k, (ld.cin, ld.cout)) * np.sqrt(1.0 / ld.cin)
            params.append({"w": w, "b": jnp.zeros((ld.cout,))})
        elif ld.kind == "se":
            w1 = jax.random.normal(k, (ld.cin, ld.cout)) * np.sqrt(1.0 / ld.cin)
            w2 = (jax.random.normal(jax.random.fold_in(k, 1), (ld.cout, ld.cin))
                  * np.sqrt(1.0 / ld.cout))
            params.append({"w1": w1, "b1": jnp.zeros((ld.cout,)),
                           "w2": w2, "b2": jnp.zeros((ld.cin,))})
        else:
            params.append({})
    return params


def _apply_layer(ld: ConvLayerDef, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if ld.kind == "conv":
        pad = (ld.k - 1) // 2
        x = jax.lax.conv_general_dilated(
            x, p["w"], (ld.stride, ld.stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu6(x + p["b"])
    if ld.kind == "dwconv":
        pad = (ld.k - 1) // 2
        x = jax.lax.conv_general_dilated(
            x, p["w"], (ld.stride, ld.stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=ld.cin)
        return jax.nn.relu6(x + p["b"])
    if ld.kind == "se":
        g = jnp.mean(x, axis=(1, 2))
        y = jax.nn.relu(g @ p["w1"] + p["b1"])
        y = jax.nn.sigmoid(y @ p["w2"] + p["b2"])
        return x * y[:, None, None, :]
    if ld.kind == "linear":
        return x @ p["w"] + p["b"]
    if ld.kind == "pool":
        return jnp.mean(x, axis=(1, 2)) if x.ndim == 4 else x
    return x


def forward_range(cfg: CNNConfig, params, x, start: int, stop: int):
    """Run layers [start, stop). This is the partition-segment executor."""
    for i in range(start, stop):
        x = _apply_layer(cfg.layers[i], params[i], x)
    return x


def forward(cfg: CNNConfig, params, x):
    return forward_range(cfg, params, x, 0, len(cfg.layers))


def activation_bytes(cfg: CNNConfig, boundary: int, batch: int = 1,
                     dtype_bytes: int = 4) -> int:
    """Size of the tensor crossing a partition boundary before layer i —
    the communication cost the green partitioner minimises."""
    size = cfg.input_size
    ch = cfg.input_channels
    flat = False
    for ld in cfg.layers[:boundary]:
        if ld.kind in ("conv", "dwconv"):
            size = -(-size // ld.stride)
            ch = ld.cout if ld.kind == "conv" else ld.cin
        elif ld.kind == "pool":
            flat = True
        elif ld.kind == "linear":
            flat = True
            ch = ld.cout if ld.cout != 0 else ch
    n = ch if flat else size * size * ch
    return n * batch * dtype_bytes
