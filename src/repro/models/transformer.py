"""Model assembly: layer-pattern stacks scanned with lax.scan.

The layer stack is ``pattern * repeats + suffix`` (configs/base.py). Params
for each pattern position are stacked with a leading ``repeats`` dim and the
stack is driven by one ``lax.scan`` — HLO size stays O(pattern), not
O(num_layers), which keeps 62-layer compiles cheap and is also what the
green partitioner reasons over.

Public API:
    model_spec / init_params / abstract_params / logical_axes
    forward(cfg, params, batch)           -> (hidden, aux)    full sequence
    unembed(cfg, params, hidden)          -> logits
    init_cache / abstract_cache
    prefill(cfg, params, batch, max_len)  -> (cache, last_hidden)
    decode_step(cfg, params, cache, token, pos) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerDef, ModelConfig
from repro.models import attention, common, mlp, modes, moe, ssm, xlstm
from repro.models.common import ParamSpec
from repro.sharding.constraints import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, ld: LayerDef, decoder: bool) -> Dict:
    D = cfg.d_model
    if ld.kind == "attn":
        spec = {"ln1": common.norm_spec(cfg, D), "attn": attention.attention_spec(cfg)}
        if decoder and cfg.cross_attention:
            spec["ln_x"] = common.norm_spec(cfg, D)
            spec["xattn"] = attention.attention_spec(cfg)
        if cfg.moe is not None:
            spec["ln2"] = common.norm_spec(cfg, D)
            spec["moe"] = moe.moe_spec(cfg)
        elif cfg.d_ff > 0:
            spec["ln2"] = common.norm_spec(cfg, D)
            spec["mlp"] = mlp.mlp_spec(cfg, cfg.d_ff, cfg.mlp_gated)
        return spec
    if ld.kind == "mamba2":
        return {"ln1": common.norm_spec(cfg, D), "mamba": ssm.mamba2_spec(cfg)}
    if ld.kind == "mlstm":
        return {"ln1": common.norm_spec(cfg, D), "mlstm": xlstm.mlstm_spec(cfg)}
    if ld.kind == "slstm":
        return {"ln1": common.norm_spec(cfg, D), "slstm": xlstm.slstm_spec(cfg)}
    raise ValueError(ld.kind)


def model_spec(cfg: ModelConfig) -> Dict:
    D, V = cfg.d_model, cfg.vocab_size
    spec: Dict = {
        "embedding": {"table": ParamSpec((V, D), ("vocab", "embed"), scale=0.02)},
        "final_norm": common.norm_spec(cfg, D),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    # pattern positions, each stacked over repeats
    spec["pattern"] = {
        str(i): common.stack_spec(block_spec(cfg, ld, decoder=True), cfg.repeats)
        for i, ld in enumerate(cfg.pattern)
    }
    if cfg.suffix:
        spec["suffix"] = common.stack_spec(
            block_spec(cfg, cfg.suffix[0], decoder=True), len(cfg.suffix))
    if cfg.encoder_layers:
        spec["encoder"] = common.stack_spec(
            _encoder_block_spec(cfg), cfg.encoder_layers)
        spec["encoder_norm"] = common.norm_spec(cfg, D)
    return spec


def _encoder_block_spec(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    return {
        "ln1": common.norm_spec(cfg, D),
        "attn": attention.attention_spec(cfg),
        "ln2": common.norm_spec(cfg, D),
        "mlp": mlp.mlp_spec(cfg, cfg.d_ff, cfg.mlp_gated),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    return common.init_from_spec(model_spec(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig) -> PyTree:
    return common.abstract_from_spec(model_spec(cfg), jnp.dtype(cfg.param_dtype))


def logical_axes(cfg: ModelConfig) -> PyTree:
    return common.axes_from_spec(model_spec(cfg))


# ---------------------------------------------------------------------------
# Block forward (full sequence)
# ---------------------------------------------------------------------------


def _block_forward(cfg: ModelConfig, ld: LayerDef, p, h, ctx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Residual block. ctx: dict(positions, mrope_pos, enc_kv_fn, causal)."""
    aux = jnp.zeros((), jnp.float32)
    if ld.kind == "attn":
        h = h + attention.attn_forward(
            cfg, p["attn"], common.apply_norm(cfg, p["ln1"], h),
            causal=ctx.get("causal", True), window=ld.window,
            positions=ctx.get("positions"), mrope_pos=ctx.get("mrope_pos"))
        if "xattn" in p and ctx.get("enc_out") is not None:
            xn = common.apply_norm(cfg, p["ln_x"], h)
            ek, ev = attention.encode_kv(cfg, p["xattn"], ctx["enc_out"])
            h = h + attention.cross_attn_forward(cfg, p["xattn"], xn, ek, ev)
        if cfg.moe is not None:
            y, aux = moe.moe_forward(cfg, p["moe"], common.apply_norm(cfg, p["ln2"], h))
            h = h + y
        elif cfg.d_ff > 0:
            h = h + mlp.mlp_forward(cfg, p["mlp"], common.apply_norm(cfg, p["ln2"], h),
                                    cfg.mlp_gated)
    elif ld.kind == "mamba2":
        h = h + ssm.mamba2_forward(cfg, p["mamba"], common.apply_norm(cfg, p["ln1"], h))
    elif ld.kind == "mlstm":
        h = h + xlstm.mlstm_forward(cfg, p["mlstm"], common.apply_norm(cfg, p["ln1"], h))
    elif ld.kind == "slstm":
        h = h + xlstm.slstm_forward(cfg, p["slstm"], common.apply_norm(cfg, p["ln1"], h))
    else:
        raise ValueError(ld.kind)
    return h, aux


def _scan_blocks(cfg: ModelConfig, defs, stacked_params, h, ctx):
    """Scan the repeating unit over its stacked params."""

    def body(carry, xs):
        hh, aux_sum = carry
        for i, ld in enumerate(defs):
            hh, aux = _block_forward(cfg, ld, xs[str(i)], hh, ctx)
            hh = constrain(hh, "batch", None, None)
            aux_sum = aux_sum + aux
        return (hh, aux_sum), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux), _ = modes.scan(body_fn, (h, jnp.zeros((), jnp.float32)), stacked_params)
    return h, aux


# ---------------------------------------------------------------------------
# Embedding / unembedding / inputs
# ---------------------------------------------------------------------------


def embed(cfg: ModelConfig, params, tokens):
    h = params["embedding"]["table"].astype(jnp.dtype(cfg.dtype))[tokens]
    return constrain(h, "batch", None, None)


def unembed(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, params["embedding"]["table"])
    return jnp.einsum("...d,dv->...v", h, params["lm_head"])


def _assemble_inputs(cfg: ModelConfig, params, batch):
    """Returns (h, ctx) for the decoder stack."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    h = embed(cfg, params, tokens)
    ctx: Dict = {"causal": True}
    if cfg.vision_tokens:
        ve = batch["vision_embeds"].astype(h.dtype)
        h = jnp.concatenate([ve, h], axis=1)
    S = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ctx["positions"] = positions
    if cfg.mrope_sections:
        mp = batch.get("mrope_positions")
        if mp is None:
            mp = jnp.broadcast_to(positions[:, None, :], (B, 3, S))
        ctx["mrope_pos"] = mp
    if cfg.pos_emb == "sinusoidal":
        h = h + common.sinusoidal_pos_emb(positions, cfg.d_model).astype(h.dtype)
    if cfg.encoder_layers:
        ctx["enc_out"] = encode(cfg, params, batch["encoder_embeds"])
    return h, ctx


def encode(cfg: ModelConfig, params, enc_embeds):
    """Whisper-style encoder over stub frame embeddings."""
    B, S, _ = enc_embeds.shape
    h = enc_embeds.astype(jnp.dtype(cfg.dtype))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_emb == "sinusoidal":
        h = h + common.sinusoidal_pos_emb(pos, cfg.d_model).astype(h.dtype)

    def body(carry, xs):
        hh = carry
        hh = hh + attention.attn_forward(
            cfg, xs["attn"], common.apply_norm(cfg, xs["ln1"], hh),
            causal=False, window=None, positions=pos)
        hh = hh + mlp.mlp_forward(cfg, xs["mlp"],
                                  common.apply_norm(cfg, xs["ln2"], hh), cfg.mlp_gated)
        return hh, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = modes.scan(body_fn, h, params["encoder"])
    return common.apply_norm(cfg, params["encoder_norm"], h)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / eval)
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h, ctx = _assemble_inputs(cfg, params, batch)
    h, aux = _scan_blocks(cfg, cfg.pattern, params["pattern"], h, ctx)
    if cfg.suffix:
        h, aux2 = _scan_blocks(cfg, (cfg.suffix[0],), {"0": params["suffix"]},
                               h, ctx)
        aux = aux + aux2
    h = common.apply_norm(cfg, params["final_norm"], h)
    return h, aux


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def _block_cache(cfg: ModelConfig, ld: LayerDef, batch: int, max_len: int, dtype):
    if ld.kind == "attn":
        K, hd = cfg.num_kv_heads, cfg.head_dim
        c = {"k": jnp.zeros((batch, max_len, K, hd), dtype),
             "v": jnp.zeros((batch, max_len, K, hd), dtype)}
        if cfg.cross_attention:
            c["xk"] = jnp.zeros((batch, cfg.encoder_seq, K, hd), dtype)
            c["xv"] = jnp.zeros((batch, cfg.encoder_seq, K, hd), dtype)
        return c
    if ld.kind == "mamba2":
        return ssm.mamba2_init_cache(cfg, batch, dtype)
    if ld.kind == "mlstm":
        return xlstm.mlstm_init_cache(cfg, batch, dtype)
    if ld.kind == "slstm":
        return xlstm.slstm_init_cache(cfg, batch, dtype)
    raise ValueError(ld.kind)


def _stack_cache(tree, n):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    cache: Dict = {"pattern": {
        str(i): _stack_cache(_block_cache(cfg, ld, batch, max_len, dtype), cfg.repeats)
        for i, ld in enumerate(cfg.pattern)
    }}
    if cfg.suffix:
        cache["suffix"] = _stack_cache(
            _block_cache(cfg, cfg.suffix[0], batch, max_len, dtype), len(cfg.suffix))
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def _block_prefill(cfg, ld, p, h, ctx, max_len):
    if ld.kind == "attn":
        y, (ck, cv) = attention.attn_prefill(
            cfg, p["attn"], common.apply_norm(cfg, p["ln1"], h), max_len,
            causal=True, window=ld.window,
            positions=ctx.get("positions"), mrope_pos=ctx.get("mrope_pos"))
        h = h + y
        c = {"k": ck, "v": cv}
        if "xattn" in p and ctx.get("enc_out") is not None:
            xn = common.apply_norm(cfg, p["ln_x"], h)
            ek, ev = attention.encode_kv(cfg, p["xattn"], ctx["enc_out"])
            h = h + attention.cross_attn_forward(cfg, p["xattn"], xn, ek, ev)
            c["xk"], c["xv"] = ek, ev
        if cfg.moe is not None:
            y, _ = moe.moe_forward(cfg, p["moe"], common.apply_norm(cfg, p["ln2"], h))
            h = h + y
        elif cfg.d_ff > 0:
            h = h + mlp.mlp_forward(cfg, p["mlp"],
                                    common.apply_norm(cfg, p["ln2"], h), cfg.mlp_gated)
        return h, c
    if ld.kind == "mamba2":
        y, c = ssm.mamba2_prefill(cfg, p["mamba"], common.apply_norm(cfg, p["ln1"], h))
        return h + y, c
    if ld.kind == "mlstm":
        y, c = xlstm.mlstm_forward(cfg, p["mlstm"],
                                   common.apply_norm(cfg, p["ln1"], h), return_state=True)
        return h + y, c
    if ld.kind == "slstm":
        y, c = xlstm.slstm_forward(cfg, p["slstm"],
                                   common.apply_norm(cfg, p["ln1"], h), return_state=True)
        return h + y, c
    raise ValueError(ld.kind)


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Run the prompt, build the cache. Returns (cache, last_hidden)."""
    h, ctx = _assemble_inputs(cfg, params, batch)

    def body(hh, xs):
        caches = {}
        for i, ld in enumerate(cfg.pattern):
            hh, c = _block_prefill(cfg, ld, xs[str(i)], hh, ctx, max_len)
            caches[str(i)] = c
        return hh, caches

    h, pattern_cache = modes.scan(body, h, params["pattern"])
    cache = {"pattern": pattern_cache}
    if cfg.suffix:
        def sbody(hh, xs):
            hh, c = _block_prefill(cfg, cfg.suffix[0], xs, hh, ctx, max_len)
            return hh, c
        h, cache["suffix"] = modes.scan(sbody, h, params["suffix"])
    h = common.apply_norm(cfg, params["final_norm"], h)
    return cache, h[:, -1]


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------


def _block_decode(cfg, ld, p, c, h, pos, ctx):
    if ld.kind == "attn":
        xn = common.apply_norm(cfg, p["ln1"], h)
        mrope = None
        if cfg.mrope_sections:
            B = h.shape[0]
            mrope = jnp.broadcast_to(jnp.asarray(pos)[None, None, None], (B, 3, 1))
        y, (ck, cv) = attention.attn_decode(
            cfg, p["attn"], xn, (c["k"], c["v"]), pos, window=ld.window,
            mrope_pos=mrope)
        h = h + y
        c = dict(c, k=ck, v=cv)
        if "xattn" in p and "xk" in c:
            xn = common.apply_norm(cfg, p["ln_x"], h)
            h = h + attention.cross_attn_decode(cfg, p["xattn"], xn, (c["xk"], c["xv"]))
        if cfg.moe is not None:
            y, _ = moe.moe_forward(cfg, p["moe"], common.apply_norm(cfg, p["ln2"], h))
            h = h + y
        elif cfg.d_ff > 0:
            h = h + mlp.mlp_forward(cfg, p["mlp"],
                                    common.apply_norm(cfg, p["ln2"], h), cfg.mlp_gated)
        return h, c
    if ld.kind == "mamba2":
        y, c = ssm.mamba2_decode(cfg, p["mamba"], common.apply_norm(cfg, p["ln1"], h), c)
        return h + y, c
    if ld.kind == "mlstm":
        y, c = xlstm.mlstm_decode(cfg, p["mlstm"], common.apply_norm(cfg, p["ln1"], h), c)
        return h + y, c
    if ld.kind == "slstm":
        y, c = xlstm.slstm_decode(cfg, p["slstm"], common.apply_norm(cfg, p["ln1"], h), c)
        return h + y, c
    raise ValueError(ld.kind)


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token: (B,1) int32; pos: scalar int32. Returns (logits (B,V), cache)."""
    h = embed(cfg, params, token)
    if cfg.pos_emb == "sinusoidal":
        h = h + common.sinusoidal_pos_emb(
            jnp.full((h.shape[0], 1), pos), cfg.d_model).astype(h.dtype)
    ctx: Dict = {}

    def body(hh, xs):
        p, c = xs
        new_c = {}
        for i, ld in enumerate(cfg.pattern):
            hh, nc = _block_decode(cfg, ld, p[str(i)], c[str(i)], hh, pos, ctx)
            new_c[str(i)] = nc
        return hh, new_c

    h, new_pattern = modes.scan(body, h, (params["pattern"], cache["pattern"]))
    new_cache = {"pattern": new_pattern}
    if cfg.suffix:
        def sbody(hh, xs):
            p, c = xs
            hh, nc = _block_decode(cfg, cfg.suffix[0], p, c, hh, pos, ctx)
            return hh, nc
        h, new_cache["suffix"] = modes.scan(sbody, (h), (params["suffix"], cache["suffix"]))
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = unembed(cfg, params, h[:, 0])
    return logits, new_cache
