"""Gated (SwiGLU) and plain MLP blocks."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, activation
from repro.sharding.constraints import constrain


def mlp_spec(cfg: ModelConfig, d_ff: int, gated: bool) -> Dict:
    D = cfg.d_model
    spec = {
        "w_up": ParamSpec((D, d_ff), ("embed", "ff")),
        "w_down": ParamSpec((d_ff, D), ("ff", "embed")),
    }
    if gated:
        spec["w_gate"] = ParamSpec((D, d_ff), ("embed", "ff"))
    return spec


def mlp_forward(cfg: ModelConfig, p, x, gated: bool):
    act = activation(cfg.act)
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if gated:
        up = up * act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    else:
        up = act(up)
    up = constrain(up, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", up, p["w_down"])
