"""Per-model candidate cut profiles for joint (cut, node) scheduling.

The scalar green partitioner (core/partitioner.py) answers "how do I split
this model across a *given* node list". The joint scheduler asks the
converse: "over every candidate cut point and every node, which (cut,
node) pair scores best right now". This module derives the per-model side
of that decision once — a :class:`CutProfile` holding vectorized (P,)
per-segment FLOP and activation-byte columns from the same cost fronts
``partition_costs`` uses (``costmodel.cnn_costs`` + ``models.cnn.
activation_bytes`` for CNNs, ``costmodel.block_flops`` +
``costmodel.boundary_bytes`` for transformers) — so the per-step work in
:class:`repro.partition.policy.PartitionPolicy` is pure column math.

Cut semantics: cut ``c`` runs layers [0, c) on the requesting device and
offloads layers [c, L) to the chosen node. ``c = 0`` (always a candidate)
is full offload — exactly what the cut-unaware scheduler does — so the
joint decision can only match or beat it under the same scoring rule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import CNNConfig, ModelConfig
from repro.core import costmodel


@dataclass(frozen=True)
class CutProfile:
    """Candidate cuts for one model with (P,)-aligned per-segment columns.

    Frozen and tuple-backed so a profile is hashable — the FeatureCache
    keys its per-profile joint column block on the profile object itself
    (see ``FeatureCache.partition_block``).
    """

    name: str
    total_cost: float                    # sum of per-layer Eq. 5 costs/FLOPs
    cuts: Tuple[int, ...]                # (P,) ascending cut indices, cuts[0] == 0
    local_cost: Tuple[float, ...]        # (P,) cost of layers [0, c)
    remote_cost: Tuple[float, ...]       # (P,) cost of layers [c, L)
    comm_bytes: Tuple[float, ...]        # (P,) activation bytes crossing c

    @property
    def num_cuts(self) -> int:
        return len(self.cuts)

    def remote_frac(self) -> np.ndarray:
        """(P,) fraction of the model's compute that lands on the fleet."""
        r = np.asarray(self.remote_cost, dtype=np.float64)
        return r / max(self.total_cost, 1e-12)

    def comm_seconds(self, link_mbps: float) -> np.ndarray:
        """(P,) transfer time of the boundary activation over the uplink."""
        return np.asarray(self.comm_bytes, dtype=np.float64) / (link_mbps * 125000.0)


def profile_costs(costs: Sequence[float],
                  boundary_bytes: Optional[Sequence[float]] = None,
                  name: str = "model", max_cuts: int = 32) -> CutProfile:
    """Build a :class:`CutProfile` from per-layer costs + boundary bytes.

    Candidate cuts are every layer index 0..L-1 (the offloaded suffix is
    never empty — a fully-local task needs no placement at all). When the
    model has more layers than ``max_cuts``, the candidates are thinned
    deterministically to the cuts with the smallest crossing bytes (ties
    by index), always keeping cut 0, and re-sorted ascending.
    """
    costs = np.asarray(costs, dtype=np.float64)
    L = costs.size
    bb = np.asarray(boundary_bytes if boundary_bytes is not None
                    else np.zeros(L + 1), dtype=np.float64)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])         # (L+1,)
    cand = np.arange(max(L, 1))
    if max_cuts and cand.size > max_cuts:
        rest = cand[1:]
        order = np.lexsort((rest, bb[rest]))                   # bytes, then index
        cand = np.concatenate([[0], np.sort(rest[order[:max_cuts - 1]])])
    return CutProfile(
        name=name,
        total_cost=float(prefix[-1]),
        cuts=tuple(int(c) for c in cand),
        local_cost=tuple(float(x) for x in prefix[cand]),
        remote_cost=tuple(float(x) for x in prefix[-1] - prefix[cand]),
        comm_bytes=tuple(float(x) for x in bb[cand]),
    )


def profile_cnn(cfg: CNNConfig, batch: int = 1, max_cuts: int = 32,
                name: Optional[str] = None) -> CutProfile:
    """Cut profile for a CNN-zoo config (Eq. 5 costs + activation bytes)."""
    from repro.models import cnn as cnn_mod

    costs = costmodel.cnn_costs(cfg)
    bb = [cnn_mod.activation_bytes(cfg, i, batch)
          for i in range(len(costs) + 1)]
    return profile_costs(costs, bb, name or getattr(cfg, "name", "cnn"),
                         max_cuts)


def profile_transformer(cfg: ModelConfig, seq: int, batch: int,
                        max_cuts: int = 32,
                        name: Optional[str] = None) -> CutProfile:
    """Cut profile for a transformer config (per-block FLOPs + constant
    hidden-state boundary bytes)."""
    costs = [costmodel.block_flops(cfg, ld, seq, batch)
             for ld in cfg.layer_defs]
    bb = [costmodel.boundary_bytes(cfg, seq, batch)] * (len(costs) + 1)
    return profile_costs(costs, bb,
                         name or getattr(cfg, "name", "transformer"),
                         max_cuts)
