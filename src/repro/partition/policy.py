"""Joint partition+placement policy: (B, N) scoring widened to (B, P, N).

:class:`PartitionPolicy` is a ``SchedulingPolicy``-compatible scorer that
decides a **(partition cut, node) pair** per task instead of a bare node
index. The Eq. 3/4 scoring rule is unchanged — only two feature columns
widen per (cut, node) cell:

- ``COL_TIME_S``: the *offloaded segment's* service time,
  ``avg_time_s[n] * remote_frac[p] + comm_s[p]`` (the boundary activation
  must cross the uplink before the node can start);
- ``COL_IXE``: Eq. 4's ``I * E_est`` with E_est derived from that widened
  time at the node's power draw.

S_R, S_L, S_B and feasibility stay per-node, so the Pallas kernel's tile
math (``kernels.node_score._eq3_tile_scores``) is reused verbatim by the
(B, P, N) on-chip reduction (``select_best_joint``); the numpy column path
broadcasts the cached (P, N) time/energy block
(``FeatureCache.partition_block``) and the scalar cut-major loop
:func:`select_joint_scalar` is the bit-exact parity oracle per house
style. Cut candidates come from a :class:`~repro.partition.profile.
CutProfile`; the scalar DP (``core.partitioner.partition_costs``) remains
the oracle for multi-segment splits of a *fixed* node list.
"""
from __future__ import annotations

from dataclasses import dataclass
from time import perf_counter
from typing import List, Optional, Sequence

import numpy as np

from repro.core.api import CarbonIntensityProvider
from repro.core.policy import (COL_CPU_FREE, COL_IXE, COL_LOAD, COL_MEM_FREE,
                               COL_RUNNING, COL_TIME_S, COL_VALID,
                               FEATURE_DIM, VectorizedPolicy, _SelectionMemo,
                               get_cache)
from repro.core.scheduler import Task, Weights, node_feasible
from repro.partition.profile import CutProfile

# Default uplink between the requesting device and the fleet: a 100 Mbps
# edge wireless link, slow enough that shipping a large early-layer
# activation genuinely competes with computing locally.
DEFAULT_LINK_MBPS = 100.0


def joint_time_energy(avg_time_s, power_w, remote_frac, comm_s):
    """Widened (cut, node) service time (s) and Eq. 4 energy (kWh).

    THE single statement of the joint columns' arithmetic: the scalar
    oracle evaluates it per cell, ``FeatureCache.partition_block``
    broadcasts the identical expressions over (P, N) — bit-exact parity by
    construction. Accepts scalars or broadcastable arrays.
    """
    t = avg_time_s * remote_frac + comm_s
    e = power_w * (t * 1000.0) / 3.6e6
    return t, e


@dataclass(frozen=True)
class JointDecision:
    """One task's joint decision: offload layers [cut, L) to ``node``."""

    node: str
    cut: int             # layer index (profile.cuts[cut_index])
    cut_index: int       # p — row into the profile's (P,) columns
    score: float
    remote_frac: float
    comm_s: float

    def effective_latency_ms(self, base_latency_ms: float) -> float:
        """Base latency of the offloaded segment (what the fleet executes
        and bills): the remote compute share plus the uplink transfer."""
        return base_latency_ms * self.remote_frac + self.comm_s * 1000.0


def select_joint_scalar(cluster, task: Task, profile: CutProfile,
                        weights: Weights,
                        provider: Optional[CarbonIntensityProvider] = None,
                        now_hour: float = 0.0,
                        latency_threshold_ms: float = 5000.0,
                        link_mbps: float = DEFAULT_LINK_MBPS
                        ) -> Optional[JointDecision]:
    """Cut-major Python loop over (p, n) — the joint parity oracle.

    Iterates cuts in the outer loop and nodes in insertion order inside,
    keeping the first strict maximum, so exact ties resolve to the lowest
    (p, n) — np.argmax semantics over the flattened (P, N) plane, which
    the numpy column path and the Pallas fold both reproduce. Component
    accumulation order matches the column path exactly (task-independent
    base first, then the S_R term), keeping parity bit-exact.
    """
    w = weights.as_array()
    rf = profile.remote_frac()
    cs = profile.comm_seconds(link_mbps)
    rows = []
    for name, st in cluster.nodes.items():
        if st.avg_time_ms > latency_threshold_ms:
            continue
        if not node_feasible(st, task):
            continue
        intensity = (provider.intensity(name, now_hour)
                     if provider is not None else st.spec.carbon_intensity)
        free_cpu = st.spec.cpu * (1.0 - st.load)
        free_mem = st.spec.mem_mb - st.mem_used_mb
        cpu_frac = free_cpu / task.cpu if task.cpu > 0 else 1.0
        mem_frac = free_mem / task.mem_mb if task.mem_mb > 0 else 1.0
        s_r = 0.5 * min(1.0, cpu_frac) + 0.5 * min(1.0, mem_frac)
        rows.append((name, s_r, 1.0 - st.load,
                     1.0 / (1.0 + st.running * 2.0),
                     st.avg_time_ms / 1000.0,
                     st.power_w(cluster.host_power_w), intensity))
    best_score, best = 0.0, None
    for p in range(profile.num_cuts):
        for name, s_r, s_l, s_b, avg_s, power, intensity in rows:
            t, e = joint_time_energy(avg_s, power, rf[p], cs[p])
            base = (w[1] * s_l + w[2] * (1.0 / (1.0 + t)) + w[3] * s_b
                    + w[4] * (1.0 / (1.0 + intensity * e)))
            s = w[0] * s_r + base
            if s > best_score:
                best_score = s
                best = JointDecision(name, profile.cuts[p], p, float(s),
                                     float(rf[p]), float(cs[p]))
    return best


class PartitionPolicy:
    """Batched joint (cut, node) selection over one :class:`CutProfile`.

    ``backend`` mirrors :class:`~repro.core.policy.VectorizedPolicy`:
    ``"numpy"`` broadcasts the cached (P, N) column block (bit-exact with
    the scalar oracle), ``"pallas"`` runs the fused (B, P, N) on-chip
    reduction (float32, interpret mode off TPU), ``"auto"`` picks by host.
    The fleet-scale machinery carries over: features come from the
    cluster's incremental FeatureCache (per-profile (P, N) block cached on
    ``data_rev``), duplicate (cpu, mem_mb) task profiles share one scored
    row, and steady-state selections memoize per profile epoch. Clusters
    without FeatureCache plumbing fall back to the scalar oracle per task.

    As an engine policy, ``select_batch`` returns node names and exposes
    the per-task joint decisions on ``last_decisions``;
    ``execution_latency_ms`` is the :class:`~repro.core.api.
    CarbonEdgeEngine` hook that makes the engine execute and bill only the
    offloaded segment (local-segment compute runs on the requesting
    device, outside the fleet's ledgers).
    """

    name = "partition"

    def __init__(self, profile: CutProfile, backend: str = "auto",
                 latency_threshold_ms: float = 5000.0,
                 link_mbps: float = DEFAULT_LINK_MBPS,
                 use_cache: bool = True, use_select_memo: bool = True):
        if backend not in ("auto", "numpy", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.profile = profile
        self.backend = backend
        self.latency_threshold_ms = latency_threshold_ms
        self.link_mbps = link_mbps
        self.use_cache = use_cache
        self.use_select_memo = use_select_memo
        self._rf = profile.remote_frac()             # (P,)
        self._cs = profile.comm_seconds(link_mbps)   # (P,)
        self._block_key = (profile, link_mbps)
        self.last_decisions: List[Optional[JointDecision]] = []
        self._last_eff: Optional[np.ndarray] = None
        # Observability hooks (DESIGN.md §9), mirroring VectorizedPolicy:
        # `capture_scores` publishes {"score", "runner_up", "cut"} per
        # task on `last_scores`; `profiler` gets featurize/score spans.
        self.profiler = None
        self.capture_scores = False
        self.last_scores = None

    def _resolved_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "numpy"

    # -- joint decisions ---------------------------------------------------
    def decide(self, cluster, task: Task, weights: Weights,
               provider: Optional[CarbonIntensityProvider] = None,
               now_hour: float = 0.0) -> Optional[JointDecision]:
        return self.decide_batch(cluster, [task], weights, provider,
                                 now_hour)[0]

    def decide_batch(self, cluster, tasks: Sequence[Task], weights: Weights,
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0) -> List[Optional[JointDecision]]:
        """Per-task joint decisions; rows depend only on (cpu, mem_mb), so
        duplicate resource profiles share one scored (P, N) pass."""
        if not tasks:
            return []
        keys = [(t.cpu, t.mem_mb) for t in tasks]
        uniq: dict = {}
        reps: List[Task] = []
        for t, key in zip(tasks, keys):
            if key not in uniq:
                uniq[key] = len(reps)
                reps.append(t)
        chosen = self._decide_unique(cluster, reps, weights, provider,
                                     now_hour)
        if not self.capture_scores:
            return [chosen[uniq[key]] for key in keys]
        # expand the rep-level runner-up capture with the same index map
        # (C-speed fromiter over map + one object-array gather)
        idx = np.fromiter(map(uniq.__getitem__, keys), np.intp,
                          count=len(keys))
        run = getattr(self, "_cap_run_reps", None)
        self._cap_run_tasks = (
            np.asarray(run)[idx]
            if run is not None and len(run) == len(reps)
            else np.full(len(keys), np.nan))
        return np.asarray(chosen, dtype=object)[idx].tolist()

    def _decide_unique(self, cluster, reps, weights, provider, now_hour):
        cap = self.capture_scores
        if cap:
            self._cap_run: List[np.ndarray] = []
        cache = get_cache(cluster) if self.use_cache else None
        if cache is None:
            # Cluster-likes without FeatureCache plumbing: the oracle IS
            # the decision procedure (P x N scalar scan per unique task).
            out = [select_joint_scalar(cluster, t, self.profile, weights,
                                       provider, now_hour,
                                       self.latency_threshold_ms,
                                       self.link_mbps) for t in reps]
            if cap:
                # oracle keeps only the winner; runner-up unavailable
                self._cap_run_reps = np.full(len(out), np.nan)
            return out
        if not self.use_select_memo:
            out = self._decide_cached(cache, reps, weights, provider,
                                      now_hour)
            if cap:
                self._cap_run_reps = (np.concatenate(self._cap_run)
                                      if self._cap_run else np.zeros(0))
            return out
        memo = getattr(cache, "_sel_memo", None)
        if memo is None:
            memo = cache._sel_memo = _SelectionMemo()
        memo.sync_epoch(cache, provider, now_hour)
        # `cap` keys the table: capture-on entries are (decision,
        # runner_up) pairs, plain entries bare decisions
        cfg = ("partition", self._block_key, self._resolved_backend(),
               self.latency_threshold_ms, weights.as_array().tobytes(), cap)
        table = memo.map.setdefault(cfg, {})
        keys = [(t.cpu, t.mem_mb) for t in reps]
        missing = [i for i, k in enumerate(keys) if k not in table]
        if missing:
            chosen = self._decide_cached(cache, [reps[i] for i in missing],
                                         weights, provider, now_hour)
            if (len(table) + len(missing)
                    > VectorizedPolicy.MEMO_MAX_PROFILES):
                table.clear()
            if cap:
                mr = (np.concatenate(self._cap_run) if self._cap_run
                      else np.zeros(0))
                for j, (i, ch) in enumerate(zip(missing, chosen)):
                    table[keys[i]] = (ch, float(mr[j]))
            else:
                for i, ch in zip(missing, chosen):
                    table[keys[i]] = ch
        if not cap:
            return [table[k] for k in keys]
        entries = [table[k] for k in keys]
        self._cap_run_reps = np.array([e[1] for e in entries])
        return [e[0] for e in entries]

    def _decide_cached(self, cache, reps, weights, provider, now_hour):
        prof = self.profiler
        t0 = perf_counter() if prof is not None else 0.0
        t_pn, e_pn = cache.partition_block(self._block_key, self._rf,
                                           self._cs)           # (P, N)
        task_cpu = np.array([t.cpu for t in reps], dtype=float)
        task_mem = np.array([t.mem_mb for t in reps], dtype=float)
        feas = cache.feasible(task_cpu, task_mem,
                              self.latency_threshold_ms)       # (U, N)
        ints = cache.intensities(provider, now_hour,
                                 need=feas.any(axis=0))        # (N,)
        if prof is not None:
            prof.add("featurize", perf_counter() - t0)
            t0 = perf_counter()
        if self._resolved_backend() == "pallas":
            out = self._decide_pallas(cache, task_cpu, task_mem, feas,
                                      ints, t_pn, e_pn, weights)
        else:
            out = self._decide_numpy(cache, task_cpu, task_mem, feas, ints,
                                     t_pn, e_pn, weights)
        if prof is not None:
            prof.add("score", perf_counter() - t0)
        return out

    @staticmethod
    def _resource_fracs(cache, task_cpu, task_mem):
        """(U, N) cpu/mem free fractions, featurize's guarded division."""
        cpu_frac = np.ones((task_cpu.size, cache.n))
        np.divide(cache.free_cpu[None, :], task_cpu[:, None], out=cpu_frac,
                  where=(task_cpu > 0)[:, None])
        mem_frac = np.ones((task_mem.size, cache.n))
        np.divide(cache.free_mem[None, :], task_mem[:, None], out=mem_frac,
                  where=(task_mem > 0)[:, None])
        return cpu_frac, mem_frac

    def _decide_numpy(self, cache, task_cpu, task_mem, feas, ints, t_pn,
                      e_pn, weights):
        """Column path: one task-independent (P, N) base per step, then an
        (N,) S_R row + flattened argmax per unique task — the scalar
        oracle's accumulation order, so selections are bit-exact."""
        w = weights.as_array()
        base_pn = (w[1] * (1.0 - cache.load)[None, :]
                   + w[2] * (1.0 / (1.0 + t_pn))
                   + w[3] * (1.0 / (1.0 + cache.running * 2.0))[None, :]
                   + w[4] * (1.0 / (1.0 + ints[None, :] * e_pn)))  # (P, N)
        cpu_frac, mem_frac = self._resource_fracs(cache, task_cpu, task_mem)
        s_r = 0.5 * np.minimum(1.0, cpu_frac) + 0.5 * np.minimum(1.0, mem_frac)
        N = cache.n
        cap = self.capture_scores
        runs: List[float] = []
        out: List[Optional[JointDecision]] = []
        for u in range(task_cpu.size):
            totals = np.where(feas[u][None, :],
                              w[0] * s_r[u][None, :] + base_pn, -np.inf)
            flat = int(np.argmax(totals))
            p, n = divmod(flat, N)
            val = totals[p, n]
            if cap:
                # runner-up over the flattened (P, N) plane, winner masked
                t2 = totals.ravel().copy()
                t2[flat] = -np.inf
                runs.append(float(t2.max()) if t2.size > 1 else -np.inf)
            out.append(JointDecision(cache.names[n], self.profile.cuts[p],
                                     p, float(val), float(self._rf[p]),
                                     float(self._cs[p]))
                       if val > 0.0 else None)
        if cap:
            self._cap_run.append(np.asarray(runs))
        return out

    def _decide_pallas(self, cache, task_cpu, task_mem, feas, ints, t_pn,
                       e_pn, weights):
        """Fused path: build the widened (U, P, N, 8) feature tensor once,
        pad to power-of-two buckets, and reduce on-chip."""
        import jax.numpy as jnp

        from repro.kernels import ops

        U, N = feas.shape
        P = self._rf.size
        cpu_frac, mem_frac = self._resource_fracs(cache, task_cpu, task_mem)
        F = np.zeros((U, P, N, FEATURE_DIM), np.float32)
        F[:, :, :, COL_CPU_FREE] = cpu_frac[:, None, :]
        F[:, :, :, COL_MEM_FREE] = mem_frac[:, None, :]
        F[:, :, :, COL_LOAD] = cache.load[None, None, :]
        F[:, :, :, COL_TIME_S] = t_pn[None, :, :]
        F[:, :, :, COL_RUNNING] = cache.running[None, None, :]
        F[:, :, :, COL_IXE] = np.where(feas[:, None, :],
                                       (ints[None, :] * e_pn)[None, :, :],
                                       0.0)
        F[:, :, :, COL_VALID] = feas[:, None, :].astype(np.float32)
        bucket = VectorizedPolicy._bucket
        Up, Pp, Np = bucket(U), bucket(P), bucket(N)
        if (Up, Pp, Np) != (U, P, N):
            Fp = np.zeros((Up, Pp, Np, FEATURE_DIM), np.float32)
            Fp[:U, :P, :N] = F         # pad cells: valid=0 -> masked out
            F = Fp
        w8 = np.zeros(FEATURE_DIM, np.float32)
        w8[:5] = weights.as_array()
        pidx, nidx, val = ops.select_best_node_joint(jnp.asarray(F),
                                                     jnp.asarray(w8))
        pidx = np.asarray(pidx)[:U]
        nidx = np.asarray(nidx)[:U]
        val = np.asarray(val, np.float64)[:U]
        if self.capture_scores:
            # fused winner-only fold: runner-up not materialized
            self._cap_run.append(np.full(U, np.nan))
        return [JointDecision(cache.names[n], self.profile.cuts[p], int(p),
                              float(v), float(self._rf[p]),
                              float(self._cs[p]))
                if v > 0.0 else None
                for p, n, v in zip(pidx, nidx, val)]

    # -- SchedulingPolicy interface ----------------------------------------
    def select_batch(self, cluster, tasks: Sequence[Task], weights: Weights,
                     provider: Optional[CarbonIntensityProvider] = None,
                     now_hour: float = 0.0) -> List[Optional[str]]:
        decisions = self.decide_batch(cluster, tasks, weights, provider,
                                      now_hour)
        self.last_decisions = decisions
        eff = np.array([d.effective_latency_ms(t.base_latency_ms)
                        if d is not None else t.base_latency_ms
                        for t, d in zip(tasks, decisions)])
        self._last_eff = eff
        if self.capture_scores:
            self.last_scores = {
                "score": np.array([d.score if d is not None else np.nan
                                   for d in decisions]),
                "runner_up": self._cap_run_tasks,
                "cut": np.array([d.cut_index if d is not None else -1
                                 for d in decisions], dtype=np.int32),
            }
        return [d.node if d is not None else None for d in decisions]

    def select(self, cluster, task: Task, weights: Weights, provider=None,
               now_hour: float = 0.0) -> Optional[str]:
        return self.select_batch(cluster, [task], weights, provider,
                                 now_hour)[0]

    def execution_latency_ms(self, tasks: Sequence[Task]
                             ) -> Optional[np.ndarray]:
        """Engine hook: per-task effective base latency for the batch the
        last ``select_batch`` decided — the offloaded segment's compute
        share plus the uplink transfer. Returns None if the batch doesn't
        line up (a wrapper re-grouped tasks), in which case the engine
        bills the full base latency."""
        if self._last_eff is None or len(self._last_eff) != len(tasks):
            return None
        return self._last_eff

    def set_link_mbps(self, link_mbps: float) -> None:
        """Retune the uplink bandwidth mid-run (a link flap, DESIGN.md
        §10): recomputes the per-cut comm column and rotates the
        FeatureCache block key so the next score sees the new link —
        restoring the original value restores bit-identical columns."""
        self.link_mbps = float(link_mbps)
        self._cs = self.profile.comm_seconds(self.link_mbps)
        self._block_key = (self.profile, self.link_mbps)

    def fallback_latency_ms(self, task: Task) -> float:
        """Engine failover hook (DESIGN.md §10): when a task's offload
        target died after selection, the split is stranded — re-bill the
        whole model on the replacement node through the cut-0
        (full-offload) column: base latency scaled by remote_frac[0]
        (= 1.0) plus the full-payload transfer."""
        return float(task.base_latency_ms * self._rf[0]
                     + self._cs[0] * 1000.0)
