"""Split-conformal uncertainty for the scheduler's two noisy signals
(DESIGN.md §8): forecast grid-carbon intensity and the latency model.

CarbonCP's observation (PAPERS.md) is that carbon-aware partition and
deferral decisions made on *point* forecasts silently gamble: a deferral
into a mispredicted "green" window loses carbon. Split-conformal
prediction fixes the decision rule, not the forecast — calibrate the
absolute residuals of a held-out window, and the quantile

    q = the ceil((n + 1) * coverage)-th smallest |residual|

gives a symmetric band ``pred ± q`` with finite-sample marginal coverage
>= ``coverage`` under exchangeability (the standard split-conformal
guarantee, no distributional assumptions). Risk-bounded callers
(``core.temporal.plan_wake_risk_batch``, the tenancy deferral gate) then
defer/reject only when the *pessimistic* end of the band still beats
executing now.

The provider-facing plumbing lives in ``core.api`` (the
``intensity_interval_batch`` dispatch helper plus native zero-width
intervals on the measured providers); this module owns the calibrators.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.api import (CarbonIntensityProvider, intensity_batch,
                            intensity_interval_batch)

__all__ = [
    "SplitConformal", "ConformalProvider", "calibrate_intensity",
    "calibrate_latency", "intensity_interval_batch",
]


class SplitConformal:
    """Split-conformal calibrator over absolute residuals.

    ``residuals`` is any array of held-out ``actual - predicted`` values
    (signs are discarded). ``quantile(coverage)`` returns the
    finite-sample-corrected order statistic — ``inf`` when the calibration
    set is too small to certify the requested coverage (n + 1 <= n *
    coverage), which callers should read as "no risk bound available".
    """

    def __init__(self, residuals):
        r = np.sort(np.abs(np.asarray(residuals, dtype=float).ravel()))
        if r.size == 0:
            raise ValueError("SplitConformal needs at least one residual")
        self._r = r

    @property
    def n(self) -> int:
        return int(self._r.size)

    def quantile(self, coverage: float = 0.9) -> float:
        if not 0.0 < coverage < 1.0:
            raise ValueError(f"coverage must be in (0, 1), got {coverage}")
        k = int(np.ceil((self._r.size + 1) * coverage))
        if k > self._r.size:
            return float("inf")
        return float(self._r[k - 1])

    def interval(self, pred, coverage: float = 0.9):
        """``pred ± quantile(coverage)`` elementwise (scalars or arrays)."""
        q = self.quantile(coverage)
        p = np.asarray(pred, dtype=float)
        return p - q, p + q


class ConformalProvider:
    """Wrap any intensity provider with a :class:`SplitConformal` band.

    Point reads pass through untouched (the engine's billing path is
    unchanged); ``intensity_interval_batch`` answers ``pred ± q`` with the
    lower band clipped at zero. Use this to retrofit intervals onto a
    provider that has none, or to override a bundled provider's native
    (zero-width) answer with an empirically calibrated one.
    """

    def __init__(self, base: CarbonIntensityProvider,
                 conformal: SplitConformal):
        self.base = base
        self.conformal = conformal

    @property
    def TIME_INVARIANT(self) -> bool:          # noqa: N802 (provider protocol)
        return bool(getattr(self.base, "TIME_INVARIANT", False))

    def intensity(self, node: str, hour: float = 0.0) -> float:
        return self.base.intensity(node, hour)

    def intensity_batch(self, names: Sequence[str], hours) -> np.ndarray:
        return np.asarray(intensity_batch(self.base, names, hours))

    def covers(self, node: str) -> bool:
        cov = getattr(self.base, "covers", None)
        return bool(cov(node)) if cov is not None else True

    def intensity_interval_batch(self, names: Sequence[str], hours,
                                 coverage: float = 0.9):
        pred = np.asarray(self.intensity_batch(names, hours), dtype=float)
        q = self.conformal.quantile(coverage)
        return np.maximum(pred - q, 0.0), pred + q


def calibrate_intensity(forecast: CarbonIntensityProvider,
                        actual: CarbonIntensityProvider,
                        names: Sequence[str], hours) -> SplitConformal:
    """Calibrate forecast-vs-actual intensity residuals over a held-out
    (names x hours) calibration window — one batched read per provider.
    Attach the result to a ``ForecastProvider(conformal=...)`` or wrap the
    forecast in a :class:`ConformalProvider`."""
    pred = np.asarray(intensity_batch(forecast, names, hours), dtype=float)
    true = np.asarray(intensity_batch(actual, names, hours), dtype=float)
    return SplitConformal(true - pred)


def calibrate_latency(predicted_ms, measured_ms) -> SplitConformal:
    """Calibrate the latency model's residuals (predicted vs measured
    service time, e.g. ``cluster.latency_energy`` estimates against
    ``TaskResult.latency_ms``). The returned calibrator's ``interval``
    bounds future latency predictions for risk-bounded admission."""
    p = np.asarray(predicted_ms, dtype=float).ravel()
    m = np.asarray(measured_ms, dtype=float).ravel()
    if p.size != m.size:
        raise ValueError(
            f"predicted/measured length mismatch: {p.size} vs {m.size}")
    return SplitConformal(m - p)
