"""Joint partition+placement scheduling with conformal uncertainty
(DESIGN.md §8): per-model cut profiles, the (B, P, N) PartitionPolicy,
and split-conformal calibrators for risk-bounded decisions."""
from repro.partition.policy import (DEFAULT_LINK_MBPS, JointDecision,
                                    PartitionPolicy, joint_time_energy,
                                    select_joint_scalar)
from repro.partition.profile import (CutProfile, profile_cnn, profile_costs,
                                     profile_transformer)
from repro.partition.uncertainty import (ConformalProvider, SplitConformal,
                                         calibrate_intensity,
                                         calibrate_latency,
                                         intensity_interval_batch)
