"""Compatibility shims for the installed jax version.

``jax.shard_map`` was promoted to the top-level namespace only in newer
jax releases; older versions (including the one baked into this container)
ship it as ``jax.experimental.shard_map`` with a ``check_rep`` keyword where
newer releases spell it ``check_vma``. Model, launch, and test code must
import ``shard_map`` from here rather than from jax directly so the repo
collects and runs on both generations.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6: top-level export, check_vma keyword
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # older jax: experimental namespace, check_rep keyword
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg translated to
    whatever the installed jax spells it (``check_vma`` <-> ``check_rep``)."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across the rename from ``TPUCompilerParams``
    (older jax) to ``CompilerParams`` (newer jax)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
