"""repro.obs — always-available observability (DESIGN.md §9, §12).

Six pillars, each independently switchable and ``None`` when off:

- :class:`~repro.obs.trace.DecisionTrace` — column-oriented ring buffer
  of per-task scheduling decisions (node/cut/mode, winning vs runner-up
  score, intensity + conformal interval, admission verdict, carbon) with
  a deterministic JSONL exporter.
- :class:`~repro.obs.registry.MetricsRegistry` — numpy-column counters /
  gauges / histograms with Prometheus-style text exposition.
- :class:`~repro.obs.profiler.StepProfiler` — ``perf_counter`` spans
  around the engine/sim phases, folded into per-phase histograms.
- :class:`~repro.obs.journey.JourneyTrace` — per-request causal record
  keyed by sim task uid (arrival → verdicts → defer/wake → retry/failover
  → execute-or-dead-letter) with ``explain_journey`` forensics and a
  vectorized critical-path decomposition.
- :class:`~repro.obs.rollup.RollupStore` — fixed-width sim-time windows
  folding carbon/energy/SLO/verdict/tenant/availability columns into
  bounded-memory series (O(windows), not O(tasks)).
- :class:`~repro.obs.alerts.AlertEngine` — declarative threshold /
  burn-rate rules evaluated vectorized per rollup window, emitting a
  deterministic fire/resolve event log.

``Observability`` bundles them for threading through
``CarbonEdgeEngine(obs=...)`` and ``AsyncEngineDriver(obs=...)``. The
disabled default costs one ``is not None`` check per instrumented site and
leaves every existing output byte-identical (the sim ``to_text`` contract,
enforced by ``gate_obs``); this package imports only stdlib + numpy so the
core/tenancy/partition layers can depend on it without cycles.
"""
from __future__ import annotations

import logging
import sys
from typing import Dict, Optional, Sequence, Union

from repro.obs.alerts import (ALERT_KINDS, AlertEngine, AlertEvent,
                              AlertRule, default_rules)
from repro.obs.journey import (J_DEAD, J_DONE, J_OPEN, J_REJECT,
                               PARK_DEFER, PARK_RETRY, STATE_LABELS,
                               JourneyTrace)
from repro.obs.profiler import SPAN_EDGES_S, StepProfiler
from repro.obs.registry import DEFAULT_EDGES, Family, MetricsRegistry
from repro.obs.rollup import VERDICT_COLS, RollupStore
from repro.obs.trace import (MODE_LABELS, VERDICT_DEAD, VERDICT_DEFER,
                             VERDICT_DONE, VERDICT_LABELS, VERDICT_REJECT,
                             VERDICT_RETRY, DecisionTrace)

__all__ = [
    "ALERT_KINDS", "AlertEngine", "AlertEvent", "AlertRule",
    "DEFAULT_EDGES", "DecisionTrace", "Family", "J_DEAD", "J_DONE",
    "J_OPEN", "J_REJECT", "JourneyTrace", "MetricsRegistry",
    "MODE_LABELS", "Observability", "PARK_DEFER", "PARK_RETRY",
    "RollupStore", "SPAN_EDGES_S", "STATE_LABELS", "StepProfiler",
    "VERDICT_COLS", "VERDICT_DEAD", "VERDICT_DEFER", "VERDICT_DONE",
    "VERDICT_LABELS", "VERDICT_REJECT", "VERDICT_RETRY", "console_logger",
    "default_rules",
]


class Observability:
    """Hub carrying the enabled pillars; a pillar is ``None`` when off.

    Each argument accepts ``False`` (off), ``True`` (fresh default
    instance), or an existing instance to share between components."""

    def __init__(self, *,
                 trace: Union[bool, DecisionTrace] = False,
                 metrics: Union[bool, MetricsRegistry] = False,
                 profile: Union[bool, StepProfiler] = False,
                 journeys: Union[bool, JourneyTrace] = False,
                 rollups: Union[bool, RollupStore] = False,
                 alerts: Union[bool, AlertEngine] = False,
                 trace_capacity: int = 1 << 16,
                 rollup_window_hours: float = 0.25,
                 alert_rules: Optional[Sequence[AlertRule]] = None) -> None:
        self.trace = (trace if isinstance(trace, DecisionTrace)
                      else DecisionTrace(trace_capacity) if trace else None)
        self.metrics = (metrics if isinstance(metrics, MetricsRegistry)
                        else MetricsRegistry() if metrics else None)
        self.profiler = (profile if isinstance(profile, StepProfiler)
                         else StepProfiler() if profile else None)
        self.journeys = (journeys if isinstance(journeys, JourneyTrace)
                         else JourneyTrace() if journeys else None)
        self.rollups = (rollups if isinstance(rollups, RollupStore)
                        else RollupStore(rollup_window_hours)
                        if rollups else None)
        # Alerts need rollups to evaluate against; an AlertEngine without
        # a RollupStore is inert but harmless (evaluate is never called).
        self.alerts = (alerts if isinstance(alerts, AlertEngine)
                       else AlertEngine(alert_rules) if alerts else None)

    @classmethod
    def all(cls, trace_capacity: int = 1 << 16,
            rollup_window_hours: float = 0.25,
            alert_rules: Optional[Sequence[AlertRule]] = None
            ) -> "Observability":
        """Every pillar on — the ``gate_obs`` enabled configuration."""
        return cls(trace=True, metrics=True, profile=True,
                   journeys=True, rollups=True, alerts=True,
                   trace_capacity=trace_capacity,
                   rollup_window_hours=rollup_window_hours,
                   alert_rules=alert_rules)

    @property
    def enabled(self) -> bool:
        return (self.trace is not None or self.metrics is not None
                or self.profiler is not None or self.journeys is not None
                or self.rollups is not None or self.alerts is not None)

    def report(self) -> Dict:
        """JSON-ready summary of whatever pillars are on."""
        out: Dict = {}
        if self.trace is not None:
            out["trace"] = self.trace.stats()
        if self.profiler is not None:
            out["profiler"] = self.profiler.summary()
        if self.journeys is not None:
            out["journeys"] = self.journeys.stats()
        if self.rollups is not None:
            out["rollups"] = self.rollups.stats()
        if self.alerts is not None:
            out["alerts"] = self.alerts.stats()
        if self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        return out


def console_logger(name: str, level: int = logging.INFO) -> logging.Logger:
    """Module-level logger with a plain-``%(message)s`` stdout handler on
    the shared ``repro`` root, so launch scripts keep their exact printed
    output under ``logging`` (SNIPPETS.md §1). Idempotent: the handler is
    attached once no matter how many modules call this."""
    logger = logging.getLogger(name)
    # attach to the shared "repro" ancestor when possible so one handler
    # serves the whole package; "__main__"-style names get their own
    root = (logging.getLogger("repro")
            if name == "repro" or name.startswith("repro.") else logger)
    if not any(getattr(h, "_repro_console", False) for h in root.handlers):
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter("%(message)s"))
        handler._repro_console = True
        root.addHandler(handler)
        root.setLevel(level)
    logger.setLevel(level)
    return logger
