"""repro.obs — always-available observability (DESIGN.md §9).

Three pillars, each independently switchable and ``None`` when off:

- :class:`~repro.obs.trace.DecisionTrace` — column-oriented ring buffer
  of per-task scheduling decisions (node/cut/mode, winning vs runner-up
  score, intensity + conformal interval, admission verdict, carbon) with
  a deterministic JSONL exporter.
- :class:`~repro.obs.registry.MetricsRegistry` — numpy-column counters /
  gauges / histograms with Prometheus-style text exposition.
- :class:`~repro.obs.profiler.StepProfiler` — ``perf_counter`` spans
  around the engine/sim phases, folded into per-phase histograms.

``Observability`` bundles them for threading through
``CarbonEdgeEngine(obs=...)`` and ``AsyncEngineDriver(obs=...)``. The
disabled default costs one ``is not None`` check per instrumented site and
leaves every existing output byte-identical (the sim ``to_text`` contract,
enforced by ``gate_obs``); this package imports only stdlib + numpy so the
core/tenancy/partition layers can depend on it without cycles.
"""
from __future__ import annotations

import logging
import sys
from typing import Dict, Union

from repro.obs.profiler import SPAN_EDGES_S, StepProfiler
from repro.obs.registry import DEFAULT_EDGES, Family, MetricsRegistry
from repro.obs.trace import (MODE_LABELS, VERDICT_DEAD, VERDICT_DEFER,
                             VERDICT_DONE, VERDICT_LABELS, VERDICT_REJECT,
                             VERDICT_RETRY, DecisionTrace)

__all__ = [
    "DEFAULT_EDGES", "DecisionTrace", "Family", "MetricsRegistry",
    "MODE_LABELS", "Observability", "SPAN_EDGES_S", "StepProfiler",
    "VERDICT_DEAD", "VERDICT_DEFER", "VERDICT_DONE", "VERDICT_LABELS",
    "VERDICT_REJECT", "VERDICT_RETRY", "console_logger",
]


class Observability:
    """Hub carrying the enabled pillars; a pillar is ``None`` when off.

    Each argument accepts ``False`` (off), ``True`` (fresh default
    instance), or an existing instance to share between components."""

    def __init__(self, *,
                 trace: Union[bool, DecisionTrace] = False,
                 metrics: Union[bool, MetricsRegistry] = False,
                 profile: Union[bool, StepProfiler] = False,
                 trace_capacity: int = 1 << 16) -> None:
        self.trace = (trace if isinstance(trace, DecisionTrace)
                      else DecisionTrace(trace_capacity) if trace else None)
        self.metrics = (metrics if isinstance(metrics, MetricsRegistry)
                        else MetricsRegistry() if metrics else None)
        self.profiler = (profile if isinstance(profile, StepProfiler)
                         else StepProfiler() if profile else None)

    @classmethod
    def all(cls, trace_capacity: int = 1 << 16) -> "Observability":
        """Every pillar on — the ``gate_obs`` enabled configuration."""
        return cls(trace=True, metrics=True, profile=True,
                   trace_capacity=trace_capacity)

    @property
    def enabled(self) -> bool:
        return (self.trace is not None or self.metrics is not None
                or self.profiler is not None)

    def report(self) -> Dict:
        """JSON-ready summary of whatever pillars are on."""
        out: Dict = {}
        if self.trace is not None:
            out["trace"] = self.trace.stats()
        if self.profiler is not None:
            out["profiler"] = self.profiler.summary()
        if self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        return out


def console_logger(name: str, level: int = logging.INFO) -> logging.Logger:
    """Module-level logger with a plain-``%(message)s`` stdout handler on
    the shared ``repro`` root, so launch scripts keep their exact printed
    output under ``logging`` (SNIPPETS.md §1). Idempotent: the handler is
    attached once no matter how many modules call this."""
    logger = logging.getLogger(name)
    # attach to the shared "repro" ancestor when possible so one handler
    # serves the whole package; "__main__"-style names get their own
    root = (logging.getLogger("repro")
            if name == "repro" or name.startswith("repro.") else logger)
    if not any(getattr(h, "_repro_console", False) for h in root.handlers):
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter("%(message)s"))
        handler._repro_console = True
        root.addHandler(handler)
        root.setLevel(level)
    logger.setLevel(level)
    return logger
