"""Per-request journey trace (DESIGN.md §12).

``JourneyTrace`` is the causal record of one request's life across the
sim: arrival -> (forecast plan-defer) -> enqueue -> admission verdict(s)
-> budget-defer / retry-backoff parks -> failover hops -> execute,
reject, or dead-letter. ``DecisionTrace`` (§9) answers "what did the
scheduler decide *this step*"; the journey answers "why was THIS request
slow/dirty/dead" across every step and event it touched.

Storage is columnar and keyed by the driver's dense task uid: parallel
numpy arrays indexed ``[uid]``, grown by doubling, populated by batched
scatters from the sim driver's existing enqueue/drain/outcome paths — a
step's whole drained batch lands as a handful of fancy-index writes, no
per-task Python on the hot path. Each uid's wall phases are accumulated
so that for a completed journey

    plan_defer + queue_wait + budget_defer + retry_backoff + service
        == finish - submit            (hours, up to float associativity)

— the vectorized critical-path identity :meth:`critical_path` verifies
over the whole run and :meth:`explain_journey` renders per uid.

Recording never touches an RNG or the sim's ``MetricsCollector``, so a
wired journey trace leaves ``metrics.to_text`` byte-identical (the §9
zero-overhead-when-disabled contract extends to this pillar: the driver
holds ``None`` when off and guards every hook with one ``is not None``).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

# Terminal-state encoding for the ``state`` column.
J_OPEN, J_DONE, J_REJECT, J_DEAD = 0, 1, 2, 3
STATE_LABELS = ("open", "done", "reject", "dead")

# Park-kind encoding for the ``park_kind`` column (-1 = not parked).
PARK_DEFER, PARK_RETRY = 0, 1

_GROW_MIN = 1024


class JourneyTrace:
    """Growable uid-indexed columns tracing each request's causal path."""

    def __init__(self, capacity: int = _GROW_MIN) -> None:
        cap = max(int(capacity), 1)
        self._name_ids: Dict[str, Dict[str, int]] = {"node": {},
                                                     "tenant": {}}
        self._names: Dict[str, List[str]] = {"node": [], "tenant": []}
        self.max_uid = 0                  # highest uid ever recorded
        self._alloc(cap)

    def _alloc(self, cap: int) -> None:
        self.submit = np.full(cap, np.nan)
        self.enqueue_hour = np.full(cap, np.nan)   # first queue entry
        self.last_enqueue = np.full(cap, np.nan)
        self.plan_defer_h = np.zeros(cap)          # forecast-planned wait
        self.budget_defer_h = np.zeros(cap)        # tenancy park time
        self.retry_backoff_h = np.zeros(cap)       # resilience park time
        self.queue_wait_h = np.zeros(cap)          # summed enqueue->drain
        self.start = np.full(cap, np.nan)          # final exec batch hour
        self.finish = np.full(cap, np.nan)
        self.state = np.zeros(cap, dtype=np.int8)  # J_OPEN
        self.drains = np.zeros(cap, dtype=np.int32)   # verdicts seen
        self.defers = np.zeros(cap, dtype=np.int32)
        self.retries = np.zeros(cap, dtype=np.int32)
        self.failovers = np.zeros(cap, dtype=np.int32)
        self.park_kind = np.full(cap, -1, dtype=np.int8)
        self.parked_at = np.full(cap, np.nan)
        self.tenant = np.full(cap, -1, dtype=np.int32)
        self.node = np.full(cap, -1, dtype=np.int32)

    @property
    def capacity(self) -> int:
        return self.submit.size

    def _grow_to(self, uid_max: int) -> None:
        need = uid_max + 1
        have = self.capacity
        if need <= have:
            return
        new = max(need, 2 * have, _GROW_MIN)
        old = {k: getattr(self, k) for k in (
            "submit", "enqueue_hour", "last_enqueue", "plan_defer_h",
            "budget_defer_h", "retry_backoff_h", "queue_wait_h", "start",
            "finish", "state", "drains", "defers", "retries", "failovers",
            "park_kind", "parked_at", "tenant", "node")}
        self._alloc(new)
        for k, arr in old.items():
            getattr(self, k)[:arr.size] = arr

    # ------------------------------------------------------------------
    # interning (same shape as DecisionTrace's — own namespaces)
    # ------------------------------------------------------------------
    def intern_names(self, names, kind: str = "node") -> np.ndarray:
        arr = np.asarray(names, dtype=object)
        if arr.size == 0:
            return np.zeros(0, dtype=np.int32)
        table = self._name_ids[kind]
        out_names = self._names[kind]
        uniq, inv = np.unique(arr, return_inverse=True)
        ids = np.empty(uniq.size, dtype=np.int32)
        for k, name in enumerate(uniq):
            i = table.get(name)
            if i is None:
                i = table[name] = len(out_names)
                out_names.append(str(name))
            ids[k] = i
        return ids[inv]

    def names(self, kind: str = "node") -> List[str]:
        return list(self._names[kind])

    def intern_tenants(self, names) -> np.ndarray:
        """Tenant ids for a batch's tenant names, with ``""``
        (untenanted) mapped to -1 instead of interned."""
        arr = np.asarray(names, dtype=object)
        out = np.full(arr.size, -1, dtype=np.int32)
        nz = np.asarray([bool(x) for x in arr], dtype=bool)
        if nz.any():
            out[nz] = self.intern_names(arr[nz], "tenant")
        return out

    # ------------------------------------------------------------------
    # recording (batched scatters; uids within one call are distinct)
    # ------------------------------------------------------------------
    def begin(self, uids, hours) -> None:
        """Arrival: the requests exist as of ``hours``."""
        u = np.asarray(uids, dtype=np.int64)
        if u.size == 0:
            return
        self._grow_to(int(u.max()))
        self.max_uid = max(self.max_uid, int(u.max()))
        self.submit[u] = hours

    def plan_defer(self, uid: int, delta_hours: float) -> None:
        """Forecast planning parked the request ``delta_hours`` before its
        first enqueue (the scalar planning path records one at a time)."""
        self._grow_to(uid)
        self.max_uid = max(self.max_uid, int(uid))
        self.plan_defer_h[uid] += delta_hours

    def enqueue(self, uids, hours) -> None:
        """The requests entered the executor queue at ``hours``."""
        u = np.asarray(uids, dtype=np.int64)
        if u.size == 0:
            return
        self._grow_to(int(u.max()))
        self.max_uid = max(self.max_uid, int(u.max()))
        first = np.isnan(self.enqueue_hour[u])
        if first.any():
            self.enqueue_hour[u[first]] = np.asarray(hours)[first] \
                if np.ndim(hours) else hours
        self.last_enqueue[u] = hours

    def _drained(self, u: np.ndarray, hour: float) -> None:
        self.queue_wait_h[u] += hour - self.last_enqueue[u]
        self.drains[u] += 1

    def park(self, uids, hour: float, kind: int) -> None:
        """A drain verdict parked the requests (budget defer or retry
        backoff); time parked accumulates at :meth:`wake`."""
        u = np.asarray(uids, dtype=np.int64)
        if u.size == 0:
            return
        self._drained(u, hour)
        self.park_kind[u] = kind
        self.parked_at[u] = hour
        if kind == PARK_DEFER:
            self.defers[u] += 1
        else:
            self.retries[u] += 1

    def wake(self, uids, hour: float) -> None:
        """Parked requests woke; the park interval folds into the phase
        the stored park kind names."""
        u = np.asarray(uids, dtype=np.int64)
        if u.size == 0:
            return
        dt = hour - self.parked_at[u]
        was_defer = self.park_kind[u] == PARK_DEFER
        if was_defer.any():
            d = u[was_defer]
            self.budget_defer_h[d] += dt[was_defer]
        if (~was_defer).any():
            r = u[~was_defer]
            self.retry_backoff_h[r] += dt[~was_defer]
        self.park_kind[u] = -1
        self.parked_at[u] = np.nan

    def failover(self, uids) -> None:
        u = np.asarray(uids, dtype=np.int64)
        if u.size:
            self.failovers[u] += 1

    def done(self, uids, exec_hour: float, finishes,
             node_ids=None, tenant_ids=None) -> None:
        """The requests executed in the batch that started at
        ``exec_hour`` and finished serially at ``finishes``."""
        u = np.asarray(uids, dtype=np.int64)
        if u.size == 0:
            return
        self._drained(u, exec_hour)
        self.state[u] = J_DONE
        self.start[u] = exec_hour
        self.finish[u] = finishes
        if node_ids is not None:
            self.node[u] = node_ids
        if tenant_ids is not None:
            self.tenant[u] = tenant_ids

    def _terminal(self, uids, hour: float, state: int,
                  tenant_ids=None) -> None:
        u = np.asarray(uids, dtype=np.int64)
        if u.size == 0:
            return
        self._drained(u, hour)
        self.state[u] = state
        self.finish[u] = hour
        if tenant_ids is not None:
            self.tenant[u] = tenant_ids

    def reject(self, uids, hour: float, tenant_ids=None) -> None:
        self._terminal(uids, hour, J_REJECT, tenant_ids)

    def dead(self, uids, hour: float, tenant_ids=None) -> None:
        self._terminal(uids, hour, J_DEAD, tenant_ids)

    # ------------------------------------------------------------------
    # reading
    # ------------------------------------------------------------------
    def _u(self) -> np.ndarray:
        """All recorded uids (1..max_uid; uid 0 is never assigned)."""
        return np.arange(1, self.max_uid + 1)

    @property
    def nbytes(self) -> int:
        return sum(getattr(self, k).nbytes for k in (
            "submit", "enqueue_hour", "last_enqueue", "plan_defer_h",
            "budget_defer_h", "retry_backoff_h", "queue_wait_h", "start",
            "finish", "state", "drains", "defers", "retries", "failovers",
            "park_kind", "parked_at", "tenant", "node"))

    def journey(self, uid: int) -> Optional[Dict]:
        """One uid's journey as a dict (None when never recorded)."""
        if not 1 <= uid <= self.max_uid or np.isnan(self.submit[uid]):
            return None
        nd, tn = int(self.node[uid]), int(self.tenant[uid])
        service = float(self.finish[uid] - self.start[uid]) \
            if np.isfinite(self.start[uid]) else 0.0
        e2e = float(self.finish[uid] - self.submit[uid]) \
            if np.isfinite(self.finish[uid]) else None
        return {
            "uid": int(uid),
            "state": STATE_LABELS[int(self.state[uid])],
            "submit_hour": float(self.submit[uid]),
            "finish_hour": (float(self.finish[uid])
                            if np.isfinite(self.finish[uid]) else None),
            "node": self._names["node"][nd] if nd >= 0 else None,
            "tenant": self._names["tenant"][tn] if tn >= 0 else None,
            "drains": int(self.drains[uid]),
            "defers": int(self.defers[uid]),
            "retries": int(self.retries[uid]),
            "failovers": int(self.failovers[uid]),
            "plan_defer_h": float(self.plan_defer_h[uid]),
            "budget_defer_h": float(self.budget_defer_h[uid]),
            "retry_backoff_h": float(self.retry_backoff_h[uid]),
            "queue_wait_h": float(self.queue_wait_h[uid]),
            "service_h": service,
            "e2e_h": e2e,
        }

    def explain_journey(self, uid: int) -> Optional[str]:
        """Multi-line forensics: the request's full causal path with its
        critical-path decomposition in seconds."""
        j = self.journey(uid)
        if j is None:
            return None
        head = f"journey uid={uid} [{j['state']}]"
        if j["tenant"]:
            head += f" tenant={j['tenant']!r}"
        lines = [head,
                 f"  submitted at {j['submit_hour']:.6g} h; "
                 f"drained {j['drains']}x"]
        hops = []
        if j["defers"]:
            hops.append(f"budget-deferred {j['defers']}x")
        if j["retries"]:
            hops.append(f"retried {j['retries']}x")
        if j["failovers"]:
            hops.append(f"failed over {j['failovers']}x")
        if hops:
            lines.append("  " + ", ".join(hops))
        if j["state"] == "done":
            lines.append(f"  executed on {j['node']!r}, finished at "
                         f"{j['finish_hour']:.6g} h")
        elif j["finish_hour"] is not None:
            lines.append(f"  terminal at {j['finish_hour']:.6g} h")
        if j["e2e_h"] is not None:
            s = 3600.0
            lines.append(
                f"  e2e {j['e2e_h'] * s:.4g} s = "
                f"plan-defer {j['plan_defer_h'] * s:.4g} + "
                f"queue {j['queue_wait_h'] * s:.4g} + "
                f"budget-defer {j['budget_defer_h'] * s:.4g} + "
                f"backoff {j['retry_backoff_h'] * s:.4g} + "
                f"service {j['service_h'] * s:.4g}")
        return "\n".join(lines)

    def critical_path(self) -> Dict:
        """Vectorized critical-path decomposition over every *completed*
        journey: total and mean hours per phase, each phase's share of
        end-to-end latency, and the max absolute residual of the
        phase-sum identity (should be float-roundoff-sized)."""
        u = self._u()
        m = self.state[u] == J_DONE
        u = u[m]
        n = int(u.size)
        if n == 0:
            return {"journeys": 0}
        service = self.finish[u] - self.start[u]
        e2e = self.finish[u] - self.submit[u]
        phases = {
            "plan_defer": self.plan_defer_h[u],
            "queue_wait": self.queue_wait_h[u],
            "budget_defer": self.budget_defer_h[u],
            "retry_backoff": self.retry_backoff_h[u],
            "service": service,
        }
        e2e_total = float(np.add.accumulate(e2e)[-1])
        out: Dict = {"journeys": n, "e2e_h_total": e2e_total}
        acc = np.zeros(n)
        for name, col in phases.items():
            tot = float(np.add.accumulate(col)[-1])
            out[f"{name}_h_total"] = tot
            out[f"{name}_h_mean"] = tot / n
            out[f"{name}_share"] = tot / e2e_total if e2e_total else 0.0
            acc = acc + col
        out["identity_max_abs_err_h"] = float(np.abs(acc - e2e).max())
        return out

    def state_counts(self) -> Dict[str, int]:
        u = self._u()
        counts = np.bincount(self.state[u], minlength=len(STATE_LABELS))
        return {lbl: int(counts[i]) for i, lbl in enumerate(STATE_LABELS)}

    def stats(self) -> Dict:
        return {"journeys": self.max_uid,
                "states": self.state_counts(),
                "nbytes": self.nbytes,
                "nodes": len(self._names["node"]),
                "tenants": len(self._names["tenant"])}

    def to_text(self) -> str:
        """Deterministic per-journey rendering (``%.9g`` floats) — the
        byte-comparison surface for the journey-determinism gate."""
        u = self._u()
        lines = []
        for i in u.tolist():
            nd, tn = int(self.node[i]), int(self.tenant[i])
            lines.append(
                f"uid={i} state={STATE_LABELS[int(self.state[i])]} "
                f"submit={self.submit[i]:.9g} "
                f"finish={self.finish[i]:.9g} "
                f"plan={self.plan_defer_h[i]:.9g} "
                f"queue={self.queue_wait_h[i]:.9g} "
                f"budget={self.budget_defer_h[i]:.9g} "
                f"backoff={self.retry_backoff_h[i]:.9g} "
                f"drains={self.drains[i]} defers={self.defers[i]} "
                f"retries={self.retries[i]} "
                f"failovers={self.failovers[i]} "
                f"node={self._names['node'][nd] if nd >= 0 else '-'} "
                f"tenant={self._names['tenant'][tn] if tn >= 0 else '-'}")
        return "\n".join(lines) + ("\n" if lines else "")
