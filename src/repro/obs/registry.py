"""Vectorized metrics registry (DESIGN.md §9).

Counters, gauges, and histograms stored as numpy columns: each family
interns its label tuples to row indices once, and hot-path updates are
array scatters (``inc_at`` folds grouped increments through
``energy.ledger_scatter_add``, the unbuffered ``np.add.at`` counterpart of
the billing ledger fold — deterministic, loop-equivalent accumulation).
``to_text`` renders a Prometheus-style text exposition (``# HELP`` /
``# TYPE`` / cumulative ``_bucket`` rows) with the same ``%.9g`` float
rendering the sim's byte-identity contract uses.

Quantile-granularity contract
-----------------------------
Histograms store *bucket counts only*, never raw samples, so
:meth:`Family.quantile` (and any downstream p50/p99) resolves to the
**upper edge of the bucket containing the target rank** — exactly how a
Prometheus ``histogram_quantile`` behaves. With the default log-spaced
decade edges (:data:`DEFAULT_EDGES`) a reported p50 of ``0.0001`` means
"the median sample fell in ``(1e-5, 1e-4]``", not that the median is
exactly 100 µs; adjacent quantiles are indistinguishable within one
bucket. Callers who need tighter resolution pass their own ``edges`` at
``histogram(...)`` registration (e.g. half-decade ``10**arange(lo, hi,
0.5)`` like the profiler, or linear edges around a known operating
point) — resolution is a *registration-time* choice because bucket
counts cannot be re-binned after the fact.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.energy import ledger_scatter_add

# Default histogram edges (seconds-ish scale); families may override.
DEFAULT_EDGES = 10.0 ** np.arange(-6.0, 2.0, 1.0)

_KINDS = ("counter", "gauge", "histogram")


class Family:
    """One named metric family: a label-tuple -> row index intern table
    plus numpy value columns that grow by doubling."""

    def __init__(self, kind: str, name: str, help: str = "",
                 label_names: Sequence[str] = (), edges=None) -> None:
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        self.kind = kind
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._index: Dict[Tuple[str, ...], int] = {}
        self._labels: List[Tuple[str, ...]] = []
        if kind == "histogram":
            self.edges = np.asarray(DEFAULT_EDGES if edges is None else edges,
                                    dtype=float)
            self._bins = np.zeros((0, self.edges.size + 1), dtype=np.int64)
            self._sum = np.zeros(0, dtype=float)
        self.values = np.zeros(0, dtype=float)

    def __len__(self) -> int:
        return len(self._labels)

    def _grow(self, n: int) -> None:
        have = self.values.size
        if n <= have:
            return
        new = max(n, 2 * have, 8)
        self.values = np.concatenate(
            [self.values, np.zeros(new - have, dtype=float)])
        if self.kind == "histogram":
            self._bins = np.concatenate(
                [self._bins,
                 np.zeros((new - have, self.edges.size + 1), dtype=np.int64)])
            self._sum = np.concatenate(
                [self._sum, np.zeros(new - have, dtype=float)])

    def row(self, labels: Tuple[str, ...] = ()) -> int:
        """Intern one label tuple; returns its stable row index."""
        labels = tuple(labels)
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {labels!r}")
        i = self._index.get(labels)
        if i is None:
            i = self._index[labels] = len(self._labels)
            self._labels.append(labels)
            self._grow(i + 1)
        return i

    def rows(self, labels_list) -> np.ndarray:
        """Intern many label tuples at once (O(len) dict work; pass the
        *distinct* labels of a batch, not per-task duplicates)."""
        return np.fromiter((self.row(l) for l in labels_list),
                           dtype=np.int64, count=len(labels_list))

    # -- counter / gauge -------------------------------------------------
    # NOTE: intern (which may reallocate the columns) BEFORE touching
    # self.values — `self.values[self.row(...)]` would bind the pre-grow
    # array first.
    def inc(self, value: float = 1.0, labels: Tuple[str, ...] = ()) -> None:
        i = self.row(labels)
        self.values[i] += value

    def inc_at(self, rows: np.ndarray, values) -> None:
        """Grouped scatter increment: ``values[k]`` into row ``rows[k]``,
        folded unbuffered so repeated rows accumulate deterministically."""
        ledger_scatter_add(self.values, rows, values)

    def set(self, value: float, labels: Tuple[str, ...] = ()) -> None:
        i = self.row(labels)
        self.values[i] = value

    def set_at(self, rows: np.ndarray, values) -> None:
        self.values[np.asarray(rows)] = values

    def get(self, labels: Tuple[str, ...] = ()) -> float:
        i = self._index.get(tuple(labels))
        return 0.0 if i is None else float(self.values[i])

    # -- histogram -------------------------------------------------------
    def observe(self, values, labels: Tuple[str, ...] = ()) -> None:
        """Fold a batch of observations into one labeled series."""
        if self.kind != "histogram":
            raise ValueError(f"{self.name} is a {self.kind}, not histogram")
        v = np.atleast_1d(np.asarray(values, dtype=float))
        if v.size == 0:
            return
        i = self.row(labels)
        which = np.searchsorted(self.edges, v, side="right")
        self._bins[i] += np.bincount(which, minlength=self.edges.size + 1)
        self._sum[i] += float(v.sum())
        self.values[i] += v.size          # observation count

    def quantile(self, q: float, labels: Tuple[str, ...] = ()) -> float:
        """Histogram quantile snapped to the upper edge of the bucket
        holding rank ``ceil(q * count)`` (see the module docstring's
        quantile-granularity contract). Returns ``nan`` with no samples;
        ``inf`` when the rank lands in the overflow (+Inf) bucket."""
        if self.kind != "histogram":
            raise ValueError(f"{self.name} is a {self.kind}, not histogram")
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        i = self._index.get(tuple(labels))
        if i is None:
            return float("nan")
        cum = np.cumsum(self._bins[i])
        total = int(cum[-1])
        if total == 0:
            return float("nan")
        rank = max(1, int(math.ceil(q * total)))
        j = int(np.searchsorted(cum, rank))
        return float(self.edges[j]) if j < self.edges.size else float("inf")

    # -- rendering -------------------------------------------------------
    @staticmethod
    def _label_str(names, labels) -> str:
        if not names:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in zip(names, labels))
        return "{" + inner + "}"

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        order = sorted(range(len(self._labels)),
                       key=lambda i: self._labels[i])
        for i in order:
            lab = self._labels[i]
            if self.kind == "histogram":
                cum = np.cumsum(self._bins[i])
                for j, edge in enumerate(self.edges):
                    le = self._label_str(self.label_names + ("le",),
                                         lab + (f"{edge:.9g}",))
                    lines.append(f"{self.name}_bucket{le} {cum[j]}")
                le = self._label_str(self.label_names + ("le",),
                                     lab + ("+Inf",))
                lines.append(f"{self.name}_bucket{le} {cum[-1]}")
                ls = self._label_str(self.label_names, lab)
                lines.append(f"{self.name}_sum{ls} {self._sum[i]:.9g}")
                lines.append(f"{self.name}_count{ls} {int(self.values[i])}")
            else:
                ls = self._label_str(self.label_names, lab)
                lines.append(f"{self.name}{ls} {self.values[i]:.9g}")
        return lines

    def snapshot(self) -> Dict[str, float]:
        """{rendered-label-string: value} for report(deep=True)."""
        out = {}
        for lab in sorted(self._labels):
            key = self._label_str(self.label_names, lab) or "_"
            out[key] = float(self.values[self._index[lab]])
        return out


class MetricsRegistry:
    """Named families with get-or-create accessors and text exposition."""

    def __init__(self) -> None:
        self._families: Dict[str, Family] = {}

    def _family(self, kind: str, name: str, help: str,
                labels: Sequence[str], edges=None) -> Family:
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = Family(kind, name, help,
                                               labels, edges)
        elif fam.kind != kind or fam.label_names != tuple(labels):
            raise ValueError(
                f"metric {name!r} re-registered as {kind}{tuple(labels)}, "
                f"was {fam.kind}{fam.label_names}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._family("counter", name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._family("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), edges=None) -> Family:
        return self._family("histogram", name, help, labels, edges)

    def get(self, name: str):
        return self._families.get(name)

    def families(self) -> List[str]:
        return sorted(self._families)

    def to_text(self) -> str:
        """Prometheus-style exposition, families and series sorted."""
        lines: List[str] = []
        for name in sorted(self._families):
            lines.extend(self._families[name].render())
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict[str, Dict]:
        return {name: {"kind": fam.kind, "values": fam.snapshot()}
                for name, fam in sorted(self._families.items())}
