"""Windowed rollups: bounded-memory sim-time series (DESIGN.md §12).

``RollupStore`` folds the per-task columns the engine and driver already
compute into fixed-width sim-time windows (``window_hours`` wide,
anchored at hour 0): carbon grams, energy kWh, SLO miss counts, the
admission-verdict mix, per-tenant carbon spend, and the fleet
availability floor per window. A 10^6-client run exports O(windows)
numbers, not O(tasks) — the windows grow by doubling with the furthest
hour touched, never with task count.

Feeding is split by layer so a hub shared between the engine and the
driver never double-counts: the **engine** folds executed carbon/energy,
the verdict mix, and per-tenant spend (``_obs_record_step`` /
``_obs_record_tenancy``); the **driver** folds SLO misses (it alone
knows queueing latency) and availability transitions (it alone sees
fault events). Every fold is a deterministic scatter
(``np.add.at``-style unbuffered accumulation in input order) or a
sequential ``np.add.accumulate`` sum, so two same-seed runs — and the
batched vs scalar execute paths, and the calendar vs heap event queues —
produce bit-identical rollups (asserted by ``gate_obs``).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

# Verdict-mix column order == repro.obs.trace.VERDICT_LABELS.
VERDICT_COLS = ("done", "reject", "defer", "dead", "retry")

_GROW_MIN = 64


def _seq_sum(x) -> float:
    """Strict left-fold sum (bit-identical to a scalar ``+=`` loop)."""
    x = np.asarray(x, dtype=float)
    return float(np.add.accumulate(x)[-1]) if x.size else 0.0


class RollupStore:
    """Fixed-width sim-time windows over the run's metric columns."""

    def __init__(self, window_hours: float = 0.25) -> None:
        if window_hours <= 0:
            raise ValueError("window_hours must be > 0")
        self.window_hours = float(window_hours)
        self._last_window = -1            # highest window index touched
        self._tenant_idx: Dict[str, int] = {}
        self._tenant_names: List[str] = []
        cap = _GROW_MIN
        self.tasks = np.zeros(cap, dtype=np.int64)
        self.carbon_g = np.zeros(cap)
        self.energy_kwh = np.zeros(cap)
        self.slo_miss = np.zeros(cap, dtype=np.int64)
        self.verdicts = np.zeros((cap, len(VERDICT_COLS)), dtype=np.int64)
        self.avail_min = np.full(cap, np.nan)   # nan = no transition seen
        self.tenant_spend = np.zeros((0, cap))  # (tenants, windows)
        self._avail_last = 1.0                  # forward-fill state

    # ------------------------------------------------------------------
    # geometry / growth
    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.tasks.size

    @property
    def n_windows(self) -> int:
        """Windows actually touched (index 0..n_windows-1)."""
        return self._last_window + 1

    @property
    def nbytes(self) -> int:
        return (self.tasks.nbytes + self.carbon_g.nbytes
                + self.energy_kwh.nbytes + self.slo_miss.nbytes
                + self.verdicts.nbytes + self.avail_min.nbytes
                + self.tenant_spend.nbytes)

    def window_of(self, hour: float) -> int:
        return int(hour // self.window_hours)

    def _grow_to(self, w: int) -> None:
        if w > self._last_window:
            self._last_window = w
        have = self.capacity
        if w < have:
            return
        new = max(w + 1, 2 * have, _GROW_MIN)
        pad = new - have

        def _ext(a, fill=0.0):
            return np.concatenate(
                [a, np.full(pad, fill, dtype=a.dtype)])

        self.tasks = _ext(self.tasks)
        self.carbon_g = _ext(self.carbon_g)
        self.energy_kwh = _ext(self.energy_kwh)
        self.slo_miss = _ext(self.slo_miss)
        self.avail_min = _ext(self.avail_min, np.nan)
        self.verdicts = np.concatenate(
            [self.verdicts,
             np.zeros((pad, len(VERDICT_COLS)), dtype=np.int64)])
        if self.tenant_spend.size or self._tenant_names:
            self.tenant_spend = np.concatenate(
                [self.tenant_spend,
                 np.zeros((self.tenant_spend.shape[0], pad))], axis=1)

    def tenant_row(self, name: str) -> int:
        i = self._tenant_idx.get(name)
        if i is None:
            i = self._tenant_idx[name] = len(self._tenant_names)
            self._tenant_names.append(name)
            self.tenant_spend = np.concatenate(
                [self.tenant_spend, np.zeros((1, self.capacity))], axis=0)
        return i

    def intern_tenants(self, names) -> np.ndarray:
        """Rows for an array of tenant names (pass distinct names)."""
        return np.fromiter((self.tenant_row(str(n)) for n in names),
                           dtype=np.int64, count=len(names))

    def tenant_names(self) -> List[str]:
        return list(self._tenant_names)

    # ------------------------------------------------------------------
    # folds (engine side)
    # ------------------------------------------------------------------
    def fold_exec(self, hour: float, carbon_g, energy_kwh) -> None:
        """One executed batch: carbon/energy sums into ``hour``'s window
        (sequential fold — bit-identical across execute paths)."""
        w = self.window_of(hour)
        self._grow_to(w)
        n = np.asarray(carbon_g).size
        self.tasks[w] += n
        self.carbon_g[w] += _seq_sum(carbon_g)
        self.energy_kwh[w] += _seq_sum(energy_kwh)

    def fold_verdicts(self, hour: float, counts) -> None:
        """Admission/outcome mix for one step: ``counts`` is a length-5
        vector in :data:`VERDICT_COLS` order."""
        w = self.window_of(hour)
        self._grow_to(w)
        self.verdicts[w] += np.asarray(counts, dtype=np.int64)

    def fold_tenant_spend(self, hour: float, tenant_rows, carbon_g) -> None:
        """Executed carbon per tenant (rows from :meth:`tenant_row`),
        scattered unbuffered so repeated rows accumulate in order."""
        rows = np.asarray(tenant_rows, dtype=np.int64)
        if rows.size == 0:
            return
        w = self.window_of(hour)
        self._grow_to(w)
        np.add.at(self.tenant_spend[:, w], rows,
                  np.asarray(carbon_g, dtype=float))

    # ------------------------------------------------------------------
    # folds (driver side)
    # ------------------------------------------------------------------
    def fold_slo(self, finish_hours, miss_mask) -> None:
        """SLO misses scattered by each task's finish-hour window. The
        span always grows to the latest finish (miss or not) so the
        exported series covers every window tasks completed in."""
        h = np.asarray(finish_hours, dtype=float)
        if h.size == 0:
            return
        self._grow_to(int(h.max() // self.window_hours))
        miss = np.asarray(miss_mask, dtype=bool)
        if not miss.any():
            return
        w = (h[miss] // self.window_hours).astype(np.int64)
        np.add.at(self.slo_miss, w, 1)

    def note_availability(self, hour: float, frac: float) -> None:
        """A fleet-availability transition at ``hour`` (down-set changed):
        per-window minimum, forward-filled at export."""
        w = self.window_of(hour)
        self._grow_to(w)
        cur = self.avail_min[w]
        self.avail_min[w] = frac if np.isnan(cur) else min(cur, frac)
        self._avail_last = frac

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def availability(self) -> np.ndarray:
        """Per-window availability floor, forward-filled from 1.0:
        a window with no transition inherits the last known level."""
        n = self.n_windows
        out = np.empty(n)
        level = 1.0
        raw = self.avail_min[:n]
        for i in range(n):            # O(windows), not O(events)
            if not np.isnan(raw[i]):
                level = raw[i]
            out[i] = level
        return out

    def export(self) -> Dict:
        """JSON-ready O(windows) series, trimmed to windows touched."""
        n = self.n_windows
        out: Dict = {
            "window_hours": self.window_hours,
            "n_windows": n,
            "tasks": self.tasks[:n].tolist(),
            "carbon_g": self.carbon_g[:n].tolist(),
            "energy_kwh": self.energy_kwh[:n].tolist(),
            "slo_miss": self.slo_miss[:n].tolist(),
            "availability": self.availability().tolist(),
        }
        for j, lbl in enumerate(VERDICT_COLS):
            out[f"verdict_{lbl}"] = self.verdicts[:n, j].tolist()
        if self._tenant_names:
            out["tenant_spend_g"] = {
                name: self.tenant_spend[i, :n].tolist()
                for name, i in sorted(self._tenant_idx.items())}
        return out

    def stats(self) -> Dict:
        n = self.n_windows
        return {"windows": n,
                "window_hours": self.window_hours,
                "tasks": int(self.tasks[:n].sum()),
                "carbon_g": _seq_sum(self.carbon_g[:n]),
                "slo_miss": int(self.slo_miss[:n].sum()),
                "tenants": len(self._tenant_names),
                "nbytes": self.nbytes}

    def to_text(self) -> str:
        """Deterministic per-window rendering (``%.9g`` floats) — the
        byte-comparison surface for the rollup-determinism gate."""
        n = self.n_windows
        avail = self.availability()
        lines = []
        for w in range(n):
            v = " ".join(f"{lbl}={self.verdicts[w, j]}"
                         for j, lbl in enumerate(VERDICT_COLS))
            spend = " ".join(
                f"spend[{name}]={self.tenant_spend[i, w]:.9g}"
                for name, i in sorted(self._tenant_idx.items()))
            lines.append(
                f"w={w} tasks={self.tasks[w]} "
                f"carbon_g={self.carbon_g[w]:.9g} "
                f"energy_kwh={self.energy_kwh[w]:.9g} "
                f"slo_miss={self.slo_miss[w]} "
                f"avail={avail[w]:.9g} {v}"
                + (f" {spend}" if spend else ""))
        return "\n".join(lines) + ("\n" if lines else "")
