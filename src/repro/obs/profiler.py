"""Per-phase step profiler (DESIGN.md §9).

``perf_counter`` spans around the featurize/select/execute/bill phases of
``engine.step`` (and the sim driver's event batches) are folded into fixed
log-spaced histograms — count / total / min / max / per-bin counts per
phase — so the paper's 0.03 ms scheduling-overhead claim is a continuously
tracked artifact (``BENCH_obs.json``) instead of an ad-hoc benchmark.

The accumulator is O(1) per span (a dict lookup, four scalar updates, and
one ``searchsorted`` into the shared edge vector); instrumented call sites
guard every ``perf_counter`` pair behind a single ``is not None`` check so
the disabled path pays one pointer comparison per phase.

Quantiles inherit the histogram's bucket granularity: ``percentile_s``
returns the *upper edge* of the bin holding the target rank (see the
quantile-granularity contract in ``repro.obs.registry``), so a p50 of
``0.0001`` means the median span fell in the ``(10^-4.5, 10^-4]`` s
bin. Pass custom ``edges`` at construction when half-decade resolution
is too coarse for a phase you care about.
"""
from __future__ import annotations

from contextlib import contextmanager
from time import perf_counter
from typing import Dict

import numpy as np

# Span-duration histogram edges (seconds): half-decade steps from 100 ns
# to 10 s, plus an implicit overflow bin. Fixed edges keep summaries
# comparable across phases, runs, and CI artifacts.
SPAN_EDGES_S = 10.0 ** np.arange(-7.0, 1.5, 0.5)


class _Phase:
    __slots__ = ("count", "total_s", "min_s", "max_s", "bins")

    def __init__(self, n_bins: int) -> None:
        self.count = 0
        self.total_s = 0.0
        self.min_s = float("inf")
        self.max_s = 0.0
        self.bins = np.zeros(n_bins, dtype=np.int64)


class StepProfiler:
    """Accumulate named wall-clock spans into per-phase histograms.

    ``edges`` (seconds, ascending) overrides the shared half-decade
    :data:`SPAN_EDGES_S` — a caller-supplied resolution choice made at
    construction, because bin counts cannot be re-binned afterwards."""

    def __init__(self, edges=None) -> None:
        self.edges = np.asarray(SPAN_EDGES_S if edges is None else edges,
                                dtype=float)
        if self.edges.ndim != 1 or self.edges.size == 0:
            raise ValueError("edges must be a non-empty 1-D array")
        self._phases: Dict[str, _Phase] = {}

    def add(self, phase: str, dt_s: float) -> None:
        """Fold one span of ``dt_s`` seconds into ``phase``."""
        p = self._phases.get(phase)
        if p is None:
            p = self._phases[phase] = _Phase(self.edges.size + 1)
        p.count += 1
        p.total_s += dt_s
        if dt_s < p.min_s:
            p.min_s = dt_s
        if dt_s > p.max_s:
            p.max_s = dt_s
        p.bins[int(np.searchsorted(self.edges, dt_s, side="right"))] += 1

    @contextmanager
    def span(self, phase: str):
        """Context-manager form of :meth:`add` for coarse, cold spans."""
        t0 = perf_counter()
        try:
            yield
        finally:
            self.add(phase, perf_counter() - t0)

    def count(self, phase: str) -> int:
        p = self._phases.get(phase)
        return 0 if p is None else p.count

    def total_s(self, phase: str) -> float:
        p = self._phases.get(phase)
        return 0.0 if p is None else p.total_s

    def phases(self):
        return sorted(self._phases)

    def percentile_s(self, phase: str, q: float) -> float:
        """Histogram-resolution upper bound on the ``q`` quantile (q in
        [0, 1]): the upper edge of the bin where the cumulative count
        crosses ``q * count`` (the observed max for the overflow bin)."""
        p = self._phases.get(phase)
        if p is None or p.count == 0:
            return float("nan")
        cum = np.cumsum(p.bins)
        i = int(np.searchsorted(cum, q * p.count, side="left"))
        if i >= self.edges.size:
            return p.max_s
        return float(self.edges[i])

    def summary(self) -> Dict:
        """JSON-ready per-phase aggregates plus the shared bin edges."""
        phases = {}
        for name in sorted(self._phases):
            p = self._phases[name]
            phases[name] = {
                "count": p.count,
                "total_s": p.total_s,
                "mean_s": p.total_s / p.count if p.count else 0.0,
                "min_s": p.min_s if p.count else 0.0,
                "max_s": p.max_s,
                "p50_s": self.percentile_s(name, 0.50),
                "p95_s": self.percentile_s(name, 0.95),
                "hist": p.bins.tolist(),
            }
        return {"edges_s": self.edges.tolist(), "phases": phases}

    def reset(self) -> None:
        self._phases.clear()
