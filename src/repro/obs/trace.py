"""Column-oriented decision trace (DESIGN.md §9).

``DecisionTrace`` is a fixed-capacity ring buffer of numpy columns — one
row per drained task — recording what the scheduler decided and why: the
chosen (node, cut, mode), the winning and runner-up totals, the execution
intensity (with its conformal interval when the provider carries a
calibrator), the billed intensity and carbon, the admission verdict, and
the tenant. The engine populates whole steps at a time from arrays it
already computed for batched execute+billing, so recording costs
O(distinct nodes) Python and a handful of vectorized column writes — no
per-task loops on the hot path.

Node and tenant names are interned to integer ids (over *distinct* values
only); the JSONL exporter resolves them back and emits rows oldest-first
with sorted keys and NaN/Inf mapped to null, so a fixed-seed run exports a
byte-identical trace.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterator, List, Optional

import numpy as np

# Admission verdict encoding for the ``verdict`` column. NOTE: this is the
# trace's own encoding (done first, because untenanted steps are all-done);
# repro.tenancy.policy orders its action constants ADMIT/DEFER/REJECT —
# the engine maps explicitly, never by passing action codes through.
VERDICT_DONE, VERDICT_REJECT, VERDICT_DEFER = 0, 1, 2
VERDICT_DEAD, VERDICT_RETRY = 3, 4       # resilience outcomes (DESIGN.md §10)
VERDICT_LABELS = ("done", "reject", "defer", "dead", "retry")

# Mode encoding for the ``mode`` column; must match
# ``repro.tenancy.spec.MODE_ORDER`` (kept duplicated so repro.obs imports
# only stdlib+numpy; consistency is asserted in tests/test_obs.py).
MODE_LABELS = ("performance", "balanced", "green")


class DecisionTrace:
    """Ring buffer of per-task scheduling decisions, as numpy columns."""

    def __init__(self, capacity: int = 1 << 16) -> None:
        cap = int(capacity)
        if cap <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = cap
        self.count = 0            # rows ever recorded (ring keeps last cap)
        self._name_ids: Dict[str, Dict[str, int]] = {"node": {},
                                                     "tenant": {}}
        self._names: Dict[str, List[str]] = {"node": [], "tenant": []}
        self.step = np.zeros(cap, dtype=np.int64)
        self.pos = np.zeros(cap, dtype=np.int32)
        self.hour = np.zeros(cap, dtype=np.float64)
        self.verdict = np.zeros(cap, dtype=np.int8)
        self.node = np.full(cap, -1, dtype=np.int32)
        self.cut = np.full(cap, -1, dtype=np.int32)
        self.mode = np.full(cap, -1, dtype=np.int8)
        self.tenant = np.full(cap, -1, dtype=np.int32)
        self.score = np.full(cap, np.nan)
        self.runner_up = np.full(cap, np.nan)
        self.intensity = np.full(cap, np.nan)
        self.interval_lo = np.full(cap, np.nan)
        self.interval_hi = np.full(cap, np.nan)
        self.intensity_billed = np.full(cap, np.nan)
        self.carbon_g = np.full(cap, np.nan)
        self.expected_g = np.full(cap, np.nan)

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def intern_names(self, names, kind: str = "node") -> np.ndarray:
        """Map a sequence of names to stable integer ids (per ``kind``
        namespace). O(distinct) dict work: pass the *unique* node array
        the engine already holds and fan out with its inverse index."""
        arr = np.asarray(names, dtype=object)
        table = self._name_ids[kind]
        out_names = self._names[kind]
        if arr.size == 0:
            return np.zeros(0, dtype=np.int32)
        uniq, inv = np.unique(arr, return_inverse=True)
        ids = np.empty(uniq.size, dtype=np.int32)
        for k, name in enumerate(uniq):
            i = table.get(name)
            if i is None:
                i = table[name] = len(out_names)
                out_names.append(str(name))
            ids[k] = i
        return ids[inv]

    def names(self, kind: str = "node") -> List[str]:
        return list(self._names[kind])

    def record_batch(self, *, step, hour, verdict,
                     pos=None, node=None, cut=None, mode=None, tenant=None,
                     score=None, runner_up=None,
                     intensity=None, interval_lo=None, interval_hi=None,
                     intensity_billed=None, carbon_g=None,
                     expected_g=None) -> None:
        """Append one engine step's rows. ``verdict`` fixes the row count;
        every other column accepts an array of that length, a scalar to
        broadcast, or ``None`` for the column's "absent" fill (so ring
        slots being overwritten never leak stale values). ``node`` and
        ``tenant`` take *interned ids* (see :meth:`intern_names`)."""
        v = np.asarray(verdict, dtype=np.int8)
        m = int(v.size)
        if m == 0:
            return
        if m > self.capacity:       # keep only the rows that would survive
            drop = m - self.capacity

            def _clip(x):
                return x[drop:] if (x is not None
                                    and np.ndim(x) == 1) else x

            self.count += drop      # dropped rows still count as recorded
            return self.record_batch(
                step=step, hour=hour, verdict=v[drop:],
                pos=(_clip(pos) if pos is not None
                     else np.arange(drop, m)),
                node=_clip(node), cut=_clip(cut), mode=_clip(mode),
                tenant=_clip(tenant), score=_clip(score),
                runner_up=_clip(runner_up), intensity=_clip(intensity),
                interval_lo=_clip(interval_lo),
                interval_hi=_clip(interval_hi),
                intensity_billed=_clip(intensity_billed),
                carbon_g=_clip(carbon_g), expected_g=_clip(expected_g))
        start = self.count % self.capacity
        if start + m <= self.capacity:            # contiguous fast path
            idx = slice(start, start + m)
        else:
            idx = (start + np.arange(m)) % self.capacity
        self.step[idx] = step
        self.hour[idx] = hour
        self.verdict[idx] = v
        self.pos[idx] = np.arange(m) if pos is None else pos
        cols = ((self.node, node, -1), (self.cut, cut, -1),
                (self.mode, mode, -1), (self.tenant, tenant, -1),
                (self.score, score, np.nan),
                (self.runner_up, runner_up, np.nan),
                (self.intensity, intensity, np.nan),
                (self.interval_lo, interval_lo, np.nan),
                (self.interval_hi, interval_hi, np.nan),
                (self.intensity_billed, intensity_billed, np.nan),
                (self.carbon_g, carbon_g, np.nan),
                (self.expected_g, expected_g, np.nan))
        for col, val, absent in cols:
            col[idx] = absent if val is None else val
        self.count += m

    # ------------------------------------------------------------------
    # reading
    # ------------------------------------------------------------------
    def _order(self) -> np.ndarray:
        """Indices of retained rows, oldest first."""
        n = min(self.count, self.capacity)
        if self.count <= self.capacity:
            return np.arange(n)
        head = self.count % self.capacity
        return np.concatenate([np.arange(head, self.capacity),
                               np.arange(head)])

    def __len__(self) -> int:
        return min(self.count, self.capacity)

    def row(self, i: int) -> Dict:
        """The ``i``-th retained row (0 = oldest), names resolved."""
        j = int(self._order()[i])

        def f(x) -> Optional[float]:
            x = float(x)
            return x if math.isfinite(x) else None

        node_names, tenant_names = self._names["node"], self._names["tenant"]
        nd, tn = int(self.node[j]), int(self.tenant[j])
        cut = int(self.cut[j])
        md = int(self.mode[j])
        return {
            "step": int(self.step[j]),
            "task": int(self.pos[j]),
            "hour": float(self.hour[j]),
            "verdict": VERDICT_LABELS[int(self.verdict[j])],
            "node": node_names[nd] if nd >= 0 else None,
            "cut": cut if cut >= 0 else None,
            "mode": MODE_LABELS[md] if 0 <= md < len(MODE_LABELS) else None,
            "tenant": tenant_names[tn] if tn >= 0 else None,
            "score": f(self.score[j]),
            "runner_up": f(self.runner_up[j]),
            "intensity": f(self.intensity[j]),
            "interval_lo": f(self.interval_lo[j]),
            "interval_hi": f(self.interval_hi[j]),
            "intensity_billed": f(self.intensity_billed[j]),
            "carbon_g": f(self.carbon_g[j]),
            "expected_g": f(self.expected_g[j]),
        }

    def rows(self) -> Iterator[Dict]:
        for i in range(len(self)):
            yield self.row(i)

    def to_jsonl(self) -> str:
        """Deterministic JSONL: oldest-first, sorted keys, NaN/Inf -> null
        (``json`` would otherwise emit non-standard ``NaN`` literals)."""
        lines = [json.dumps(r, sort_keys=True) for r in self.rows()]
        return "\n".join(lines) + ("\n" if lines else "")

    def export_jsonl(self, path: str, *, append: bool = False,
                     chunk_rows: int = 4096) -> int:
        """Stream the trace to ``path`` one ``chunk_rows`` buffer at a
        time; returns the row count. Peak memory is O(chunk_rows), not
        O(rows), so a 10^7-task export never materializes the full
        string. Each line is byte-identical to the corresponding
        :meth:`to_jsonl` line (oldest-first, sorted keys, NaN/Inf ->
        null). ``append=True`` opens in append mode for incremental
        drain-and-export loops."""
        n = len(self)
        with open(path, "a" if append else "w") as fh:
            buf: List[str] = []
            for i in range(n):
                buf.append(json.dumps(self.row(i), sort_keys=True))
                if len(buf) >= chunk_rows:
                    fh.write("\n".join(buf) + "\n")
                    buf = []
            if buf:
                fh.write("\n".join(buf) + "\n")
        return n

    # ------------------------------------------------------------------
    # aggregates
    # ------------------------------------------------------------------
    def explain(self, step: int, task: int) -> Optional[str]:
        """One-line "why": the decision row for (step, task), rendered."""
        order = self._order()
        hit = np.nonzero((self.step[order] == step)
                         & (self.pos[order] == task))[0]
        if hit.size == 0:
            return None
        r = self.row(int(hit[-1]))
        parts = [f"step {r['step']} task {r['task']}: {r['verdict']}"]
        if r["node"] is not None:
            where = f"on {r['node']!r}"
            if r["cut"] is not None:
                where += f" at cut {r['cut']}"
            if r["mode"] is not None:
                where += f" ({r['mode']} mode)"
            parts.append(where)
        if r["score"] is not None:
            s = f"score {r['score']:.6g}"
            if r["runner_up"] is not None and math.isfinite(r["runner_up"]):
                s += (f" vs runner-up {r['runner_up']:.6g}"
                      f" (margin {r['score'] - r['runner_up']:.6g})")
            parts.append(s)
        if r["intensity"] is not None:
            s = f"intensity {r['intensity']:.6g} gCO2/kWh"
            if r["interval_lo"] is not None and r["interval_hi"] is not None:
                s += f" in [{r['interval_lo']:.6g}, {r['interval_hi']:.6g}]"
            parts.append(s)
        if r["carbon_g"] is not None:
            parts.append(f"billed {r['carbon_g']:.6g} gCO2")
        return "; ".join(parts)

    def verdict_counts(self) -> Dict[str, int]:
        order = self._order()
        counts = np.bincount(self.verdict[order],
                             minlength=len(VERDICT_LABELS))
        # resilience verdicts appear only when present, so pre-§10
        # consumers keep seeing the original three-key dict
        return {lbl: int(counts[i]) for i, lbl in enumerate(VERDICT_LABELS)
                if i < 3 or counts[i]}

    def cut_histogram(self) -> Dict[int, int]:
        """Retained-row counts per partition cut index (placed rows with a
        cut only); empty when no partition policy ran."""
        order = self._order()
        cuts = self.cut[order]
        cuts = cuts[cuts >= 0]
        if cuts.size == 0:
            return {}
        uniq, counts = np.unique(cuts, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, counts)}

    def conformal_coverage(self) -> Dict:
        """Empirical coverage of the recorded conformal intervals against
        the intensity each row was actually billed at (falling back to
        the execution intensity) — only rows with a non-degenerate
        interval count."""
        order = self._order()
        lo = self.interval_lo[order]
        hi = self.interval_hi[order]
        billed = self.intensity_billed[order]
        x = np.where(np.isfinite(billed), billed, self.intensity[order])
        m = (np.isfinite(lo) & np.isfinite(hi) & (hi > lo) & np.isfinite(x))
        if not m.any():
            return {"rows": 0, "coverage": None, "mean_width": None}
        inside = (x[m] >= lo[m]) & (x[m] <= hi[m])
        return {"rows": int(m.sum()),
                "coverage": float(inside.mean()),
                "mean_width": float((hi[m] - lo[m]).mean())}

    def stats(self) -> Dict:
        return {"recorded": self.count,
                "retained": len(self),
                "capacity": self.capacity,
                "verdicts": self.verdict_counts(),
                "nodes": len(self._names["node"]),
                "tenants": len(self._names["tenant"])}
