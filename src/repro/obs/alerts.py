"""Declarative alerting over windowed rollups (DESIGN.md §12).

``AlertEngine`` evaluates a list of :class:`AlertRule` thresholds as one
vectorized pass per complete rollup window and emits deterministic
fire/resolve :class:`AlertEvent` records. Rules are pure window-level
predicates over :class:`~repro.obs.rollup.RollupStore` columns:

- ``slo_burn_rate``   — window SLO-miss fraction vs a miss tolerance;
- ``carbon_pace``     — (per-tenant) carbon grams spent in the window vs
  the allowance pace (allowance_g x window/period);
- ``dead_letter_rate``— window dead-letter fraction of terminal verdicts;
- ``availability``    — per-window availability floor below a fraction.

Evaluation is incremental (``evaluate`` only looks at windows completed
since the previous call) and stateful per rule: a rule *fires* on the
first window its predicate trips while inactive and *resolves* on the
first clean window while active, so the event stream is a deduplicated
transition log, not a per-window spam feed. Events are ordered (window
asc, then rule order) and rendered with ``%.9g`` floats — the
byte-comparison surface for the alert-determinism gate. ``export``
publishes fire/resolve counts per rule into a ``MetricsRegistry`` as
labelled counters; it deliberately does NOT touch the sim's
``MetricsCollector.to_text`` so the zero-overhead byte-identity
contract of the disabled path is preserved.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .rollup import RollupStore, VERDICT_COLS

ALERT_KINDS = ("slo_burn_rate", "carbon_pace", "dead_letter_rate",
               "availability")


@dataclass(frozen=True)
class AlertRule:
    """One declarative threshold over a rollup window.

    ``threshold`` semantics per kind: ``slo_burn_rate`` and
    ``dead_letter_rate`` trip when the window fraction EXCEEDS it;
    ``carbon_pace`` trips when window grams (for ``tenant``, or fleet
    when ``tenant`` is None) exceed it; ``availability`` trips when the
    window floor drops BELOW it. ``min_tasks`` suppresses rate rules on
    near-empty windows where one task flips the fraction.
    """
    name: str
    kind: str
    threshold: float
    tenant: Optional[str] = None
    min_tasks: int = 1

    def __post_init__(self) -> None:
        if self.kind not in ALERT_KINDS:
            raise ValueError(f"unknown alert kind: {self.kind!r}")


@dataclass(frozen=True)
class AlertEvent:
    """One deterministic transition: ``action`` is 'fire' or 'resolve',
    ``hour`` is the end of the triggering window, ``value`` the observed
    window statistic."""
    hour: float
    window: int
    rule: str
    action: str
    value: float

    def render(self) -> str:
        return (f"hour={self.hour:.9g} w={self.window} rule={self.rule} "
                f"{self.action} value={self.value:.9g}")


def default_rules(*, miss_tolerance: float = 0.1,
                  dead_letter_tolerance: float = 0.05,
                  availability_floor: float = 0.5,
                  min_tasks: int = 8) -> List[AlertRule]:
    """Fleet-level starter rules (per-tenant carbon-pace rules come from
    ``TenantPolicy.alert_rules``)."""
    return [
        AlertRule("slo_burn", "slo_burn_rate", miss_tolerance,
                  min_tasks=min_tasks),
        AlertRule("dead_letter", "dead_letter_rate", dead_letter_tolerance,
                  min_tasks=min_tasks),
        AlertRule("availability", "availability", availability_floor,
                  min_tasks=0),
    ]


class AlertEngine:
    """Vectorized fire/resolve evaluation of rules over rollup windows."""

    def __init__(self, rules: Optional[Sequence[AlertRule]] = None) -> None:
        self.rules: List[AlertRule] = list(rules) if rules else []
        self.events: List[AlertEvent] = []
        self._active = np.zeros(len(self.rules), dtype=bool)
        self._evaluated = 0               # windows already consumed

    def add_rules(self, rules: Sequence[AlertRule]) -> None:
        if not rules:
            return
        self.rules.extend(rules)
        self._active = np.concatenate(
            [self._active, np.zeros(len(rules), dtype=bool)])

    @property
    def active(self) -> List[str]:
        return [r.name for r, a in zip(self.rules, self._active) if a]

    # ------------------------------------------------------------------
    def _rule_values(self, rule: AlertRule, roll: RollupStore,
                     lo: int, hi: int, avail: np.ndarray) -> np.ndarray:
        """Observed statistic per window ``lo..hi-1`` (nan = no signal,
        never trips and never resolves an active alert by itself)."""
        tasks = roll.tasks[lo:hi].astype(float)
        if rule.kind == "slo_burn_rate":
            val = np.where(tasks >= max(rule.min_tasks, 1),
                           roll.slo_miss[lo:hi] / np.maximum(tasks, 1.0),
                           np.nan)
        elif rule.kind == "dead_letter_rate":
            term = (roll.verdicts[lo:hi, VERDICT_COLS.index("done")]
                    + roll.verdicts[lo:hi, VERDICT_COLS.index("reject")]
                    + roll.verdicts[lo:hi, VERDICT_COLS.index("dead")]
                    ).astype(float)
            val = np.where(term >= max(rule.min_tasks, 1),
                           roll.verdicts[lo:hi, VERDICT_COLS.index("dead")]
                           / np.maximum(term, 1.0),
                           np.nan)
        elif rule.kind == "carbon_pace":
            if rule.tenant is None:
                val = roll.carbon_g[lo:hi].copy()
            else:
                i = roll._tenant_idx.get(rule.tenant)
                val = (roll.tenant_spend[i, lo:hi].copy()
                       if i is not None else np.full(hi - lo, np.nan))
        else:  # availability
            val = avail[lo:hi].copy()
        return val

    def evaluate(self, roll: RollupStore,
                 up_to_window: Optional[int] = None) -> List[AlertEvent]:
        """Consume windows completed since the last call and return the
        NEW events (also appended to ``self.events``). ``up_to_window``
        caps evaluation (exclusive); default = all touched windows."""
        hi = roll.n_windows if up_to_window is None \
            else min(up_to_window, roll.n_windows)
        lo = self._evaluated
        if hi <= lo or not self.rules:
            self._evaluated = max(self._evaluated, hi)
            return []
        avail = roll.availability()
        wh = roll.window_hours
        # (R, W) trip matrix, one vectorized comparison per rule.
        new: List[AlertEvent] = []
        transitions: List[tuple] = []     # (window, rule_idx, fired, value)
        for ri, rule in enumerate(self.rules):
            val = self._rule_values(rule, roll, lo, hi, avail)
            if rule.kind == "availability":
                trip = val < rule.threshold
            else:
                trip = val > rule.threshold
            trip = np.where(np.isnan(val), False, trip)
            state = bool(self._active[ri])
            for k in range(hi - lo):
                if np.isnan(val[k]):
                    continue              # no signal: hold state
                t = bool(trip[k])
                if t != state:
                    transitions.append((lo + k, ri, t, float(val[k])))
                    state = t
            self._active[ri] = state
        transitions.sort(key=lambda e: (e[0], e[1]))
        for w, ri, fired, value in transitions:
            new.append(AlertEvent(
                hour=(w + 1) * wh, window=w, rule=self.rules[ri].name,
                action="fire" if fired else "resolve", value=value))
        self.events.extend(new)
        self._evaluated = hi
        return new

    # ------------------------------------------------------------------
    def counts(self) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for ev in self.events:
            d = out.setdefault(ev.rule, {"fire": 0, "resolve": 0})
            d[ev.action] += 1
        return out

    def export(self, registry) -> None:
        """Publish per-rule fire/resolve counters into a MetricsRegistry."""
        fam = registry.counter("repro_alert_events_total",
                               "Alert fire/resolve transitions.",
                               labels=("rule", "action"))
        for rule, d in sorted(self.counts().items()):
            for action in ("fire", "resolve"):
                if d[action]:
                    fam.inc(d[action], labels=(rule, action))

    def stats(self) -> Dict:
        return {"rules": len(self.rules),
                "events": len(self.events),
                "active": self.active,
                "windows_evaluated": self._evaluated}

    def to_text(self) -> str:
        """Deterministic event log — the byte-comparison surface for the
        alert-determinism gate."""
        lines = [ev.render() for ev in self.events]
        return "\n".join(lines) + ("\n" if lines else "")
