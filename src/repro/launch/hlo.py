"""HLO analysis: collective-byte extraction from partitioned HLO text.

``cost_analysis()`` has no collective accounting, so §Roofline's collective
term comes from summing **operand** bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.

The partitioned HLO prints operands without type annotations
(``all-reduce(%x)``), so operand bytes are derived from the *result* shape
and the replica-group size:

    all-reduce         operand = result
    all-to-all         operand = result
    collective-permute operand = result
    all-gather         operand = result / group_size
    reduce-scatter     operand = result * group_size
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind operand bytes (per device) summed over the module."""
    out = {k: 0.0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(m.group(1)))
        g = _group_size(line)
        if kind == "all-gather":
            operand = result_bytes / max(g, 1)
        elif kind == "reduce-scatter":
            operand = result_bytes * g
        else:
            operand = result_bytes
        out[kind] += float(operand)
    out["total"] = float(sum(out[k] for k in COLLECTIVE_OPS))
    return out


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            out[m.group(2)] += 1
    return out


# ---------------------------------------------------------------------------
# Dot-level FLOP attribution (perf-pass diagnostics)
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT )?(%[\w.\-]+) = ([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(r" dot\((%[\w.\-]+), (%[\w.\-]+)\)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_META_RE = re.compile(r'op_name="([^"]+)"')


def dot_flops(hlo_text: str):
    """Returns list of (flops, op_name, result_shape) per dot, using the
    lhs operand's contracting dims. Per-device numbers."""
    shapes = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            dims = tuple(int(x) for x in m.group(3).split(",") if x)
            shapes[m.group(1)] = dims
    out = []
    for line in hlo_text.splitlines():
        md = _DOT_RE.search(line)
        if not md:
            continue
        mres = _DEF_RE.match(line)
        mc = _CDIMS_RE.search(line)
        if not (mres and mc):
            continue
        lhs = shapes.get(md.group(1))
        res = tuple(int(x) for x in mres.group(3).split(",") if x)
        if lhs is None:
            continue
        cdims = [int(x) for x in mc.group(1).split(",") if x]
        k = 1
        for c in cdims:
            k *= lhs[c]
        n = 1
        for d in res:
            n *= d
        name = _META_RE.search(line)
        out.append((2.0 * n * k, name.group(1) if name else "?", res))
    return out
