"""ShapeDtypeStruct stand-ins for every model input, per input shape.

``input_specs`` never allocates device memory — it is the dry-run contract:
weak-type-correct, shardable abstract values.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer
from repro.optim import adamw

# Archs whose long_500k run uses the sliding-window variant (DESIGN.md
# §Arch-applicability): every full-attention layer is overridden to a 4096
# window so 524288-token decode is a deployable configuration.
SWA_OVERRIDE_WINDOW = 4096
NATIVE_LONG = {"xlstm-350m", "zamba2-2.7b", "gemma3-27b"}


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> Tuple[ModelConfig, bool]:
    """Returns (possibly-variant config, is_swa_variant)."""
    if shape.name == "long_500k" and cfg.name not in NATIVE_LONG:
        has_full_attn = any(ld.kind == "attn" and ld.window is None
                            for ld in cfg.layer_defs)
        if has_full_attn:
            return cfg.with_attention_window(SWA_OVERRIDE_WINDOW), True
    return cfg, False


def token_len(cfg: ModelConfig, seq: int) -> int:
    return seq - cfg.vision_tokens


def train_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    st = token_len(cfg, S)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, st), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, st), jnp.int32),
    }
    _add_extras(cfg, batch, B, S)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, token_len(cfg, S)), jnp.int32)}
    _add_extras(cfg, batch, B, S)
    return batch


def decode_inputs(cfg: ModelConfig, shape: InputShape):
    """(cache, token, pos) abstract values."""
    B, S = shape.global_batch, shape.seq_len
    cache = transformer.abstract_cache(cfg, B, S)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos


def _add_extras(cfg: ModelConfig, batch: Dict, B: int, S: int):
    dt = jnp.dtype(cfg.dtype)
    if cfg.encoder_layers:
        batch["encoder_embeds"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.d_model), dt)
    if cfg.mrope_sections:
        batch["mrope_positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32)


def abstract_train_state(cfg: ModelConfig):
    params = transformer.abstract_params(cfg)
    opt = adamw.abstract_init(params)
    return params, opt
