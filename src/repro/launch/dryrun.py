"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST set the host-device-count flag before any other import (jax locks the
device count on first init).

Two passes per combination:

1. **full**   — the production step function (scans intact) is jit-lowered
   with in_shardings on the 16x16 (and 2x16x16) mesh and compiled. Success
   proves the distribution config is coherent; memory_analysis() proves the
   footprint; collective op *counts* summarise the schedule.

2. **account** — roofline accounting. HloCostAnalysis counts while-loop
   bodies once, so the step is re-lowered with structural scans unrolled at
   repeats r=1 and r=2 and extrapolated: cost(R) = c1 + (R-1)*(c2-c1).
   sLSTM's time recurrence (never unrolled) gets an analytic per-step
   correction. Collective bytes come from the partitioned HLO text
   (launch/hlo.py). This pass runs on the single-pod mesh (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape decode_32k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] [--skip-account]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: E402
from repro.configs.registry import get_config, list_archs  # noqa: E402
from repro.core import costmodel, energy  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import modes, transformer  # noqa: E402
from repro.obs import console_logger  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import steps  # noqa: E402
from repro.sharding import rules  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Module-level logger (DESIGN.md §9): bare-message stream handler keeps the
# console output identical to the raw print() it replaces (StreamHandler
# flushes per record, preserving the old flush=True behaviour).
log = console_logger(__name__)


# ---------------------------------------------------------------------------
# Step construction
# ---------------------------------------------------------------------------


def build(cfg: ModelConfig, shape: InputShape, mesh):
    """Returns (fn, args, in_shardings)."""
    mode = "train" if shape.kind == "train" else "serve"
    pspec = rules.param_pspecs(cfg, mode, mesh)
    p_ns = rules.named(pspec, mesh)
    if shape.kind == "train":
        fn = steps.train_step(cfg, adamw.AdamWConfig())
        params, opt = specs_mod.abstract_train_state(cfg)
        batch = specs_mod.train_inputs(cfg, shape)
        b_ns = rules.named(rules.batch_pspecs(cfg, "train", shape.global_batch, mesh), mesh)
        o_ns = rules.named(rules.opt_pspecs(cfg, mesh), mesh)
        return fn, (params, opt, batch), (p_ns, o_ns, b_ns)
    if shape.kind == "prefill":
        fn = steps.prefill_step(cfg, shape.seq_len)
        params = transformer.abstract_params(cfg)
        batch = specs_mod.prefill_inputs(cfg, shape)
        b_ns = rules.named(rules.batch_pspecs(cfg, "prefill", shape.global_batch, mesh), mesh)
        return fn, (params, batch), (p_ns, b_ns)
    # decode
    fn = steps.decode_fn(cfg)
    params = transformer.abstract_params(cfg)
    cache, token, pos = specs_mod.decode_inputs(cfg, shape)
    c_ns = rules.named(rules.cache_pspecs(cfg, shape.global_batch, mesh), mesh)
    tok_spec = rules.batch_pspecs(cfg, "decode", shape.global_batch, mesh)["tokens"]
    t_ns = rules.named(tok_spec, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P

    pos_ns = NamedSharding(mesh, P())
    return fn, (params, cache, token, pos), (p_ns, c_ns, t_ns, pos_ns)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def _slstm_correction(cfg: ModelConfig, shape: InputShape):
    """Analytic (flops, bytes) for sLSTM time steps not visible to
    cost_analysis (scan body counted once)."""
    if cfg.xlstm is None or shape.kind == "decode":
        return 0.0, 0.0
    from repro.models import xlstm as xl

    H, hd = xl.slstm_dims(cfg)
    n_sl = sum(1 for ld in cfg.layer_defs if ld.kind == "slstm")
    if not n_sl:
        return 0.0, 0.0
    B, S = shape.global_batch, shape.seq_len
    steps_missing = S - 1
    cell_flops = B * (4 * 2 * H * hd * hd + 20 * H * hd)
    cell_bytes = B * (8 * H * hd) * 4
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd+remat
    return (mult * n_sl * steps_missing * cell_flops,
            mult * n_sl * steps_missing * cell_bytes)


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------


def _cost_items(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def full_pass(cfg, shape, multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh = build(cfg, shape, mesh)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_d[k] = getattr(mem, k, None)
    text = compiled.as_text()
    counts = hlo_mod.collective_counts(text)
    cbytes = hlo_mod.collective_bytes(text)
    flops, byts = _cost_items(compiled)
    return {
        "compile_s": round(t1 - t0, 2),
        "memory_analysis": mem_d,
        "collective_counts_static": counts,
        "collective_bytes_static_per_device": cbytes["total"],
        "flops_once_per_device": flops,
        "bytes_once_per_device": byts,
        "hlo_size_chars": len(text),
    }


def _acct_cfg(cfg: ModelConfig, r: int) -> ModelConfig:
    return dataclasses.replace(
        cfg, repeats=r, num_layers=len(cfg.pattern) * r + len(cfg.suffix))


def _acct_metrics(cfg, shape, mesh):
    """(flops, bytes, coll_bytes, counts) for one unrolled lowering."""
    fn, args, in_sh = build(cfg, shape, mesh)
    with modes.unroll_scans():
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            compiled = lowered.compile()
    flops, byts = _cost_items(compiled)
    text = compiled.as_text()
    cb = hlo_mod.collective_bytes(text)
    return flops, byts, cb["total"], hlo_mod.collective_counts(text)


# Quadratic sequence-extrapolation for combos whose fully-unrolled inner
# scans are too large to compile (zamba2 prefill_32k: 128 SSD chunks x
# layers). Step costs are polynomials of degree <= 2 in S (attention S^2,
# everything else linear), so a Lagrange fit through S/16, S/8, S/4 is
# exact: y(16x) = 56*y(x) - 90*y(2x) + 35*y(4x).
_S_EXTRAP_COEFF = (56.0, -90.0, 35.0)


def _needs_s_extrapolation(cfg, shape) -> bool:
    if shape.kind not in ("prefill", "train"):
        return False
    n_mamba = sum(1 for ld in cfg.layer_defs if ld.kind == "mamba2")
    if not n_mamba or cfg.ssm is None:
        return False
    chunks = shape.seq_len // cfg.ssm.chunk_size
    return chunks * min(n_mamba, 2 * len([1 for ld in cfg.pattern
                                          if ld.kind == "mamba2"])) > 256


def account_pass(cfg, shape):
    """Roofline accounting on the single-pod mesh."""
    mesh = make_production_mesh(multi_pod=False)
    chips = int(np.prod(list(mesh.shape.values())))
    extrap = _needs_s_extrapolation(cfg, shape)
    res = {}
    for r in (1, 2):
        c = _acct_cfg(cfg, r)
        if not extrap:
            res[r] = _acct_metrics(c, shape, mesh)
            continue
        ys = []
        for div in (16, 8, 4):
            s_small = dataclasses.replace(shape, name=f"{shape.name}@{div}",
                                          seq_len=shape.seq_len // div)
            ys.append(_acct_metrics(c, s_small, mesh))
        f = sum(k * y[0] for k, y in zip(_S_EXTRAP_COEFF, ys))
        b = sum(k * y[1] for k, y in zip(_S_EXTRAP_COEFF, ys))
        coll = sum(k * y[2] for k, y in zip(_S_EXTRAP_COEFF, ys))
        res[r] = (f, b, coll, ys[-1][3])
    R = cfg.repeats
    f = res[1][0] + (R - 1) * (res[2][0] - res[1][0])
    b = res[1][1] + (R - 1) * (res[2][1] - res[1][1])
    coll = res[1][2] + (R - 1) * (res[2][2] - res[1][2])
    f_corr, b_corr = _slstm_correction(cfg, shape)
    f += f_corr / chips
    b += b_corr / chips
    # Memory term: analytic fused-TPU HBM model (the CPU-backend HLO byte
    # count is unfused and overstates traffic 10-30x; kept as upper bound).
    hbm = costmodel.step_hbm_bytes(cfg, shape.seq_len, shape.global_batch,
                                   shape.kind)
    terms = energy.roofline(f * chips, hbm, coll * chips, chips)
    terms_upper = energy.roofline(f * chips, b * chips, coll * chips, chips)
    mf = model_flops(cfg, shape)
    return {
        "chips": chips,
        "hlo_flops_total": f * chips,
        "hlo_bytes_total_unfused": b * chips,
        "hbm_bytes_model": hbm,
        "collective_bytes_total": coll * chips,
        "roofline": terms.as_dict(),
        "memory_s_unfused_upper": terms_upper.memory_s,
        "model_flops": mf,
        "model_to_hlo_flops_ratio": mf / (f * chips) if f else None,
        "acct_r1": {"flops": res[1][0], "bytes": res[1][1], "coll": res[1][2]},
        "acct_r2": {"flops": res[2][0], "bytes": res[2][1], "coll": res[2][2]},
        "collective_counts_r2": res[2][3],
    }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_combo(arch: str, shape_name: str, multi_pod: bool, skip_account: bool,
              out_dir: Path = RESULTS_DIR, tag: str = "") -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    cfg, swa = specs_mod.config_for_shape(cfg0, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "swa_variant": swa, "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    out["full"] = full_pass(cfg, shape, multi_pod)
    if not skip_account and not multi_pod:
        out["account"] = account_pass(cfg, shape)
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    (out_dir / name).write_text(json.dumps(out, indent=1))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-account", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)

    combos = ([(a, s) for a in list_archs() for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    ok = fail = 0
    for arch, shape in combos:
        mesh_name = "2x16x16" if args.multipod else "16x16"
        f = out_dir / f"{arch}__{shape}__{mesh_name}.json"
        if args.skip_existing and f.exists():
            log.info("[skip] %s %s %s", arch, shape, mesh_name)
            continue
        t0 = time.time()
        try:
            r = run_combo(arch, shape, args.multipod, args.skip_account, out_dir)
            dt = time.time() - t0
            rt = r.get("account", {}).get("roofline", {})
            log.info("[ok]   %-18s %-12s %s  %7.1fs compile=%ss bottleneck=%s",
                     arch, shape, mesh_name, dt, r["full"]["compile_s"],
                     rt.get("bottleneck", "-"))
            ok += 1
        except Exception as e:  # noqa: BLE001
            dt = time.time() - t0
            log.error("[FAIL] %s %s %s after %.1fs: %s",
                      arch, shape, mesh_name, dt, e)
            traceback.print_exc()
            fail += 1
    log.info("done: %d ok, %d failed", ok, fail)
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
