"""Training launcher: --arch <id> [--steps N] with reduced-config CPU mode.

On the production mesh this is the function the dry-run lowers; here it
actually runs (reduced or full config, per flags) with the data pipeline,
AdamW, checkpointing and carbon accounting.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.registry import get_config, list_archs, reduced_config
from repro.core.carbon import CarbonMonitor
from repro.data.pipeline import DataConfig, make_batches
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (needs accelerators)")
    ap.add_argument("--corpus", default=None)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--carbon-intensity", type=float, default=380.0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else reduced_config(args.arch)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 10))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    step_fn = jax.jit(steps.train_step(cfg, opt_cfg))

    monitor = CarbonMonitor()
    monitor.register_region("train", args.carbon_intensity)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      corpus=args.corpus)
    batches = make_batches(cfg, dcfg)

    t_start = time.perf_counter()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        # Bill the step: wall-clock x a CPU power estimate on this host.
        monitor.record_power_sample("train", dt, p_cpu_w=65.0, ram_gb=4.0)
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:4d}  loss {loss:.4f}  {dt*1e3:7.1f} ms  "
                  f"lr {float(metrics['lr']):.2e}  gnorm {float(metrics['grad_norm']):.3f}")
    total = time.perf_counter() - t_start
    print(f"done {args.steps} steps in {total:.1f}s; "
          f"carbon {monitor.total_carbon_g():.4f} gCO2 "
          f"({monitor.total_energy_kwh()*1e3:.3f} Wh) at "
          f"{args.carbon_intensity:.0f} gCO2/kWh")
    if args.checkpoint:
        store.save(args.checkpoint, params,
                   {"arch": cfg.name, "steps": args.steps})
        print(f"checkpoint -> {args.checkpoint}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
