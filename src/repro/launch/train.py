"""Training launcher: --arch <id> [--steps N] with reduced-config CPU mode.

On the production mesh this is the function the dry-run lowers; here it
actually runs (reduced or full config, per flags) with the data pipeline,
AdamW, checkpointing and carbon accounting.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.registry import get_config, list_archs, reduced_config
from repro.core.carbon import CarbonMonitor
from repro.data.pipeline import DataConfig, make_batches
from repro.models import transformer
from repro.obs import console_logger
from repro.optim import adamw
from repro.runtime import steps

# Module-level logger (DESIGN.md §9): bare-message stream handler keeps the
# console output identical to the raw print() it replaces.
log = console_logger(__name__)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (needs accelerators)")
    ap.add_argument("--corpus", default=None)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--carbon-intensity", type=float, default=380.0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else reduced_config(args.arch)
    log.info("arch=%s layers=%d d_model=%d params~%.1fM",
             cfg.name, cfg.num_layers, cfg.d_model, cfg.param_count() / 1e6)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 10))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    step_fn = jax.jit(steps.train_step(cfg, opt_cfg))

    monitor = CarbonMonitor()
    monitor.register_region("train", args.carbon_intensity)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      corpus=args.corpus)
    batches = make_batches(cfg, dcfg)

    t_start = time.perf_counter()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        # Bill the step: wall-clock x a CPU power estimate on this host.
        monitor.record_power_sample("train", dt, p_cpu_w=65.0, ram_gb=4.0)
        if step % args.log_every == 0 or step == 1:
            log.info("step %4d  loss %.4f  %7.1f ms  lr %.2e  gnorm %.3f",
                     step, loss, dt * 1e3, float(metrics["lr"]),
                     float(metrics["grad_norm"]))
    total = time.perf_counter() - t_start
    log.info("done %d steps in %.1fs; carbon %.4f gCO2 (%.3f Wh) at "
             "%.0f gCO2/kWh",
             args.steps, total, monitor.total_carbon_g(),
             monitor.total_energy_kwh() * 1e3, args.carbon_intensity)
    if args.checkpoint:
        store.save(args.checkpoint, params,
                   {"arch": cfg.name, "steps": args.steps})
        log.info("checkpoint -> %s", args.checkpoint)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
