"""Production mesh construction.

A function (not module-level constant) so importing never touches jax
device state. Single pod: (16, 16) = 256 chips ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, model: int = 2, data: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires host-device-count env set)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
