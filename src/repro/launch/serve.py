"""Serving launcher: carbon-aware multi-pod inference (paper's deployment).

Simulates pods in three grid regions (the paper's node scenarios scaled to
pod granularity), routes batched requests via the NSA scheduler, and
reports per-region carbon. ``--mode`` picks the Table I weight profile.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config, list_archs, reduced_config
from repro.core import costmodel, energy
from repro.core.router import GreenRouter, PodSpec
from repro.models import transformer
from repro.obs import console_logger
from repro.runtime.serving import Request, ServingEngine

# Module-level logger (DESIGN.md §9): bare-message stream handler keeps the
# console output identical to the raw print() it replaces, while letting
# embedders re-route or silence the launcher through standard logging.
log = console_logger(__name__)

DEFAULT_PODS = [
    PodSpec("pod-high", chips=256, region="coal-heavy", carbon_intensity=620.0),
    PodSpec("pod-medium", chips=256, region="cn-average", carbon_intensity=530.0),
    PodSpec("pod-green", chips=256, region="hydro-rich", carbon_intensity=380.0),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-1.7b")
    ap.add_argument("--mode", choices=["performance", "balanced", "green"],
                    default="green")
    ap.add_argument("--policy", choices=["vectorized", "scalar"],
                    default="vectorized",
                    help="scheduling policy: the batched vectorized/Pallas "
                         "path (default) or the scalar Algorithm-1 oracle")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full_config else reduced_config(args.arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    from repro.core.policy import VectorizedPolicy, WeightedScoringPolicy
    policy = (WeightedScoringPolicy() if args.policy == "scalar"
              else VectorizedPolicy())
    router = GreenRouter(DEFAULT_PODS, mode=args.mode, policy=policy)

    # Seed each pod's history with its compiled-step roofline time (identical
    # model on each pod here; heterogeneous pods would differ).
    flops = 2.0 * cfg.active_param_count() * args.batch_size
    hbm = costmodel.step_hbm_bytes(cfg, args.prompt_len, args.batch_size, "decode")
    terms = energy.roofline(flops, hbm, 0.0, chips=256)
    router.seed_profile({p.name: terms for p in DEFAULT_PODS})

    engine = ServingEngine(cfg, params, router,
                           max_len=args.prompt_len + args.max_new + 8,
                           batch_size=args.batch_size)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        engine.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))
    comps = engine.run_all()
    for c in comps[:4]:
        log.info("req %d: pod=%s latency=%.1fms carbon=%.3fugCO2 tokens=%s...",
                 c.uid, c.pod, c.latency_s * 1e3, c.carbon_g * 1e6,
                 c.tokens[:6])
    rep = engine.report()
    log.info("\ncompleted=%d total carbon %.4f mgCO2",
             rep["completed"], rep["carbon_g_total"] * 1e3)
    for region, acc in rep["per_region"].items():
        log.info("  %-12s tasks=%4d carbon=%.4f mgCO2 I=%.0f",
                 region, acc["tasks"], acc["carbon_g"] * 1e3,
                 acc["intensity"])
    return rep


if __name__ == "__main__":
    main()
