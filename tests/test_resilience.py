"""Fault injection & failure-aware scheduling (repro.resilience,
DESIGN.md §10).

Covers: deterministic fault-schedule generation (fixed seed -> identical
schedule; the no-lag oracle variant), the circuit-breaker state machine
(threshold open, doubling cooldown, half-open probe, success close /
failure re-open), the FeatureCache availability mask (literally-absent
when healthy, data_rev-bumped mutations, rebuild re-projection), the
last-known-good degraded provider (healthy bit-identity, blackout
persistence values, staleness-widened conformal intervals), the engine
gate (zero-fault bit-identity on both execute paths, contact-failure
failover, capped-backoff retry -> dead-letter, partition cut-0 re-bill)
and the sim driver's fault events (zero-fault schedule byte-identity,
fixed-fault-seed byte-identical repeats).
"""
import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, StaticProvider,
                            intensity_interval_batch)
from repro.core.cluster import EdgeCluster, NodeSpec
from repro.core.scheduler import Task
from repro.resilience import (Fault, FaultInjector, FleetHealth, Resilience,
                              ResilientProvider)
from repro.sim import AsyncEngineDriver, PoissonArrivals
from repro.sim.events import EventKind


def fleet(n=6, cpu=2.0):
    c = EdgeCluster(nodes=[])
    for i in range(n):
        c.add_node(NodeSpec(f"n{i}", cpu=cpu, mem_mb=16000.0,
                            carbon_intensity=100.0 + 40.0 * i))
    return c


def engine(cluster=None, *, resilience=None, batch_execute=True, **kw):
    return CarbonEdgeEngine(cluster if cluster is not None else fleet(),
                            resilience=resilience,
                            batch_execute=batch_execute, **kw)


# ---------------------------------------------------------------------------
# Fault schedules
# ---------------------------------------------------------------------------


def test_generate_is_deterministic_per_seed():
    nodes = [f"n{i}" for i in range(8)]
    kw = dict(crash_rate_per_hour=2.0, mttr_hours=0.1,
              detect_delay_hours=0.02, outage_rate_per_hour=1.0,
              straggle_rate_per_hour=1.0, flap_rate_per_hour=1.0)
    a = FaultInjector.generate(nodes, 1.0, seed=5, **kw)
    b = FaultInjector.generate(nodes, 1.0, seed=5, **kw)
    c = FaultInjector.generate(nodes, 1.0, seed=6, **kw)
    assert a.schedule == b.schedule
    assert a.schedule != c.schedule
    assert a.schedule          # the rates above must actually produce faults
    hours = [f.hour for f in a.schedule]
    assert hours == sorted(hours)


def test_schedule_shapes_and_event_kinds():
    inj = FaultInjector.generate(["n0"], 1.0, seed=0,
                                 crash_rate_per_hour=50.0, mttr_hours=0.01,
                                 detect_delay_hours=0.005)
    kinds = {f.kind for f in inj.schedule}
    assert kinds == {"crash", "detect", "recover"}
    for f in inj.schedule:
        if f.kind == "crash":
            assert not f.detected
            assert f.event_kind is EventKind.NODE_DOWN
        elif f.kind == "recover":
            assert f.event_kind is EventKind.NODE_UP
    # every crash has a matching later recover
    win = inj.crash_windows()
    assert len(win) == sum(1 for f in inj.schedule if f.kind == "crash")
    assert all(up > down for _, down, up in win)
    assert inj.mttr_hours() > 0.0
    assert 0.0 <= inj.fleet_availability(1, 1.0) < 1.0


def test_without_detection_lag_oracle():
    inj = FaultInjector.generate(["n0", "n1"], 1.0, seed=2,
                                 crash_rate_per_hour=5.0,
                                 detect_delay_hours=0.05)
    oracle = inj.without_detection_lag()
    assert all(f.kind != "detect" for f in oracle.schedule)
    assert all(f.detected for f in oracle.schedule if f.kind == "crash")
    assert oracle.crash_windows() == inj.crash_windows()


def test_blackout_fault_toggles_provider():
    prov = ResilientProvider(StaticProvider({"n0": 100.0}))
    eng = engine(fleet(1), provider=prov, resilience=Resilience())
    inj = FaultInjector.scripted([Fault(0.1, "blackout"),
                                  Fault(0.2, "restore")])
    prov.intensity("n0", 0.0)      # record a last-known-good
    inj.advance(0.15, eng)
    assert prov.blackout
    inj.advance(0.25, eng)
    assert not prov.blackout


def test_straggle_fault_restores_bit_exact():
    cl = fleet(2)
    eng = engine(cl, resilience=Resilience())
    orig = cl.nodes["n1"].avg_time_ms
    inj = FaultInjector.scripted([
        Fault(0.1, "straggle", "n1", factor=3.0),
        Fault(0.2, "unstraggle", "n1")])
    inj.advance(0.1, eng)
    assert cl.nodes["n1"].avg_time_ms == orig * 3.0
    inj.advance(0.2, eng)
    assert cl.nodes["n1"].avg_time_ms == orig


# ---------------------------------------------------------------------------
# Circuit breaker state machine
# ---------------------------------------------------------------------------


def test_breaker_opens_at_threshold_and_cooldown_doubles():
    cl = fleet(3)
    res = Resilience(health=FleetHealth(breaker_threshold=2,
                                        breaker_cooldown_hours=0.1,
                                        breaker_cooldown_cap_hours=1.0))
    eng = engine(cl, resilience=res)
    cache = cl.feature_cache()
    h = res.health
    h.record_failure("n0", 0.0, cache)
    assert "n0" not in h.blocked           # below threshold
    h.record_failure("n0", 0.0, cache)
    assert "n0" in h.blocked               # threshold reached -> OPEN
    assert h.open_until["n0"] == pytest.approx(0.1)
    # cooldown expiry -> half-open (unblocked, streak survives)
    h.tick(0.11, cache)
    assert "n0" not in h.blocked
    # failure in half-open re-opens with a doubled cooldown
    h.record_failure("n0", 0.2, cache)
    assert "n0" in h.blocked
    assert h.open_until["n0"] == pytest.approx(0.2 + 0.2)
    # success in half-open closes fully
    h.tick(0.5, cache)
    h.record_success("n0", cache)
    assert "n0" not in h.blocked and "n0" not in h.consec
    assert "n0" not in h.open_until
    # cooldown is capped
    for k in range(8):
        h.record_failure("n1", 0.0, cache)
    assert h.open_until["n1"] - 0.0 <= 1.0 + 1e-12


def test_manual_mask_outlives_breaker_and_success():
    cl = fleet(2)
    res = Resilience()
    engine(cl, resilience=res)
    cache = cl.feature_cache()
    h = res.health
    h.set_manual("n0", cache)
    assert "n0" in h.blocked
    # success must NOT unmask a manually-down node (only NODE_UP does)
    h.record_success("n0", cache)
    assert "n0" in h.blocked
    h.clear_manual("n0", cache, float("-inf"))
    assert "n0" not in h.blocked


def test_availability_mask_is_absent_when_healthy_and_bumps_data_rev():
    cl = fleet(4)
    res = Resilience()
    engine(cl, resilience=res)
    cache = cl.feature_cache()
    assert cache.avail is None             # literally absent: zero overhead
    rev = cache.data_rev
    res.node_down("n2")                    # detected -> masked
    assert cache.data_rev > rev
    assert cache.avail is not None and not cache.avail[cache.index["n2"]]
    assert cache.node_ok()[cache.index["n2"]] == False  # noqa: E712
    rev = cache.data_rev
    res.node_up("n2")
    assert cache.data_rev > rev
    assert cache.avail is None             # back to the zero-cost state


def test_rebuild_preserves_mask():
    cl = fleet(4)
    res = Resilience()
    engine(cl, resilience=res)
    res.node_down("n1")
    cl.remove_node("n3")                   # topology change -> full rebuild
    cache = cl.feature_cache()
    assert cache.avail is not None
    assert not cache.avail[cache.index["n1"]]
    assert cache.fail_count is None or len(cache.fail_count) == cache.n


# ---------------------------------------------------------------------------
# Degraded-mode provider
# ---------------------------------------------------------------------------


def test_resilient_provider_healthy_is_bit_identical():
    base = StaticProvider({"a": 123.0, "b": 456.0})
    prov = ResilientProvider(base)
    names = ["a", "b"]
    assert prov.intensity("a", 0.5) == base.intensity("a", 0.5)
    np.testing.assert_array_equal(prov.intensity_batch(names, 0.5),
                                  base.intensity_batch(names, 0.5))
    lo, hi = intensity_interval_batch(prov, names, 0.5)
    blo, bhi = intensity_interval_batch(base, names, 0.5)
    np.testing.assert_array_equal(lo, blo)
    np.testing.assert_array_equal(hi, bhi)
    assert prov.covers("a") and not prov.covers("zzz")


def test_blackout_serves_last_known_good_and_widens():
    base = StaticProvider({"a": 100.0, "b": 300.0})
    prov = ResilientProvider(base, widen_g_per_hour=10.0)
    prov.intensity_batch(["a", "b"], 1.0)  # LKG recorded at hour 1
    prov.begin_blackout()
    assert prov.blackout
    np.testing.assert_array_equal(prov.intensity_batch(["a", "b"], 4.0),
                                  [100.0, 300.0])
    assert prov.intensity("b", 9.0) == 300.0
    assert prov.served_stale > 0
    # staleness-widened interval: +-(widen * hours-stale) around the LKG
    lo, hi = prov.intensity_interval_batch(["a", "b"], 4.0)
    np.testing.assert_allclose(lo, [70.0, 270.0])
    np.testing.assert_allclose(hi, [130.0, 330.0])
    lo2, hi2 = prov.intensity_interval_batch(["a", "b"], 8.0)
    assert np.all(hi2 - lo2 > hi - lo)     # widening grows with staleness
    assert np.all(np.asarray(lo2) >= 0.0)
    prov.end_blackout()
    assert not prov.blackout
    assert prov.intensity("a", 10.0) == 100.0


def test_blackout_without_lkg_raises_keyerror():
    prov = ResilientProvider(StaticProvider({"a": 100.0}))
    prov.begin_blackout()
    with pytest.raises(KeyError):
        prov.intensity("a", 0.0)
    prov.end_blackout()
    prov.intensity("a", 0.0)
    prov.begin_blackout()
    assert prov.intensity("a", 1.0) == 100.0


def test_blackouts_nest():
    prov = ResilientProvider(StaticProvider({"a": 1.0}))
    prov.begin_blackout()
    prov.begin_blackout()
    prov.end_blackout()
    assert prov.blackout
    prov.end_blackout()
    assert not prov.blackout


# ---------------------------------------------------------------------------
# Engine gate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch_execute", [True, False])
def test_zero_fault_resilience_is_bit_identical(batch_execute):
    """With resilience attached but no faults, every result and report
    matches a resilience-free engine exactly on both execute paths."""
    tasks = [Task(cpu=0.1 * (1 + i % 3), base_latency_ms=50.0 + i)
             for i in range(32)]
    ref = engine(fleet(), batch_execute=batch_execute, batch_size=8)
    ref.submit_many(list(tasks))
    wired = engine(fleet(), batch_execute=batch_execute, batch_size=8,
                   resilience=Resilience())
    wired.submit_many(list(tasks))
    while ref.queue or wired.queue:
        ra = ref.step(0.25)
        rb = wired.step(0.25)
        assert [(r.node, r.latency_ms, r.energy_kwh, r.carbon_g)
                for r in ra] == \
               [(r.node, r.latency_ms, r.energy_kwh, r.carbon_g)
                for r in rb]
    ra, rb = ref.report(), wired.report()
    assert ra["totals"] == rb["totals"]
    assert ra["outcomes"] == rb["outcomes"]


@pytest.mark.parametrize("batch_execute", [True, False])
def test_undetected_crash_fails_over(batch_execute):
    cl = fleet()
    res = Resilience()
    eng = engine(cl, resilience=res, batch_execute=batch_execute)
    eng.submit_many([Task() for _ in range(4)])
    pref = eng.step(0.0)[0].node
    res.node_down(pref, detected=False)    # scheduler doesn't know yet
    eng.submit_many([Task() for _ in range(4)])
    out = eng.step(0.1)
    assert len(out) == 4
    assert all(r.node != pref for r in out)
    # contact failure was recorded and the node masked by contact
    assert res.health.fails_total.get(pref) == 1
    assert pref in res.health.blocked
    assert all(o[0] == "done" for o in eng.last_outcomes)


def test_detected_crash_avoids_contact_entirely():
    cl = fleet()
    res = Resilience()
    eng = engine(cl, resilience=res)
    eng.submit_many([Task() for _ in range(2)])
    pref = eng.step(0.0)[0].node
    res.node_down(pref, detected=True)
    eng.submit_many([Task() for _ in range(4)])
    out = eng.step(0.1)
    assert all(r.node != pref for r in out)
    assert res.health.fails_total.get(pref) is None  # never contacted


@pytest.mark.parametrize("batch_execute", [True, False])
def test_retry_backoff_then_dead_letter(batch_execute):
    cl = fleet(2)
    res = Resilience(max_attempts=3, backoff_base_hours=0.01,
                     backoff_cap_hours=0.5)
    eng = engine(cl, resilience=res, batch_execute=batch_execute)
    res.node_down("n0")
    res.node_down("n1")
    eng.submit_many([Task() for _ in range(2)])
    assert eng.step(0.0) == []
    assert [o[0] for o in eng.last_outcomes] == ["retry", "retry"]
    wake = eng.last_outcomes[0][1]
    assert wake == pytest.approx(0.01)     # base backoff
    assert len(eng.deferred) == 2
    # second attempt: doubled backoff
    eng.submit_many(eng.pop_ripe(wake))
    assert eng.step(wake) == []
    assert eng.last_outcomes[0][1] - wake == pytest.approx(0.02)
    # third attempt == max_attempts: dead-letter
    ripe = eng.pop_ripe(1.0)
    eng.submit_many(ripe)
    assert eng.step(1.0) == []
    assert [o[0] for o in eng.last_outcomes] == ["dead", "dead"]
    assert len(eng.dead_letters) == 2
    rep = eng.report()
    assert rep["outcomes"]["dead"] == 2
    assert rep["outcomes"]["retry"] == 4
    assert rep["resilience"]["dead_letters"] == 2
    # recovery drains normally again
    res.node_up("n0")
    eng.submit_many([Task()])
    assert len(eng.step(2.0)) == 1


def test_backoff_is_capped():
    res = Resilience(backoff_base_hours=0.1, backoff_cap_hours=0.3)
    assert res.backoff_hours(1) == pytest.approx(0.1)
    assert res.backoff_hours(2) == pytest.approx(0.2)
    assert res.backoff_hours(3) == pytest.approx(0.3)
    assert res.backoff_hours(9) == pytest.approx(0.3)


def test_run_until_drains_retries_to_dead_letter():
    cl = fleet(2)
    res = Resilience(max_attempts=3, backoff_base_hours=0.01)
    eng = engine(cl, resilience=res)
    res.node_down("n0")
    res.node_down("n1")
    eng.submit_many([Task() for _ in range(3)])
    rep = eng.run_until(2.0)
    assert rep["outcomes"]["dead"] == 3
    assert not eng.deferred and not eng.queue


def test_partition_fallback_rebills_cut0():
    from repro.partition import PartitionPolicy, profile_costs
    prof = profile_costs([25.0, 25.0, 25.0, 25.0],
                         boundary_bytes=[4e6, 2e6, 1e6, 5e5, 0.0],
                         name="m")
    cl = fleet()
    pol = PartitionPolicy(prof, backend="numpy")
    res = Resilience()
    eng = engine(cl, policy=pol, resilience=res)
    task = Task(base_latency_ms=400.0)
    eng.submit_many([task])
    first = eng.step(0.0)[0]
    res.node_down(first.node, detected=False)
    eng.submit_many([Task(base_latency_ms=400.0)])
    out = eng.step(0.1)[0]
    assert out.node != first.node
    # failed-over task re-bills the whole model through the cut-0 column
    expected = pol.fallback_latency_ms(task)
    st = eng.cluster.nodes[out.node]
    lat, _ = eng.cluster.latency_energy(expected, distributed=True)
    assert out.latency_ms == pytest.approx(float(lat))


def test_tenancy_gate_failover_and_retry():
    from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec
    from repro.tenancy.spec import TenantTask
    reg = TenantRegistry([TenantSpec("t0")])
    cl = fleet()
    res = Resilience(max_attempts=2, backoff_base_hours=0.01)
    eng = engine(cl, policy=TenantPolicy(registry=reg), resilience=res)
    eng.submit_many([TenantTask(tenant="t0") for _ in range(2)])
    pref = eng.step(0.0)[0].node
    res.node_down(pref, detected=False)
    eng.submit_many([TenantTask(tenant="t0") for _ in range(2)])
    out = eng.step(0.1)
    assert len(out) == 2 and all(r.node != pref for r in out)
    assert all(o[0] == "done" for o in eng.last_outcomes)
    # all nodes down -> retries, with the admitted counting reversed
    for n in list(cl.nodes):
        res.node_down(n)
    admitted_before = int(reg.admitted[0])
    eng.submit_many([TenantTask(tenant="t0")])
    assert eng.step(0.2) == []
    assert eng.last_outcomes[0][0] == "retry"
    assert int(reg.admitted[0]) == admitted_before


# ---------------------------------------------------------------------------
# Sim driver integration
# ---------------------------------------------------------------------------


def sim_text(faults=None, *, resilient=True, seed=11):
    cl = fleet()
    prov = StaticProvider({n: cl.nodes[n].spec.carbon_intensity
                           for n in cl.nodes})
    eng = CarbonEdgeEngine(cl, provider=prov,
                           resilience=Resilience() if resilient else None)
    drv = AsyncEngineDriver(
        eng, PoissonArrivals(240.0, seed=seed),
        lambda uid, hour: Task(base_latency_ms=40.0),
        horizon_hours=0.5, max_batch=8, slo_latency_s=2.0, faults=faults)
    return drv.run().to_text()


def test_sim_zero_fault_schedule_is_byte_identical():
    plain = sim_text(None, resilient=False)
    wired = sim_text(FaultInjector.scripted([]), resilient=True)
    assert plain == wired


def test_sim_fixed_fault_seed_repeats_byte_identical():
    def inj():
        # seed 2 crashes n0 — the all-tasks-preferred node — so the fault
        # run observably diverges from the zero-fault one
        return FaultInjector.generate(
            [f"n{i}" for i in range(6)], 0.5, seed=2,
            crash_rate_per_hour=3.0, mttr_hours=0.08,
            detect_delay_hours=0.02, outage_rate_per_hour=1.0,
            outage_hours=0.1)
    a = sim_text(inj())
    b = sim_text(inj())
    assert a == b
    assert a != sim_text(None)             # the faults actually bite


def test_sim_driver_fires_fault_events():
    cl = fleet()
    res = Resilience()
    eng = CarbonEdgeEngine(cl, resilience=res)
    inj = FaultInjector.scripted([
        Fault(0.05, "crash", "n0", detected=False),
        Fault(0.07, "detect", "n0"),
        Fault(0.3, "recover", "n0")])
    drv = AsyncEngineDriver(
        eng, PoissonArrivals(100.0, seed=1),
        lambda uid, hour: Task(base_latency_ms=40.0),
        horizon_hours=0.5, max_batch=4, faults=inj)
    m = drv.run()
    assert len(m.records) > 0
    assert not res.down                    # recovered by the end
    assert all(r.node != "n0" or not (0.07 <= r.start_hour < 0.3)
               for r in m.records)
