"""Discrete-event serving simulator (repro.sim, DESIGN.md §2)."""
import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                            StaticProvider, TraceProvider)
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import Task
from repro.core.temporal import DeferrableTask, plan_wake, synthetic_trace
from repro.sim import (AsyncEngineDriver, ConstantRateArrivals,
                       DiurnalArrivals, EventHeap, EventKind, MMPPArrivals,
                       PoissonArrivals, TraceReplayArrivals, VirtualClock)

TASK = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)


def fresh_cluster():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(250.0)
    return c


def duck_traces():
    return {
        "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
        "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
        "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
    }


def trace_engine(mode="green"):
    c = fresh_cluster()
    provider = TraceProvider(duck_traces(),
                             fallback=StaticProvider.from_cluster(c))
    return CarbonEdgeEngine(c, mode=mode, provider=provider)


def make_driver(engine, arrivals, *, factory=None, **kw):
    return AsyncEngineDriver(engine, arrivals,
                             factory or (lambda uid, hour: TASK), **kw)


# ---------------------------------------------------------------------------
# Clock and events
# ---------------------------------------------------------------------------


def test_clock_is_monotonic():
    clk = VirtualClock(5.0)
    assert clk.advance_to(6.5) == 6.5
    with pytest.raises(ValueError, match="backwards"):
        clk.advance_to(6.0)


def test_event_heap_orders_by_time_then_insertion():
    h = EventHeap()
    h.push(2.0, EventKind.BATCH_READY, "late")
    h.push(1.0, EventKind.ARRIVAL, "a")
    h.push(1.0, EventKind.DEFER_WAKE, "b")       # same instant: FIFO
    h.push(0.5, EventKind.INTENSITY_TICK, "first")
    got = [h.pop().payload for _ in range(len(h))]
    assert got == ["first", "a", "b", "late"]


# ---------------------------------------------------------------------------
# Arrival processes: determinism, windows, shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proc", [
    PoissonArrivals(40.0, seed=3),
    DiurnalArrivals(40.0, seed=3),
    MMPPArrivals(10.0, 120.0, mean_sojourn_hours=0.5, seed=3),
])
def test_arrivals_deterministic_and_windowed(proc):
    a = proc.times(17.0, 6.0)
    b = proc.times(17.0, 6.0)
    np.testing.assert_array_equal(a, b)          # same seed, same stream
    assert np.all(np.diff(a) >= 0)
    assert a.size == 0 or (a[0] >= 17.0 and a[-1] < 23.0)
    c = type(proc)(**{**proc.__dict__, "seed": 4}).times(17.0, 6.0)
    assert a.shape != c.shape or not np.allclose(a, c)


def test_constant_rate_is_exact():
    ts = ConstantRateArrivals(50.0).times(2.0, 1.0)
    assert ts.shape == (50,)
    np.testing.assert_allclose(np.diff(ts), 1.0 / 50.0)
    assert ConstantRateArrivals(50.0).times(0.0, 0.0).size == 0


def test_diurnal_rate_tracks_profile():
    proc = DiurnalArrivals(200.0, seed=0)
    evening = proc.times(18.0, 2.0).size         # demand peak
    night = proc.times(3.0, 2.0).size            # demand trough
    assert evening > night


def test_trace_replay_clips_to_window():
    proc = TraceReplayArrivals([1.0, 2.5, 3.0, 9.0])
    np.testing.assert_array_equal(proc.times(2.0, 2.0), [2.5, 3.0])


def test_diurnal_rejects_profile_above_sampled_supremum():
    """A custom profile spikier than the sampling grid invalidates the
    thinning bound — rejected loudly; an explicit profile_sup fixes it."""
    spike = lambda h: 10.0 if 12.04 < h % 24 < 12.06 else 1.0
    bad = DiurnalArrivals(5000.0, seed=0, profile=spike)
    with pytest.raises(ValueError, match="profile_sup"):
        bad.times(12.0, 0.1)
    ok = DiurnalArrivals(5000.0, seed=0, profile=spike, profile_sup=10.0)
    assert ok.times(12.0, 0.1).size > 0


# ---------------------------------------------------------------------------
# Driver: parity, billing, queueing
# ---------------------------------------------------------------------------


def test_driver_static_parity_with_engine_run():
    """Constant-rate arrivals + StaticProvider through the driver must
    reproduce the paper-scenario engine numbers exactly (Table II/IV/V are
    a special case of the simulator)."""
    ref = CarbonEdgeEngine(fresh_cluster(), mode="green")
    ref_rep = ref.run(task=TASK, iterations=50)

    engine = CarbonEdgeEngine(fresh_cluster(), mode="green")
    m = make_driver(engine, ConstantRateArrivals(50.0),
                    horizon_hours=1.0, max_batch=16).run()
    sim_rep = engine.report()
    assert m.summary()["tasks"] == 50
    assert sim_rep["distribution"] == ref_rep["distribution"]
    assert sim_rep["totals"]["carbon_g_per_inf"] == \
        pytest.approx(ref_rep["totals"]["carbon_g_per_inf"], abs=1e-15)


def test_driver_advances_now_hour_into_billing():
    """Arrivals spread over the duck curve must bill each batch at its own
    hour: cluster and monitor ledgers agree, and the total differs from a
    frozen-hour drain of the same workload."""
    engine = trace_engine()
    m = make_driver(engine, ConstantRateArrivals(8.0),
                    start_hour=10.0, horizon_hours=8.0, max_batch=4).run()
    cluster_total = sum(r.carbon_g for r in engine.cluster.log)
    assert engine.monitor.total_carbon_g() == pytest.approx(cluster_total)
    assert sum(r.carbon_g for r in m.records) == pytest.approx(cluster_total)

    frozen = trace_engine()
    with pytest.warns(DeprecationWarning):
        frozen_rep = frozen.run(tasks=[TASK] * 64, now_hour=10.0)
    frozen_total = sum(r.carbon_g for r in frozen.cluster.log)
    assert cluster_total != pytest.approx(frozen_total, rel=1e-3)
    assert frozen_rep["totals"]["tasks"] == 64


def test_driver_queueing_delay_emerges_under_load():
    """Near-saturation arrivals must queue: p95 wait well above the
    light-load p95, SLO violations appearing."""
    def waits(rate):
        engine = CarbonEdgeEngine(fresh_cluster(), mode="green")
        m = make_driver(engine, PoissonArrivals(rate, seed=11),
                        horizon_hours=0.05, max_batch=16,
                        slo_latency_s=2.0).run()
        return m.summary()
    light, heavy = waits(500.0), waits(12000.0)
    assert heavy["wait_s_p95"] > light["wait_s_p95"]
    assert heavy["wait_s_p95"] > 0.5
    assert heavy["slo_violation_rate"] > light["slo_violation_rate"]
    # wait histogram counts every task exactly once
    assert sum(heavy["wait_histogram"]) == heavy["tasks"]


def test_driver_seed_determinism_byte_identical():
    """Satellite: two runs with the same seed produce byte-identical
    metric reports (arrivals, event ordering, billing all deterministic)."""
    def report():
        engine = trace_engine()
        m = make_driver(engine, MMPPArrivals(20.0, 200.0, 0.25, seed=9),
                        start_hour=17.0, horizon_hours=2.0, max_batch=8,
                        slo_latency_s=1.0, tick_hours=0.5).run()
        return m.to_text()
    a, b = report(), report()
    assert a.encode() == b.encode()
    assert "tick hour=" in a and "task uid=" in a


def test_driver_intensity_ticks_sample_timeline():
    engine = trace_engine()
    m = make_driver(engine, ConstantRateArrivals(4.0),
                    start_hour=10.0, horizon_hours=4.0, tick_hours=1.0).run()
    assert len(m.timeline) == 4
    hours = [t.hour for t in m.timeline]
    assert hours == [11.0, 12.0, 13.0, 14.0]
    # duck curve: fleet-mean intensity dips toward 13:00
    assert m.timeline[2].mean_intensity < m.timeline[0].mean_intensity
    assert m.timeline[-1].carbon_g_cum == pytest.approx(
        engine.monitor.total_carbon_g(), rel=1e-6)


# ---------------------------------------------------------------------------
# Forecast-driven deferral through the driver
# ---------------------------------------------------------------------------


def deferral_run(forecast, deadline=24.0):
    engine = trace_engine()
    factory = lambda uid, hour: DeferrableTask(
        cpu=0.05, mem_mb=16.0, base_latency_ms=250.0,
        deadline_hours=deadline, duration_hours=0.25)
    m = make_driver(engine, PoissonArrivals(30.0, seed=5), factory=factory,
                    start_hour=17.0, horizon_hours=2.0, max_batch=16,
                    forecast=forecast).run()
    return m


def test_deferral_accurate_forecast_beats_run_now():
    run_now = deferral_run(None)
    deferred = deferral_run(ForecastProvider(TraceProvider(duck_traces())))
    assert run_now.deferred_tasks == 0
    assert deferred.deferred_tasks == deferred.summary()["tasks"]
    assert deferred.summary()["carbon_g_total"] < \
        0.7 * run_now.summary()["carbon_g_total"]
    # deferral trades latency for carbon: waits include the parked time
    assert deferred.summary()["wait_s_p50"] > run_now.summary()["wait_s_p50"]


def test_deferral_forecast_error_degrades_monotonically():
    base = TraceProvider(duck_traces())
    totals = [deferral_run(ForecastProvider(base, lead_hours=b)
                           ).summary()["carbon_g_total"]
              for b in (0.0, 1.0, 2.0, 4.0)]
    assert all(a < b + 1e-12 for a, b in zip(totals, totals[1:])), totals


def test_deferred_tasks_respect_deadline():
    """A 6 h deadline from 17:00 cannot reach the next-day solar dip, so
    wakes stay within the window; early arrivals (for whom 17:00 is
    already the window minimum) legitimately run immediately."""
    m = deferral_run(ForecastProvider(TraceProvider(duck_traces())),
                     deadline=6.0)
    assert m.deferred_tasks > 0
    for r in m.records:
        assert r.start_hour - r.submit_hour <= 6.0 + 1e-9
        assert r.deferred_hours <= 6.0 - 0.25 + 0.5   # deadline - duration (+slot)


def test_plan_wake_edge_cases():
    c = fresh_cluster()
    provider = TraceProvider(duck_traces())
    urgent = Task(cpu=0.05, mem_mb=16.0)
    assert plan_wake(provider, c, urgent, 17.0) == 17.0   # no slack
    t = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=24.0,
                       duration_hours=0.25)
    wake = plan_wake(provider, c, t, 17.0)
    assert 17.0 < wake <= 41.0
    # next-day solar dip is the global minimum within the window
    assert wake == pytest.approx(24.0 + 13.0, abs=1.0)
    # all nodes infeasible -> wake immediately
    for st in c.nodes.values():
        st.load = 0.95
    assert plan_wake(provider, c, t, 17.0) == 17.0


def test_plan_wake_window_matches_sampled():
    """A ForecastProvider (window path) and its base provider (per-slot
    sampling path) must plan the same wake slot when the forecast is
    exact."""
    c = fresh_cluster()
    base = TraceProvider(duck_traces())
    t = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=12.0,
                       duration_hours=0.5)
    assert plan_wake(ForecastProvider(base), c, t, 19.0) == \
        plan_wake(base, c, t, 19.0)


# ---------------------------------------------------------------------------
# Batched plan_wake vs the scalar oracle (ISSUE 3 satellite)
# ---------------------------------------------------------------------------


def _random_wake_cluster(rng, n):
    from tests.test_policy_parity import random_cluster

    c = random_cluster(rng, n)
    for st in c.nodes.values():             # keep a good share feasible
        st.load = float(rng.uniform(0.0, 0.9))
    return c


@pytest.mark.parametrize("seed", range(8))
def test_plan_wake_batched_matches_scalar_randomized(seed):
    """plan_wake (batched (S, N) grid) == plan_wake_scalar (nodes x slots
    Python loop) on randomized fleets, providers and deadlines — exact
    equality, ties included."""
    from repro.core.temporal import plan_wake_scalar

    rng = np.random.default_rng(seed)
    c = _random_wake_cluster(rng, int(rng.integers(2, 16)))
    names = list(c.nodes)
    traces = {n: synthetic_trace(n, float(rng.uniform(100.0, 900.0)),
                                 seed=int(rng.integers(0, 100)))
              for n in names[:int(rng.integers(1, len(names) + 1))]}
    provider = TraceProvider(traces, fallback=StaticProvider.from_cluster(c))
    if seed % 3 == 1:
        provider = ForecastProvider(provider, lead_hours=0.5,
                                    smoothing_hours=1.0)
    elif seed % 3 == 2:
        provider = StaticProvider.from_cluster(c)   # constant: full tie
    t = DeferrableTask(cpu=float(rng.uniform(0.01, 0.5)),
                       mem_mb=float(rng.uniform(4.0, 64.0)),
                       deadline_hours=float(rng.uniform(0.0, 30.0)),
                       duration_hours=float(rng.uniform(0.0, 2.0)))
    now = float(rng.uniform(0.0, 24.0))
    assert plan_wake(provider, c, t, now) == \
        plan_wake_scalar(provider, c, t, now)


def test_plan_wake_tie_breaks_earliest_slot_first_node():
    """Exact ties: a constant signal must wake immediately (earliest
    slot), and when two nodes share the minimum the first (insertion
    order) node's earliest minimum slot must win."""
    from repro.core.temporal import IntensityTrace, plan_wake_scalar

    c = fresh_cluster()
    t = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=6.0,
                       duration_hours=0.5)
    # constant everywhere -> every (slot, node) ties -> run now
    const = StaticProvider.from_cluster(c)
    assert plan_wake(const, c, t, 3.0) == 3.0
    # node-high (first) has its min at slot 4, node-medium the same min
    # value at slot 2: the scalar oracle keeps the FIRST node's slot.
    vals_high = [500.0] * 24
    vals_high[5] = 100.0                      # 3.0 + 4*0.5 = hour 5
    vals_med = [500.0] * 24
    vals_med[4] = 100.0                       # 3.0 + 2*0.5 = hour 4
    provider = TraceProvider({
        "node-high": IntensityTrace("a", tuple(vals_high)),
        "node-medium": IntensityTrace("b", tuple(vals_med)),
        "node-green": IntensityTrace("c", (500.0,) * 24),
    })
    want = plan_wake_scalar(provider, c, t, 3.0)
    assert want == 5.0                        # first node's earliest min
    assert plan_wake(provider, c, t, 3.0) == want


def test_plan_wake_duck_typed_cluster():
    """A cluster-like with .nodes but no feature_cache plumbing (custom
    executors) must still plan — via the scalar feasibility fallback."""
    from repro.core.temporal import plan_wake_scalar

    real = fresh_cluster()

    class DuckCluster:
        nodes = real.nodes

    provider = TraceProvider(duck_traces())
    t = DeferrableTask(cpu=0.05, mem_mb=16.0, deadline_hours=24.0,
                       duration_hours=0.25)
    assert plan_wake(provider, DuckCluster(), t, 17.0) == \
        plan_wake_scalar(provider, real, t, 17.0)


def test_fallback_provider_batch_splits_by_coverage():
    """FallbackProvider with a partial-coverage primary resolves the batch
    with covers()-split batched calls — values must equal the scalar path."""
    from repro.core.api import FallbackProvider, intensity_batch

    c = fresh_cluster()
    primary = TraceProvider({"node-green": duck_traces()["node-green"]})
    provider = FallbackProvider(primary, StaticProvider.from_cluster(c))
    names = list(c.nodes)
    hours = np.array([0.0, 6.5, 13.0])
    grid = intensity_batch(provider, names, hours)
    for s, hr in enumerate(hours):
        for j, n in enumerate(names):
            assert grid[s, j] == provider.intensity(n, float(hr)), (n, hr)


def test_plan_wake_batch_matches_per_task():
    from repro.core.temporal import plan_wake_batch

    rng = np.random.default_rng(3)
    c = _random_wake_cluster(rng, 6)
    provider = TraceProvider(
        {n: synthetic_trace(n, 400.0 + 50.0 * i, seed=i)
         for i, n in enumerate(c.nodes)})
    tasks = [DeferrableTask(cpu=float(rng.uniform(0.01, 0.4)),
                            mem_mb=8.0,
                            deadline_hours=float(rng.uniform(0.0, 20.0)),
                            duration_hours=0.25)
             for _ in range(7)]
    batch = plan_wake_batch(provider, c, tasks, 17.0)
    singles = [plan_wake(provider, c, t, 17.0) for t in tasks]
    np.testing.assert_array_equal(batch, singles)


def test_sim_determinism_byte_identical_with_batched_plan_wake(monkeypatch):
    """The batched planner must preserve the sim determinism contract:
    to_text() byte-identical to a run forced through the scalar oracle."""
    import repro.core.temporal as temporal_mod

    text_batched = deferral_run(
        ForecastProvider(TraceProvider(duck_traces()))).to_text()
    monkeypatch.setattr(temporal_mod, "plan_wake",
                        temporal_mod.plan_wake_scalar)
    text_scalar = deferral_run(
        ForecastProvider(TraceProvider(duck_traces()))).to_text()
    assert text_batched == text_scalar


# ---------------------------------------------------------------------------
# Engine run_until / peek / partial drain
# ---------------------------------------------------------------------------


def test_engine_peek_and_partial_drain():
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green")
    eng.submit_many([TASK] * 5)
    assert eng.peek(2) == [TASK, TASK]
    assert len(eng.queue) == 5                    # peek does not dequeue
    assert len(eng.step(limit=2)) == 2
    assert len(eng.queue) == 3


def test_engine_run_until_advances_billing_hour():
    """run_until bills successive batches at advancing hours; on a
    time-varying provider that differs from the frozen-hour run."""
    a = trace_engine()
    a.submit_many([TASK] * 400)
    rep = a.run_until(end_hour=24.0, start_hour=12.5, limit=50)
    assert rep["totals"]["tasks"] == 400
    assert rep["end_hour"] > 12.5
    assert a.monitor.total_carbon_g() == pytest.approx(
        sum(r.carbon_g for r in a.cluster.log))

    b = trace_engine()
    with pytest.warns(DeprecationWarning, match="frozen"):
        b.run(tasks=[TASK] * 400, now_hour=12.5)
    assert sum(r.carbon_g for r in a.cluster.log) != \
        pytest.approx(sum(r.carbon_g for r in b.cluster.log), rel=1e-6)


def test_engine_run_until_stops_at_end_hour():
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green")
    eng.submit_many([TASK] * 10)
    rep = eng.run_until(end_hour=0.0, start_hour=0.0)
    assert rep["totals"] == {"tasks": 0} and len(eng.queue) == 10


def test_engine_run_until_no_progress_terminates():
    """Regression: a step that drains nothing (limit=0) must bail instead
    of spinning forever."""
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green")
    eng.submit_many([TASK] * 3)
    rep = eng.run_until(end_hour=10.0, limit=0)
    assert rep["totals"] == {"tasks": 0} and len(eng.queue) == 3


def test_engine_run_static_provider_does_not_warn():
    import warnings
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.run(task=TASK, iterations=3)


# ---------------------------------------------------------------------------
# ServingEngine as the driver's executor (wait/service split)
# ---------------------------------------------------------------------------


def serving_engine():
    import jax
    import numpy as np

    from repro.configs.registry import reduced_config
    from repro.core import costmodel, energy
    from repro.core.router import GreenRouter, PodSpec
    from repro.models import transformer
    from repro.runtime.serving import ServingEngine

    pods = [PodSpec("pod-high", 256, "coal-heavy", 620.0),
            PodSpec("pod-green", 256, "hydro-rich", 380.0)]
    cfg = reduced_config("qwen3-1.7b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    router = GreenRouter(pods, mode="green")
    terms = energy.roofline(2.0 * cfg.active_param_count() * 2,
                            costmodel.step_hbm_bytes(cfg, 16, 2, "decode"),
                            0.0, 256)
    router.seed_profile({p.name: terms for p in pods})
    return cfg, ServingEngine(cfg, params, router, max_len=32, batch_size=4)


def test_serving_completion_splits_wait_and_service():
    """Satellite: queue wait (submit -> batch start) and per-request
    service (until *its own* last token) are reported separately; latency
    is their sum, and a short request no longer inherits the batch dt."""
    from repro.runtime.serving import Request

    cfg, eng = serving_engine()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(2)]
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=2),
               now_s=10.0)
    eng.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=8),
               now_s=25.0)
    eng.submit(Request(uid=2, prompt=prompts[0], max_new_tokens=0),
               now_s=25.0)
    short, long, zero = eng.run_batch(now_hour=0.0, now_s=40.0)
    assert short.wait_s == pytest.approx(30.0)
    assert long.wait_s == pytest.approx(15.0)
    assert 0.0 < short.service_s < long.service_s   # own last token, not batch dt
    assert short.latency_s == pytest.approx(short.wait_s + short.service_s)
    assert len(short.tokens) == 2 and len(long.tokens) == 8
    # a zero-token request's service ends at prefill, before any decode
    assert zero.tokens == [] and 0.0 < zero.service_s <= short.service_s


def test_serving_submit_preserves_virtual_time_zero():
    """Regression: a pre-stamped virtual submission time of exactly 0.0
    (an arrival at simulated hour 0) must not be clobbered by the wall
    clock."""
    from repro.runtime.serving import Request

    r = Request(uid=0, prompt=np.zeros(4, np.int32), submitted_s=0.0)
    cfg, eng = serving_engine()
    eng.submit(r)
    assert r.submitted_s == 0.0
    r2 = Request(uid=1, prompt=np.zeros(4, np.int32))
    eng.submit(r2)
    assert r2.submitted_s is not None and r2.submitted_s > 0.0  # wall stamp


def test_serving_engine_drives_through_sim():
    """ServingEngine satisfies the BatchExecutor protocol: the driver
    interleaves virtual-time arrivals with real prefill/decode batches."""
    from repro.runtime.serving import Request

    cfg, eng = serving_engine()
    rng = np.random.default_rng(1)

    def factory(uid, hour):
        # deliberately NOT pre-stamping submitted_s: the driver must stamp
        # virtual time so Completion.wait_s stays on the sim clock
        return Request(uid=uid,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           size=6).astype(np.int32),
                       max_new_tokens=2)

    from repro.sim import TraceReplayArrivals
    m = AsyncEngineDriver(eng, TraceReplayArrivals([0.1, 0.1, 0.2]), factory,
                          start_hour=0.0, horizon_hours=1.0,
                          max_batch=2).run()
    assert m.summary()["tasks"] == 3
    assert {r.node for r in m.records} == {"pod-green"}
    assert all(r.carbon_g > 0 for r in m.records)
    # per-task energy backfilled from the router monitor's step delta, so
    # carbon > 0 never pairs with the impossible energy == 0
    assert all(r.energy_kwh > 0 for r in m.records)
    assert m.summary()["energy_kwh_total"] == pytest.approx(
        eng.router.monitor.total_energy_kwh())
    assert eng.report()["completed"] == 3
    # virtual-time waits, not wall/virtual clock mixing: requests batched
    # at their arrival instant waited ~0 virtual seconds
    assert all(c.wait_s < 60.0 for c in eng.completions)
