"""Multi-tenant carbon budgets (paper §V future work)."""
from repro.core.budget import BudgetedRouter
from repro.core.energy import RooflineTerms
from repro.core.router import GreenRouter, PodSpec

PODS = [
    PodSpec("pod-high", 256, "coal", 620.0),
    PodSpec("pod-green", 256, "hydro", 380.0),
]
TERMS = RooflineTerms(0.010, 0.004, 0.002)   # 10 ms compute-bound step


def make(alloc_a=1.0, alloc_b=1.0):
    router = GreenRouter(PODS, mode="performance")
    router.seed_profile({p.name: TERMS for p in PODS})
    br = BudgetedRouter(router)
    br.register_tenant("a", alloc_a)
    br.register_tenant("b", alloc_b)
    return br


def test_admission_and_charging():
    br = make(alloc_a=10.0)
    res = br.admit("a", TERMS)
    assert res.admitted and res.pod is not None
    c = br.commit("a", res.pod, TERMS)
    assert c > 0
    assert abs(br.tenants["a"].spent_g - c) < 1e-12


def test_budget_exhaustion_denies():
    # one step emits ~ 256 chips * 230 W * 0.01 s -> ~1.6e-4 kWh * I
    br = make(alloc_a=1e-5)
    res1 = br.admit("a", TERMS)
    assert not res1.admitted
    assert br.tenants["a"].denied == 1


def test_escalation_to_green():
    br = make(alloc_a=10.0)
    # drain past 80% (remaining still covers a green step, ~0.06 g)
    br.tenants["a"].spent_g = 8.5
    res = br.admit("a", TERMS)
    assert res.admitted
    assert res.mode == "green"
    assert res.pod == "pod-green"


def test_escalation_to_balanced():
    br = make(alloc_a=10.0)
    br.tenants["a"].spent_g = 6.5   # 65% utilisation
    res = br.admit("a", TERMS)
    assert res.mode == "balanced"


def test_low_utilisation_keeps_performance_mode():
    br = make(alloc_a=100.0)
    res = br.admit("a", TERMS)
    assert res.mode == "performance"


def test_tenants_isolated():
    br = make(alloc_a=1e-5, alloc_b=10.0)
    r_a = br.admit("a", TERMS)
    r_b = br.admit("b", TERMS)
    assert not r_a.admitted and r_b.admitted
    br.commit("b", r_b.pod, TERMS)
    assert br.tenants["a"].spent_g == 0.0
    assert br.tenants["b"].spent_g > 0.0


def test_near_exhaustion_falls_back_to_greenest():
    """If the routed pod exceeds the remainder but the greenest pod fits,
    admit there instead of denying."""
    br = make(alloc_a=1.0)
    from repro.core import energy

    exp_high = energy.carbon_g(energy.step_energy_kwh(TERMS, 256, 200.0), 620.0)
    exp_green = energy.carbon_g(energy.step_energy_kwh(TERMS, 256, 200.0), 380.0)
    br.tenants["a"].spent_g = 1.0 - (exp_high + exp_green) / 2
    res = br.admit("a", TERMS)
    assert res.admitted
    assert res.pod == "pod-green"


def test_report():
    br = make()
    res = br.admit("a", TERMS)
    br.commit("a", res.pod, TERMS)
    rep = br.report()
    assert rep["a"]["admitted"] == 1
    assert rep["a"]["spent_g"] > 0
    assert 0 <= rep["a"]["utilisation"] <= 1.0
