import os

# Keep tests single-device (the dry-run sets its own 512-device flag in a
# subprocess; see test_dryrun_small.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
