"""Observability (repro.obs, DESIGN.md §9).

The load-bearing contract: obs disabled leaves every existing output
byte-identical (sim ``to_text`` across both execute paths), obs enabled
never perturbs a decision, and a fixed-seed run exports a byte-identical
JSONL trace.
"""
import json
import logging

import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, ForecastProvider,
                            StaticProvider, TraceProvider)
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.policy import VectorizedPolicy
from repro.core.scheduler import MODES, Task
from repro.core.temporal import DeferrableTask, synthetic_trace
from repro.obs import (MODE_LABELS, VERDICT_LABELS, DecisionTrace,
                       MetricsRegistry, Observability, StepProfiler,
                       console_logger)
from repro.partition import PartitionPolicy, profile_costs
from repro.sim import AsyncEngineDriver, PoissonArrivals
from repro.tenancy import (MODE_ORDER, TenantPolicy, TenantRegistry,
                           TenantSpec, TenantTask)

TASK = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)


def fresh_cluster():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(250.0)
    return c


def submit_n(eng, n, seed=7):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(Task(cpu=float(rng.uniform(0.01, 0.2)),
                        mem_mb=float(rng.uniform(8, 64)),
                        base_latency_ms=float(rng.uniform(100, 800))))


# ---------------------------------------------------------------------------
# DecisionTrace
# ---------------------------------------------------------------------------


def test_trace_record_and_row_roundtrip():
    t = DecisionTrace(capacity=8)
    ids = t.intern_names(["b", "a", "b"])
    t.record_batch(step=1, hour=2.5, verdict=np.zeros(3, np.int8),
                   node=ids, score=[0.9, 0.8, 0.7], carbon_g=0.25)
    assert len(t) == 3 and t.count == 3
    r = t.row(0)
    assert r["step"] == 1 and r["task"] == 0 and r["hour"] == 2.5
    assert r["verdict"] == "done" and r["node"] == "b"
    assert r["score"] == 0.9 and r["carbon_g"] == 0.25
    # absent columns render as None, not stale fills
    assert r["cut"] is None and r["tenant"] is None and r["intensity"] is None


def test_trace_ring_wraparound_keeps_newest_oldest_first():
    t = DecisionTrace(capacity=5)
    for s in range(4):                       # 4 steps x 2 rows = 8 > 5
        t.record_batch(step=s, hour=0.0, verdict=np.zeros(2, np.int8),
                       score=[s + 0.1, s + 0.2])
    assert t.count == 8 and len(t) == 5
    got = [(r["step"], r["task"]) for r in t.rows()]
    assert got == [(1, 1), (2, 0), (2, 1), (3, 0), (3, 1)]
    scores = [r["score"] for r in t.rows()]
    assert scores == sorted(scores)          # oldest-first ordering


def test_trace_oversize_batch_clips_to_tail():
    t = DecisionTrace(capacity=4)
    t.record_batch(step=0, hour=0.0, verdict=np.zeros(10, np.int8),
                   score=np.arange(10.0))
    assert t.count == 10 and len(t) == 4
    assert [r["task"] for r in t.rows()] == [6, 7, 8, 9]
    assert [r["score"] for r in t.rows()] == [6.0, 7.0, 8.0, 9.0]


def test_trace_jsonl_sorted_keys_and_null_for_nan():
    t = DecisionTrace(capacity=4)
    t.record_batch(step=0, hour=0.0, verdict=np.zeros(1, np.int8))
    text = t.to_jsonl()
    assert text.endswith("\n") and "NaN" not in text
    row = json.loads(text.splitlines()[0])
    assert list(row) == sorted(row)
    assert row["score"] is None and row["node"] is None


def test_trace_explain_names_node_and_margin():
    t = DecisionTrace(capacity=4)
    ids = t.intern_names(["node-green"])
    t.record_batch(step=3, hour=0.0, verdict=np.zeros(1, np.int8),
                   node=ids, cut=2, mode=2, score=0.9, runner_up=0.7,
                   intensity=380.0, carbon_g=0.01)
    line = t.explain(3, 0)
    assert "'node-green'" in line and "cut 2" in line
    assert "green mode" in line and "margin 0.2" in line
    assert t.explain(99, 0) is None


def test_trace_verdict_counts_and_conformal_coverage():
    t = DecisionTrace(capacity=8)
    t.record_batch(step=0, hour=0.0, verdict=np.array([0, 1, 2, 0], np.int8),
                   intensity=[400.0, 400.0, 400.0, 500.0],
                   interval_lo=[390.0, np.nan, 390.0, 490.0],
                   interval_hi=[410.0, np.nan, 410.0, 495.0])
    assert t.verdict_counts() == {"done": 2, "reject": 1, "defer": 1}
    cov = t.conformal_coverage()
    # 3 non-degenerate intervals, the 500-in-[490,495] row misses
    assert cov["rows"] == 3 and cov["coverage"] == pytest.approx(2 / 3)


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_and_grow():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "tasks", labels=("node",))
    for i in range(20):                      # force several _grow doublings
        c.inc(1.0, (f"n{i:02d}",))
    c.inc(2.5, ("n00",))
    assert c.get(("n00",)) == 3.5 and len(c) == 20
    g = reg.gauge("depth", "queue depth")
    g.set(7.0)
    assert g.get() == 7.0


def test_registry_inc_at_matches_scalar_loop_on_duplicates():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "", labels=("k",))
    rows = c.rows([("a",), ("b",)])
    idx = np.array([rows[0], rows[1], rows[0], rows[0]])
    vals = np.array([1.0, 2.0, 3.0, 4.0])
    c.inc_at(idx, vals)
    assert c.get(("a",)) == 8.0 and c.get(("b",)) == 2.0


def test_registry_histogram_buckets_cumulative_render():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s", "latency", edges=[0.1, 1.0, 10.0])
    h.observe([0.05, 0.5, 0.5, 5.0, 50.0])
    text = reg.to_text()
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="1"} 3' in text
    assert 'lat_s_bucket{le="10"} 4' in text
    assert 'lat_s_bucket{le="+Inf"} 5' in text
    assert "lat_s_count 5" in text
    assert "# TYPE lat_s histogram" in text


def test_registry_rejects_kind_and_label_mismatch():
    reg = MetricsRegistry()
    reg.counter("a_total", "", labels=("x",))
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("a_total", "", labels=("x",))
    with pytest.raises(ValueError, match="re-registered"):
        reg.counter("a_total", "", labels=("y",))
    with pytest.raises(ValueError, match="expected labels"):
        reg.get("a_total").inc(1.0, ())


def test_registry_exposition_is_deterministic():
    def build():
        reg = MetricsRegistry()
        c = reg.counter("n_total", "help text", labels=("node",))
        for name in ("zeta", "alpha", "mid"):
            c.inc(1.5, (name,))
        return reg.to_text()

    assert build() == build()
    lines = build().splitlines()
    assert lines[0] == "# HELP n_total help text"
    # series sorted by label tuple regardless of intern order
    assert [l for l in lines if l.startswith("n_total{")] == [
        'n_total{node="alpha"} 1.5', 'n_total{node="mid"} 1.5',
        'n_total{node="zeta"} 1.5']


# ---------------------------------------------------------------------------
# StepProfiler
# ---------------------------------------------------------------------------


def test_profiler_add_span_and_percentiles():
    p = StepProfiler()
    for dt in (1e-5, 1e-4, 1e-4, 1e-3):
        p.add("score", dt)
    with p.span("score"):
        pass
    assert p.count("score") == 5
    assert p.total_s("score") >= 1e-5 + 2e-4 + 1e-3
    assert p.percentile_s("score", 50) <= p.percentile_s("score", 95)
    s = p.summary()["phases"]["score"]
    assert s["count"] == 5 and s["min_s"] <= 1e-5 and s["max_s"] >= 1e-3
    p.reset()
    assert p.phases() == []


def test_profiler_bins_handle_out_of_range_durations():
    p = StepProfiler()
    p.add("x", 1e-12)                        # below the first edge
    p.add("x", 1e6)                          # beyond the last edge
    s = p.summary()["phases"]["x"]
    assert s["count"] == 2 and sum(s["hist"]) == 2
    assert p.percentile_s("x", 99) == pytest.approx(1e6)


# ---------------------------------------------------------------------------
# encoding consistency (kept duplicated to avoid import cycles)
# ---------------------------------------------------------------------------


def test_mode_labels_match_tenancy_mode_order():
    assert MODE_LABELS == MODE_ORDER
    assert set(MODE_LABELS) == set(MODES)


def test_verdict_labels_are_the_trace_contract():
    from repro.obs import VERDICT_DEFER, VERDICT_DONE, VERDICT_REJECT
    assert VERDICT_LABELS[VERDICT_DONE] == "done"
    assert VERDICT_LABELS[VERDICT_REJECT] == "reject"
    assert VERDICT_LABELS[VERDICT_DEFER] == "defer"


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def untenanted_engine(obs=None, batch_execute=True):
    c = fresh_cluster()
    return CarbonEdgeEngine(c, mode="green", batch_execute=batch_execute,
                            obs=obs)


@pytest.mark.parametrize("batch_execute", [True, False])
def test_engine_obs_never_perturbs_decisions(batch_execute):
    base = untenanted_engine(batch_execute=batch_execute)
    submit_n(base, 40)
    ra = base.step(now_hour=3.0)

    obs = Observability.all()
    eng = untenanted_engine(obs=obs, batch_execute=batch_execute)
    submit_n(eng, 40)
    rb = eng.step(now_hour=3.0)

    assert [r.node for r in ra] == [r.node for r in rb]
    assert [r.carbon_g for r in ra] == [r.carbon_g for r in rb]
    # trace mirrors the executed batch exactly
    rows = list(obs.trace.rows())
    assert len(rows) == len(rb)
    for row, res in zip(rows, rb):
        assert row["node"] == res.node and row["verdict"] == "done"
        assert row["carbon_g"] == pytest.approx(res.carbon_g, rel=1e-12)


def test_engine_trace_scores_winner_beats_runner_up():
    obs = Observability.all()
    eng = untenanted_engine(obs=obs)
    submit_n(eng, 30)
    eng.step(now_hour=0.0)
    rows = list(obs.trace.rows())
    assert all(r["score"] is not None for r in rows)
    assert all(r["score"] >= r["runner_up"] for r in rows)
    assert all(r["intensity"] is not None and r["intensity_billed"] is not None
               for r in rows)


def test_engine_capture_off_leaves_policy_untouched():
    pol = VectorizedPolicy()
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green", policy=pol)
    submit_n(eng, 10)
    eng.step(now_hour=0.0)
    assert pol.capture_scores is False and pol.last_scores is None
    assert pol.profiler is None


def test_engine_report_surfaces_outcomes_and_depths():
    obs = Observability.all()
    eng = untenanted_engine(obs=obs)
    submit_n(eng, 25)
    eng.step(now_hour=0.0, limit=10)
    eng.step(now_hour=0.0, limit=10)
    rep = eng.report()
    assert rep["steps"] == 2
    assert rep["outcomes"] == {"done": 20, "reject": 0, "defer": 0}
    assert rep["deferred_depth"] == 0
    deep = eng.report(deep=True)["deep"]
    assert deep["trace"]["recorded"] == 20
    assert deep["deferral"]["parked"] == 0
    prof = deep["profiler"]["phases"]
    for phase in ("select", "execute", "bill", "observe"):
        assert prof[phase]["count"] == 2, phase
    assert "engine_tasks_total" in deep["metrics"]


def test_engine_report_outcomes_without_obs():
    eng = untenanted_engine()
    submit_n(eng, 8)
    eng.step(now_hour=0.0)
    rep = eng.report()
    assert rep["steps"] == 1 and rep["outcomes"]["done"] == 8
    assert "deep" not in rep


def test_engine_conformal_interval_recorded_and_covered():
    c = fresh_cluster()

    class Margin:
        def quantile(self, coverage):
            return 25.0

    prov = ForecastProvider(StaticProvider.from_cluster(c), conformal=Margin())
    obs = Observability(trace=True)
    eng = CarbonEdgeEngine(c, mode="green", provider=prov, obs=obs)
    submit_n(eng, 12)
    eng.step(now_hour=0.0)
    rows = list(obs.trace.rows())
    assert all(r["interval_hi"] - r["interval_lo"] == pytest.approx(50.0)
               for r in rows)
    cov = obs.trace.conformal_coverage()
    assert cov["rows"] == 12 and cov["coverage"] == 1.0


def test_engine_partition_trace_records_cuts():
    prof = profile_costs([10.0, 10.0, 10.0, 10.0],
                         boundary_bytes=[1e4, 1e4, 1e4, 0.0])
    obs = Observability.all()
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green",
                           policy=PartitionPolicy(prof, backend="numpy"),
                           obs=obs)
    submit_n(eng, 20)
    eng.step(now_hour=0.0)
    rows = list(obs.trace.rows())
    assert all(r["cut"] is not None for r in rows)
    hist = obs.trace.cut_histogram()
    assert sum(hist.values()) == 20
    deep = eng.report(deep=True)["deep"]
    assert deep["partition"]["cut_histogram"] == hist
    assert deep["partition"]["last_batch_cuts"] == hist


def test_engine_partition_obs_parity():
    prof = profile_costs([10.0, 10.0, 10.0, 10.0],
                         boundary_bytes=[1e4, 1e4, 1e4, 0.0])

    def run(obs):
        eng = CarbonEdgeEngine(fresh_cluster(), mode="green",
                               policy=PartitionPolicy(prof, backend="numpy"),
                               obs=obs)
        submit_n(eng, 20)
        res = eng.step(now_hour=0.0)
        return ([r.node for r in res],
                [d.cut_index for d in eng.policy.last_decisions])

    assert run(None) == run(Observability.all())


def tenant_specs():
    return [TenantSpec("acme", allowance_g=1e-5, period_hours=1.0,
                       defer_over_reject=False),
            TenantSpec("zen", allowance_g=1e6, period_hours=1.0)]


def test_engine_tenancy_trace_verdicts_match_outcomes():
    obs = Observability.all()
    reg = TenantRegistry(tenant_specs())
    eng = CarbonEdgeEngine(fresh_cluster(), mode="green",
                           policy=TenantPolicy(registry=reg), obs=obs)
    for i in range(8):
        eng.submit(TenantTask(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0,
                              tenant=("acme" if i % 2 == 0 else "zen")))
    eng.step(now_hour=0.0)
    rows = list(obs.trace.rows())
    assert len(rows) == 8
    outcome_kinds = [k for k, _ in eng.last_outcomes]
    assert [r["verdict"] for r in rows] == outcome_kinds
    # tenants resolve by name; admitted rows carry node + score
    assert {r["tenant"] for r in rows} == {"acme", "zen"}
    done = [r for r in rows if r["verdict"] == "done"]
    assert done and all(r["node"] is not None and r["score"] is not None
                        for r in done)
    rejected = [r for r in rows if r["verdict"] == "reject"]
    assert rejected and all(r["node"] is None for r in rejected)
    assert all(r["expected_g"] is not None for r in rows)
    # outcome totals line up with the verdict counters
    rep = eng.report()
    assert rep["outcomes"]["done"] == len(done)
    assert rep["outcomes"]["reject"] == len(rejected)
    fam = obs.metrics.get("engine_outcomes_total")
    assert fam.get(("done",)) == len(done)
    assert fam.get(("reject",)) == len(rejected)


@pytest.mark.parametrize("batch_execute", [True, False])
def test_engine_tenancy_obs_parity(batch_execute):
    def run(obs):
        reg = TenantRegistry(tenant_specs())
        eng = CarbonEdgeEngine(fresh_cluster(), mode="green",
                               policy=TenantPolicy(registry=reg),
                               batch_execute=batch_execute, obs=obs)
        for i in range(10):
            eng.submit(TenantTask(cpu=0.05, mem_mb=16.0,
                                  base_latency_ms=250.0,
                                  tenant=("acme" if i % 2 else "zen")))
        res = eng.step(now_hour=0.0)
        return [k for k, _ in eng.last_outcomes], [r.node for r in res]

    assert run(None) == run(Observability.all())


# ---------------------------------------------------------------------------
# policy score capture
# ---------------------------------------------------------------------------


def test_policy_capture_matches_full_featurize_argmax():
    from repro.core.policy import featurize

    c = fresh_cluster()
    pol = VectorizedPolicy(backend="numpy")
    pol.capture_scores = True
    rng = np.random.default_rng(3)
    tasks = [Task(cpu=float(rng.uniform(0.01, 0.2)),
                  mem_mb=float(rng.uniform(8, 64)),
                  base_latency_ms=float(rng.uniform(100, 800)))
             for _ in range(16)]
    prov = StaticProvider.from_cluster(c)
    choices = pol.select_batch(c, tasks, MODES["green"], provider=prov)
    ls = pol.last_scores
    assert len(ls["score"]) == 16
    for t, ch, s, r in zip(tasks, choices, ls["score"], ls["runner_up"]):
        F, names = featurize(c, [t], provider=prov)
        totals = pol.score_batch(F, MODES["green"])[0]
        best = int(np.argmax(totals))
        assert ch == names[best]
        assert s == pytest.approx(totals[best], rel=1e-12)
        rest = np.delete(totals, best)
        rest = rest[np.isfinite(rest)]
        if rest.size:
            assert r == pytest.approx(rest.max(), rel=1e-12)
    # memo-hit path returns identical captures
    again = pol.select_batch(c, tasks, MODES["green"], provider=prov)
    assert again == choices
    np.testing.assert_array_equal(pol.last_scores["score"], ls["score"])


# ---------------------------------------------------------------------------
# sim integration: the byte-identity contract
# ---------------------------------------------------------------------------


def duck_traces():
    return {
        "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
        "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
        "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
    }


def sim_run(obs=None, batch_execute=True, plain=False):
    """One fixed-seed sim; ``plain=True`` builds pre-obs-style objects
    (no obs kwarg anywhere) — the pre-PR golden path."""
    c = fresh_cluster()
    provider = TraceProvider(duck_traces(),
                             fallback=StaticProvider.from_cluster(c))
    ekw = {} if plain else {"obs": obs}
    eng = CarbonEdgeEngine(c, mode="green", provider=provider,
                           batch_execute=batch_execute, **ekw)
    fore = ForecastProvider(provider)

    def factory(uid, hour):
        if uid % 3 == 0:
            return DeferrableTask(cpu=0.05, mem_mb=16.0,
                                  base_latency_ms=250.0, deadline_hours=4.0)
        return TASK

    dkw = {} if plain else {"obs": obs}
    d = AsyncEngineDriver(eng, PoissonArrivals(rate_per_hour=240.0, seed=11),
                          factory, horizon_hours=1.0, max_batch=16,
                          forecast=fore, tick_hours=0.25,
                          slo_latency_s=2.0, **dkw)
    return d.run(), (None if plain else obs)


@pytest.mark.parametrize("batch_execute", [True, False])
def test_sim_to_text_byte_identical_across_obs_states(batch_execute):
    golden = sim_run(plain=True, batch_execute=batch_execute)[0].to_text()
    off = sim_run(obs=None, batch_execute=batch_execute)[0].to_text()
    disabled = sim_run(obs=Observability(),
                       batch_execute=batch_execute)[0].to_text()
    on = sim_run(obs=Observability.all(),
                 batch_execute=batch_execute)[0].to_text()
    assert off == golden
    assert disabled == golden
    assert on == golden


def test_sim_trace_jsonl_deterministic_across_runs():
    _, a = sim_run(obs=Observability.all())
    _, b = sim_run(obs=Observability.all())
    ja, jb = a.trace.to_jsonl(), b.trace.to_jsonl()
    assert ja and ja == jb


def test_sim_obs_counters_and_phases():
    m, obs = sim_run(obs=Observability.all())
    phases = set(obs.profiler.phases())
    assert {"sim_step", "sim_record", "sim_plan",
            "select", "execute", "bill", "observe"} <= phases
    ev = obs.metrics.get("sim_events_total")
    n_tasks = len(m.records)
    assert ev.get(("ARRIVAL",)) >= n_tasks
    # every profiled executor step came from a BATCH_READY event
    assert 0 < obs.profiler.count("sim_step") <= ev.get(("BATCH_READY",))
    # the exported summary gauge agrees with the collector
    assert obs.metrics.get("sim_summary").get(("tasks",)) == n_tasks
    done = obs.metrics.get("sim_tasks_total")
    total = sum(done.get((n,)) for n in ("node-high", "node-medium",
                                         "node-green"))
    assert total == n_tasks
    # trace saw exactly the completed tasks (untenanted: all done)
    assert obs.trace.verdict_counts()["done"] == n_tasks


# ---------------------------------------------------------------------------
# console logger
# ---------------------------------------------------------------------------


def test_console_logger_idempotent_and_bare_format():
    root = logging.getLogger("repro")
    before = [h for h in root.handlers
              if getattr(h, "_repro_console", False)]
    a = console_logger("repro.launch.serve")
    b = console_logger("repro.launch.train")
    after = [h for h in root.handlers
             if getattr(h, "_repro_console", False)]
    assert len(after) == max(1, len(before))       # attached exactly once
    assert a is not b and after[0].formatter._fmt == "%(message)s"


def test_console_logger_emits_bare_message(capsys):
    log = console_logger("obs_test_logger")        # non-repro: own handler
    log.info("plain %d output", 42)
    assert capsys.readouterr().out == "plain 42 output\n"


def test_launchers_use_module_loggers():
    import repro.launch.serve as serve
    import repro.launch.train as train
    assert isinstance(serve.log, logging.Logger)
    assert isinstance(train.log, logging.Logger)
