"""Internet-scale event calendar vs the scalar heap oracle
(repro.sim.events, DESIGN.md §11)."""
import numpy as np
import pytest

from repro.core.api import TraceProvider, load_intensity_csv
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import Task
from repro.sim import (AsyncEngineDriver, ClientPopulation,
                       ClosedLoopClientPool, EventCalendar, EventHeap,
                       EventKind, SimExhausted, TraceReplayArrivals)
from repro.sim.events import KIND_CODE

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # the container image ships without hypothesis
    HAVE_HYPOTHESIS = False

TASK = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)

# Kinds whose interleaving the calendar must keep in heap order: int
# payloads (CLIENT_READY/RETRY) share the payload column with the
# object-store kinds (DEFER_WAKE/NODE_DOWN/...).
FUZZ_KINDS = (EventKind.DEFER_WAKE, EventKind.RETRY, EventKind.NODE_DOWN,
              EventKind.CLIENT_READY, EventKind.ARRIVAL)


# ---------------------------------------------------------------------------
# Empty-pop regression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make", [EventHeap, EventCalendar])
def test_empty_pop_raises_sim_exhausted(make):
    q = make()
    with pytest.raises(SimExhausted):
        q.pop()
    # SimExhausted subclasses IndexError (what heapq used to leak), so
    # pre-existing `except IndexError` callers keep working
    with pytest.raises(IndexError):
        q.pop()
    q.push(1.0, EventKind.ARRIVAL)
    q.pop()
    with pytest.raises(SimExhausted):
        q.pop()


# ---------------------------------------------------------------------------
# Calendar-vs-heap ordering parity
# ---------------------------------------------------------------------------


def _payload_for(kind: EventKind, i: int):
    # CLIENT_READY/RETRY payloads ride the int column; others go through
    # the per-kind object store
    if kind in (EventKind.CLIENT_READY, EventKind.RETRY):
        return i
    return ("obj", i) if i % 3 else None


def _apply_ops(ops):
    """Apply the same op sequence to heap and calendar; compare every
    popped event and the final drain."""
    heap, cal = EventHeap(), EventCalendar(target_bucket_events=4)
    i = 0
    for op in ops:
        if op == "pop":
            if heap:
                a, b = heap.pop(), cal.pop()
                assert (a.time_hours, a.seq, a.kind, a.payload) == \
                    (b.time_hours, b.seq, b.kind, b.payload)
            continue
        if op[0] == "push":
            _, t, kind = op
            heap.push(t, kind, _payload_for(kind, i))
            cal.push(t, kind, _payload_for(kind, i))
            i += 1
        else:                                   # ("batch", times, kind)
            _, times, kind = op
            ts = np.asarray(times, dtype=float)
            if kind in (EventKind.CLIENT_READY, EventKind.RETRY):
                ids = np.arange(i, i + ts.size, dtype=np.int64)
                cal.push_batch(ts, kind, ids)
                for j, t in enumerate(ts.tolist()):
                    heap.push(t, kind, i + j)
            else:
                cal.push_batch(ts, kind)
                for t in ts.tolist():
                    heap.push(t, kind, None)
            i += ts.size
    assert len(heap) == len(cal)
    while heap:
        a, b = heap.pop(), cal.pop()
        assert (a.time_hours, a.seq, a.kind, a.payload) == \
            (b.time_hours, b.seq, b.kind, b.payload)
    assert not cal


def test_calendar_matches_heap_under_collision_bursts():
    """Seeded fuzz: bursts of identical timestamps interleaved with
    scalar pushes, batch pushes and pops must preserve (time, seq)
    order exactly — including pushes landing behind the cursor after
    the first pop activates the calendar."""
    rng = np.random.default_rng(20260808)
    grid = np.array([0.0, 0.25, 0.25, 0.5, 0.5, 0.5, 1.0, 2.5])
    for _ in range(25):
        ops = []
        for _ in range(rng.integers(10, 60)):
            r = rng.random()
            if r < 0.45:
                ops.append(("push", float(rng.choice(grid)),
                            FUZZ_KINDS[rng.integers(len(FUZZ_KINDS))]))
            elif r < 0.7:
                n = int(rng.integers(1, 12))
                ops.append(("batch", rng.choice(grid, n),
                            FUZZ_KINDS[rng.integers(len(FUZZ_KINDS))]))
            else:
                ops.append("pop")
        _apply_ops(ops)


def test_pop_run_matches_scalar_pops():
    """pop_run must return exactly the prefix a scalar pop loop with the
    same qualification rule would, in the same order."""
    rng = np.random.default_rng(7)
    codes = (KIND_CODE[EventKind.CLIENT_READY], KIND_CODE[EventKind.RETRY])
    for _ in range(10):
        heap, cal = EventHeap(), EventCalendar(target_bucket_events=8)
        n = int(rng.integers(30, 120))
        ts = np.round(rng.uniform(0.0, 1.0, n), 2)     # forced collisions
        for j, t in enumerate(ts.tolist()):
            kind = FUZZ_KINDS[int(rng.integers(len(FUZZ_KINDS)))]
            p = j if KIND_CODE[kind] in codes else None
            heap.push(t, kind, p)
            cal.push(t, kind, p)
        while cal:
            max_n = int(rng.integers(1, 16))
            max_t = float(rng.choice([0.3, 0.7, np.inf]))
            rt, rp, rk = cal.pop_run(codes, max_n, max_time=max_t)
            # reference: scalar pops off the heap under the same rule
            want = []
            while heap and len(want) < max_n:
                nxt = heap.peek()
                if KIND_CODE[nxt.kind] not in codes or \
                        not nxt.time_hours <= max_t:
                    break
                want.append(heap.pop())
            assert rt.size == len(want)
            assert rt.tolist() == [e.time_hours for e in want]
            assert rk.tolist() == [KIND_CODE[e.kind] for e in want]
            assert rp.tolist() == [e.payload for e in want]
            if rt.size == 0:                # next event doesn't qualify
                a, b = heap.pop(), cal.pop()
                assert (a.time_hours, a.seq, a.kind) == \
                    (b.time_hours, b.seq, b.kind)


if HAVE_HYPOTHESIS:
    _kind = st.sampled_from(FUZZ_KINDS)
    _time = st.sampled_from([0.0, 0.125, 0.25, 0.25, 0.5, 1.0])
    _op = st.one_of(
        st.just("pop"),
        st.tuples(st.just("push"), _time, _kind),
        st.tuples(st.just("batch"),
                  st.lists(_time, min_size=1, max_size=10), _kind),
    )

    @settings(max_examples=60, deadline=None)
    @given(st.lists(_op, max_size=60))
    def test_hypothesis_calendar_heap_parity(ops):
        _apply_ops(ops)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_calendar_heap_parity():
        pass


# ---------------------------------------------------------------------------
# Driver byte identity (null executor isolates the event machinery)
# ---------------------------------------------------------------------------


class _NullResult:
    __slots__ = ()
    latency_ms = 0.05
    energy_kwh = 1e-6
    carbon_g = 0.5
    node = "n0"


class _NullExecutor:
    """Constant-cost executor exposing the same surface the driver uses
    on a real engine (submit/submit_many/step + last_exec columns)."""

    def __init__(self, max_batch=256):
        self._queued = 0
        self._res = _NullResult()
        self._uniq = np.array([_NullResult.node])
        self._inv = np.zeros(max_batch, dtype=np.int64)
        self._lat = np.full(max_batch, _NullResult.latency_ms)
        self._ekwh = np.full(max_batch, _NullResult.energy_kwh)
        self._cg = np.full(max_batch, _NullResult.carbon_g)
        self.last_exec = None

    def submit(self, task):
        self._queued += 1

    def submit_many(self, tasks):
        self._queued += len(tasks)

    def step(self, now_hour=0.0, limit=None):
        k = self._queued if limit is None else min(self._queued, limit)
        self._queued -= k
        self.last_exec = (self._uniq, self._inv[:k], self._lat[:k],
                          self._ekwh[:k], self._cg[:k])
        return [self._res] * k


def _closed_loop_text(event_queue, *, n_clients=300, max_batch=64,
                      horizon=0.1, slo=1e-5):
    pool = ClosedLoopClientPool([
        ClientPopulation("bulk", (n_clients * 2) // 3,
                         mean_think_hours=0.01),
        ClientPopulation("strict", n_clients - (n_clients * 2) // 3,
                         mean_think_hours=0.015, slo_latency_s=slo,
                         max_attempts=3, priority=1),
    ], seed=11)
    drv = AsyncEngineDriver(
        _NullExecutor(max_batch), None, lambda uid, hour, tenant: uid,
        horizon_hours=horizon, max_batch=max_batch,
        batch_window_hours=5e-4, clients=pool, event_queue=event_queue)
    return drv.run().to_text()


def test_driver_byte_identity_closed_loop():
    assert _closed_loop_text("calendar") == _closed_loop_text("heap")


def test_driver_byte_identity_saturated_regime():
    """max_batch=2 keeps the pending set full, forcing the calendar loop
    through its scalar-dispatch fallback and the per-bucket heap overlay
    — the paths a wide-open batch never touches."""
    kw = dict(n_clients=120, max_batch=2, horizon=0.05)
    assert _closed_loop_text("calendar", **kw) == _closed_loop_text(
        "heap", **kw)


def test_saturated_flush_event_count_linear():
    """Sustained saturation (pending never drains below max_batch) must
    keep the BATCH_READY population bounded: one armed flush at a time,
    re-armed per drain — not one per enqueue, which made total event
    count quadratic (each drain dragged every same-time flush event
    along, ~45 flush pops per client event at 10^6 clients)."""
    for event_queue in ("heap", "calendar"):
        pool = ClosedLoopClientPool([
            ClientPopulation("bulk", 400, mean_think_hours=0.01),
        ], seed=7)
        drv = AsyncEngineDriver(
            _NullExecutor(4), None, lambda uid, hour, tenant: uid,
            horizon_hours=0.02, max_batch=4, batch_window_hours=5e-4,
            clients=pool, event_queue=event_queue)
        m = drv.run()
        # per task: one CLIENT_READY/RETRY pop + O(1) amortized flush
        # pops (drain + busy bounce + superseded stale); initial events
        # past the horizon still pop once each
        bound = 6 * m.n_records + 2 * 400 + 50
        assert drv.events_processed <= bound, (
            event_queue, drv.events_processed, m.n_records)


def test_driver_horizon_boundary_parity():
    """Arrivals exactly at and beyond start+horizon: the batched
    searchsorted split must drop the same suffix the scalar `now >=
    horizon` check does, on both queues."""
    ts = np.array([0.005, 0.01, 0.02, 0.02, 0.05, 0.0500000001, 0.06])

    def one(event_queue):
        drv = AsyncEngineDriver(
            _NullExecutor(), TraceReplayArrivals(ts),
            lambda uid, hour: uid, horizon_hours=0.05, max_batch=16,
            batch_window_hours=5e-4, event_queue=event_queue)
        m = drv.run()
        return m.to_text(), m.n_records

    (text_c, n_c), (text_h, n_h) = one("calendar"), one("heap")
    assert text_c == text_h
    assert n_c == n_h
    assert n_c <= 5        # the past-horizon tail must not be served


def test_pool_initial_events_batch_matches_scalar():
    def pool():
        # both paths consume the RNG stream, so each gets a fresh pool
        return ClosedLoopClientPool([
            ClientPopulation("a", 40, mean_think_hours=0.01),
            ClientPopulation("b", 25, mean_think_hours=0.02, priority=1),
        ], seed=3)

    ats, cids = pool().initial_events_arrays(2.0)
    scalar = pool().initial_events(2.0)
    assert ats.size == len(scalar) == 65
    assert list(zip(ats.tolist(), cids.tolist())) == scalar
    # priority=1 clients win same-instant ties; all stagger past start
    assert (ats >= 2.0).all()


# ---------------------------------------------------------------------------
# Regional CSV ingestion + multi-region trace replay determinism
# ---------------------------------------------------------------------------

CSV_ISO = (
    "datetime,zone_name,carbon_intensity_avg\n"
    "2026-08-07T00:00:00Z,DE,320.5\n"
    "2026-08-07T00:00:00Z,FR,58.0\n"
    "2026-08-07T01:00:00Z,DE,310.0\n"
    "2026-08-07T01:00:00Z,FR,61.5\n"
    "2026-08-07T02:00:00Z,DE,300.25\n"
    "2026-08-07T02:00:00Z,FR,60.0\n"
)


def test_load_intensity_csv_iso_multizone():
    zones = load_intensity_csv(CSV_ISO)
    assert sorted(zones) == ["DE", "FR"]
    de = zones["DE"]
    # midnight-started day rebases onto hours 0..2, one-hour steps
    assert de.start_hour == 0.0 and de.step_hours == 1.0
    assert de.at(0.0) == 320.5
    assert de.at(1.5) == pytest.approx((310.0 + 300.25) / 2)
    assert zones["FR"].at(2.0) == 60.0


def test_load_intensity_csv_numeric_hours_zoneless():
    text = "hour,intensity\n0.0,100\n0.5,110\n\n1.0,120\n"
    zones = load_intensity_csv(text)
    assert list(zones) == [""]
    tr = zones[""]
    assert tr.step_hours == 0.5
    assert tr.at(0.25) == pytest.approx(105.0)


def test_load_intensity_csv_errors():
    with pytest.raises(KeyError, match="carbon-intensity"):
        load_intensity_csv("hour,zone\n0,DE\n1,DE\n")
    with pytest.raises(ValueError, match="uniformly spaced"):
        load_intensity_csv("hour,intensity\n0,100\n1,110\n3,120\n")
    with pytest.raises(ValueError, match="no intensity rows"):
        load_intensity_csv("hour,intensity\n")


def test_from_csv_unknown_zone_lists_available():
    with pytest.raises(KeyError, match="zones.*DE.*FR"):
        TraceProvider.from_csv(CSV_ISO, node_zones={"n0": "XX"})


def test_from_csv_node_mapping_and_batch_parity():
    tp = TraceProvider.from_csv(CSV_ISO, node_zones={"n0": "DE",
                                                     "n1": "FR"})
    assert tp.intensity("n0", 1.0) == 310.0
    hours = np.array([0.5, 1.0, 2.0])
    batch = tp.intensity_batch(["n0", "n1"], hours)   # (hours, names) grid
    want = [[tp.intensity(n, h) for n in ("n0", "n1")]
            for h in hours.tolist()]
    assert batch.tolist() == want


def test_multi_region_trace_replay_deterministic():
    """A sim over an ingested multi-region CSV renders byte-identically
    across a repeat run, both event queues and both execute paths."""
    from repro.core.api import CarbonEdgeEngine

    node_zones = {n.name: ("DE", "FR")[i % 2]
                  for i, n in enumerate(PAPER_NODES)}
    ts = np.round(np.linspace(0.02, 2.9, 60), 4)

    def one(event_queue, batch_execute):
        provider = TraceProvider.from_csv(CSV_ISO, node_zones=node_zones)
        cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
        cluster.profile(250.0)
        engine = CarbonEdgeEngine(cluster, mode="green",
                                  provider=provider,
                                  batch_execute=batch_execute)
        drv = AsyncEngineDriver(
            engine, TraceReplayArrivals(ts), lambda uid, hour: TASK,
            horizon_hours=3.0, max_batch=8, batch_window_hours=0.01,
            tick_hours=0.5, event_queue=event_queue)
        return drv.run().to_text()

    ref = one("calendar", True)
    assert one("calendar", True) == ref
    assert one("heap", True) == ref
    assert one("calendar", False) == ref
