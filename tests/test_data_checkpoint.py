"""Data pipeline determinism + checkpoint roundtrip + optimizer math."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.registry import reduced_config
from repro.data.pipeline import DataConfig, make_batches, synthetic_batches
from repro.models import transformer
from repro.optim import adamw


def test_pipeline_deterministic():
    cfg = reduced_config("qwen3-1.7b")
    d = DataConfig(seq_len=32, global_batch=4, seed=7)
    b1 = next(synthetic_batches(cfg, d))
    b2 = next(synthetic_batches(cfg, d))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = next(synthetic_batches(cfg, DataConfig(32, 4, seed=8)))
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_labels_shifted():
    cfg = reduced_config("qwen3-1.7b")
    b = next(synthetic_batches(cfg, DataConfig(32, 4)))
    # labels are next-token targets
    assert b["tokens"].shape == b["labels"].shape
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_extras():
    cfg = reduced_config("whisper-base")
    b = next(synthetic_batches(cfg, DataConfig(16, 2)))
    assert b["encoder_embeds"].shape == (2, cfg.encoder_seq, cfg.d_model)
    cfg = reduced_config("qwen2-vl-2b")
    b = next(synthetic_batches(cfg, DataConfig(32, 2)))
    assert b["vision_embeds"].shape == (2, cfg.vision_tokens, cfg.d_model)
    assert b["tokens"].shape == (2, 32 - cfg.vision_tokens)
    assert b["mrope_positions"].shape == (2, 3, 32)


def test_corpus_pipeline(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello carbon aware world " * 200)
    cfg = reduced_config("qwen3-1.7b")
    b = next(make_batches(cfg, DataConfig(16, 2, corpus=str(p))))
    assert b["tokens"].max() < 256
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced_config("qwen3-1.7b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(3))
    path = str(tmp_path / "ckpt.msgpack")
    store.save(path, params, {"arch": cfg.name, "step": 42})
    restored = store.restore(path, params)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, restored)
    assert store.load_meta(path)["step"] == 42


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100, grad_clip=10.0, min_lr_frac=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(120):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, g, state, params)
    assert float(loss(params)) < 0.3


def test_adamw_schedule():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(adamw.schedule(cfg, jnp.int32(5))) < 1e-3
    assert abs(float(adamw.schedule(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(adamw.schedule(cfg, jnp.int32(100))) <= 1e-3 * (
        cfg.min_lr_frac + 1e-6)


def test_grad_clip_and_update_bound():
    """Clipping keeps the step finite under huge grads, and (Adam being
    scale-invariant) the per-coordinate update is bounded by ~lr."""
    cfg = adamw.AdamWConfig(lr=0.01, grad_clip=1.0, weight_decay=0.0,
                            warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones(4)}
    state = adamw.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _, m = adamw.apply(cfg, huge, state, params)
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert float(m["grad_norm"]) > 1e8          # metric reports raw norm
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 0.011 * 1.2
