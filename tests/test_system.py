"""End-to-end behaviour tests: train loop learns; serving engine routes,
generates and accounts carbon; the full CarbonEdge story in one pass."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs.registry import reduced_config
from repro.core import costmodel, energy
from repro.core.router import GreenRouter, PodSpec
from repro.data.pipeline import DataConfig, synthetic_batches
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import steps
from repro.runtime.serving import Request, ServingEngine


def test_training_learns():
    """~60 steps on structured synthetic data: loss must drop >= 1 nat."""
    cfg = reduced_config("qwen3-1.7b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=2e-3, total_steps=100, warmup_steps=5)
    opt = adamw.init(params)
    step = jax.jit(steps.train_step(cfg, opt_cfg))
    batches = synthetic_batches(cfg, DataConfig(seq_len=64, global_batch=8))
    losses = []
    for i in range(100):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[0] > losses[-1] + 0.8, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


PODS = [
    PodSpec("pod-high", 256, "coal-heavy", 620.0),
    PodSpec("pod-medium", 256, "cn-average", 530.0),
    PodSpec("pod-green", 256, "hydro-rich", 380.0),
]


def _engine(mode):
    cfg = reduced_config("qwen3-1.7b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    router = GreenRouter(PODS, mode=mode)
    flops = 2.0 * cfg.active_param_count() * 2
    hbm = costmodel.step_hbm_bytes(cfg, 16, 2, "decode")
    terms = energy.roofline(flops, hbm, 0.0, 256)
    router.seed_profile({p.name: terms for p in PODS})
    eng = ServingEngine(cfg, params, router, max_len=32, batch_size=2)
    return cfg, eng


def test_serving_green_routing_and_accounting():
    cfg, eng = _engine("green")
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=12).astype(np.int32), max_new_tokens=3))
    comps = eng.run_all()
    assert len(comps) == 4
    assert all(c.pod == "pod-green" for c in comps)
    assert all(len(c.tokens) == 3 for c in comps)
    assert all(0 <= t < cfg.vocab_size for c in comps for t in c.tokens)
    rep = eng.report()
    assert rep["completed"] == 4
    assert rep["carbon_g_total"] > 0
    assert rep["per_region"]["pod-green"]["tasks"] > 0
    assert rep["per_region"]["pod-high"]["tasks"] == 0


def test_green_pod_availability_changes_carbon():
    """Same workload with the green pod saturated (load filter, Algorithm 1
    line 3) must emit more carbon — and the ratio must follow the grid
    intensities exactly (identical work, different region)."""
    totals = {}
    pods_used = {}
    for scenario in ("green-free", "green-busy"):
        cfg, eng = _engine("green")
        if scenario == "green-busy":
            eng.router.cluster.nodes["pod-green"].load = 0.9
            eng.router.cluster.nodes["pod-medium"].load = 0.9
        rng = np.random.default_rng(0)
        for i in range(2):
            eng.submit(Request(uid=i, prompt=rng.integers(
                0, cfg.vocab_size, size=12).astype(np.int32), max_new_tokens=2))
        eng.run_all()
        totals[scenario] = eng.report()["carbon_g_total"]
        pods_used[scenario] = {r for c in eng.completions for r in [c.pod]}
    assert pods_used["green-free"] == {"pod-green"}
    assert pods_used["green-busy"] == {"pod-high"}
    np.testing.assert_allclose(totals["green-free"] / totals["green-busy"],
                               380.0 / 620.0, rtol=0.05)


def test_greedy_decode_deterministic():
    cfg, eng = _engine("green")
    prompt = np.arange(12, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    a = eng.run_all()[0].tokens
    cfg2, eng2 = _engine("green")
    eng2.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    b = eng2.run_all()[0].tokens
    assert a == b
