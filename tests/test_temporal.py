"""Temporal (time-shifting) carbon scheduler — paper §V future work.

The hypothesis-based tests at the bottom are optional (``[test]`` extra in
pyproject.toml); the deterministic tests always run.
"""
import numpy as np
import pytest

try:  # optional extra — see pyproject.toml
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):      # no-op stand-ins so the hypothesis
        return lambda f: f           # tests below stay defined once and

    def settings(*args, **kwargs):   # are reported as skipped
        return lambda f: f

    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed — pip install -e .[test]")

from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import MODES
from repro.core.temporal import (DeferrableTask, IntensityTrace,
                                 TemporalScheduler,
                                 carbon_savings_from_deferral,
                                 synthetic_trace)


def make_sched(weights=None):
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(250.0)
    traces = {
        "node-high": synthetic_trace("coal-heavy", 620.0, solar_dip=0.1),
        "node-medium": synthetic_trace("cn-average", 530.0, solar_dip=0.3),
        "node-green": synthetic_trace("hydro-rich", 380.0, solar_dip=0.5),
    }
    return TemporalScheduler(c, traces, weights or MODES["green"]), traces


def test_trace_interpolation():
    tr = IntensityTrace("r", tuple(float(i) for i in range(24)))
    assert tr.at(0.0) == 0.0
    assert abs(tr.at(1.5) - 1.5) < 1e-9
    assert abs(tr.at(23.5) - (23 * 0.5 + 0 * 0.5)) < 1e-9  # wraps
    assert abs(tr.at(25.0) - 1.0) < 1e-9


def test_synthetic_trace_duck_curve():
    tr = synthetic_trace("r", 500.0)
    vals = np.array(tr.values)
    assert np.argmin(vals) in (12, 13, 14)        # midday solar dip
    assert vals.max() <= 500.0 * 1.2
    assert vals.min() >= 500.0 * 0.5


def test_urgent_task_runs_now():
    sched, _ = make_sched()
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=0.0,
                       duration_hours=0.1)
    pl = sched.select(t, now_hour=18.0)
    assert pl is not None
    assert pl.deferred_hours == 0.0


def test_deferral_targets_solar_dip():
    """A task submitted in the evening with a 20h deadline should shift
    into the next midday dip on the greenest trace."""
    sched, traces = make_sched()
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=20.0,
                       duration_hours=0.5)
    pl = sched.select(t, now_hour=18.0)
    assert pl.node == "node-green"
    start = pl.start_hour % 24
    assert 10.0 <= start <= 16.0, pl            # midday window
    # carbon at the chosen slot beats run-now on the same node
    run_now = traces["node-green"].at(18.25)
    chosen = traces["node-green"].at(pl.start_hour + 0.25)
    assert chosen < run_now


def test_deferral_saves_carbon():
    sched, traces = make_sched()
    c = sched.cluster
    tasks = [DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=16.0,
                            duration_hours=0.25) for _ in range(10)]
    out = carbon_savings_from_deferral(c, traces, MODES["green"], tasks,
                                       now_hour=19.0)
    assert out["deferred_g"] <= out["run_now_g"] + 1e-12
    assert out["savings_pct"] > 10.0            # evening -> midday shift


def test_equal_carbon_tiebreak_prefers_higher_score():
    """Regression: when two placements tie on expected carbon, the Eq. 3
    weighted score must break the tie (the seed computed the score and then
    discarded it, so the first-scanned node always won)."""
    from repro.core.cluster import NodeSpec

    # intensity inversely proportional to cpu quota => identical expected
    # carbon per node; the small node is listed first so carbon-only
    # first-wins scanning would (wrongly) pick it.
    nodes = [NodeSpec("n-small", 0.4, 512, 750.0),
             NodeSpec("n-big", 1.0, 1024, 300.0)]
    c = EdgeCluster(nodes=nodes, host_power_w=142.0)
    c.profile(250.0)
    sched = TemporalScheduler(c, traces={}, weights=MODES["balanced"])
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=0.0,
                       duration_hours=0.5)
    pl = sched.select(t, now_hour=0.0)
    # equal carbon; n-big has the better S_P (faster history) => higher score
    assert pl.node == "n-big"


def test_score_tiebreak_prefers_run_now():
    """With a flat (static) intensity every slot ties on carbon AND score;
    the deferral penalty must keep the choice at 'run now'."""
    sched = TemporalScheduler(
        EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0), traces={},
        weights=MODES["green"])
    sched.cluster.profile(250.0)
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=12.0,
                       duration_hours=0.25)
    pl = sched.select(t, now_hour=3.0)
    assert pl.deferred_hours == 0.0
    assert pl.start_hour == 3.0


@requires_hypothesis
@settings(max_examples=30, deadline=None)
@given(now=st.floats(0.0, 23.9), deadline=st.floats(0.0, 30.0))
def test_deadline_respected(now, deadline):
    sched, _ = make_sched()
    t = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=deadline,
                       duration_hours=0.2)
    pl = sched.select(t, now_hour=now)
    assert pl is not None
    assert pl.deferred_hours <= max(deadline - 0.2, 0.0) + sched.slot_hours
    assert pl.start_hour >= now - 1e-9


@requires_hypothesis
@settings(max_examples=30, deadline=None)
@given(deadline=st.floats(1.0, 24.0))
def test_deferral_never_worse_than_now(deadline):
    """More slack can only reduce (or keep) expected carbon."""
    sched, traces = make_sched()
    urgent = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=0.0,
                            duration_hours=0.2)
    slack = DeferrableTask(cpu=0.05, mem_mb=16, deadline_hours=deadline,
                           duration_hours=0.2)
    now = 19.0
    p0 = sched.select(urgent, now)
    p1 = sched.select(slack, now)
    assert p1.expected_carbon_g <= p0.expected_carbon_g + 1e-12
