"""Sharding rules + small-mesh distributed execution (subprocess: the
device-count flag must be set before jax init, so multi-device tests run in
their own interpreter)."""
import json
import subprocess
import sys

import pytest

from jax.sharding import PartitionSpec as P


def test_spec_from_axes_divisibility():
    from repro.sharding import rules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 8}

    r = rules.rules_for("train")
    # heads=16 divisible by 8 -> model; embed -> data
    spec = rules.spec_from_axes(("embed", "heads", "head_dim"),
                                (64, 16, 128), r, FakeMesh())
    assert spec == P("data", "model", None)
    # heads=6 NOT divisible -> falls to head_dim
    spec = rules.spec_from_axes(("embed", "heads", "head_dim"),
                                (64, 6, 128), r, FakeMesh())
    assert spec == P("data", None, "model")
    # serve mode: no fsdp on embed
    r2 = rules.rules_for("serve")
    spec = rules.spec_from_axes(("embed", "ff"), (64, 128), r2, FakeMesh())
    assert spec == P(None, "model")


_DISTRIBUTED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.configs.registry import reduced_config
from repro.launch.dryrun import build
from repro.launch.mesh import make_test_mesh
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import steps
from repro.sharding import rules

cfg = reduced_config("qwen3-1.7b")
mesh = make_test_mesh(data=4, model=2)
shape = InputShape("tiny_train", seq_len=32, global_batch=8, kind="train")

fn, args, in_sh = build(cfg, shape, mesh)
with mesh:
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
    compiled = lowered.compile()

# now ACTUALLY run the distributed step with real arrays and compare with
# the single-device result
params = transformer.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

step = steps.train_step(cfg, adamw.AdamWConfig())
with mesh:
    p_sh, o_sh, b_sh = in_sh
    params_d = jax.device_put(params, p_sh)
    opt_d = jax.device_put(opt, o_sh)
    batch_d = jax.device_put(batch, b_sh)
    _, _, metrics_d = jax.jit(step, in_shardings=in_sh)(params_d, opt_d, batch_d)
_, _, metrics_1 = step(params, opt, batch)
out = {
    "loss_distributed": float(metrics_d["loss"]),
    "loss_single": float(metrics_1["loss"]),
    "compiled_ok": True,
}
print("RESULT::" + json.dumps(out))
"""


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    proc = subprocess.run(
        [sys.executable, "-c", _DISTRIBUTED_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert out["compiled_ok"]
    assert abs(out["loss_distributed"] - out["loss_single"]) < 1e-2, out


_DECODE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro.configs.base import InputShape
from repro.configs.registry import reduced_config
from repro.launch.dryrun import build
from repro.launch.mesh import make_test_mesh

ok = {}
for arch in ("qwen3-1.7b", "zamba2-2.7b", "qwen2-moe-a2.7b"):
    cfg = reduced_config(arch)
    mesh = make_test_mesh(data=2, model=2, pod=2)
    shape = InputShape("tiny_decode", seq_len=64, global_batch=4, kind="decode")
    fn, args, in_sh = build(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    ok[arch] = True
print("RESULT::" + json.dumps(ok))
"""


@pytest.mark.slow
def test_multipod_decode_lowers():
    proc = subprocess.run(
        [sys.executable, "-c", _DECODE_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert all(out.values()) and len(out) == 3


_SHARDED_SELECT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import node_score as ns
from repro.kernels import ops

rng = np.random.default_rng(0)
B, N = 4, 8 * 1024                       # node axis divides 8 devices
f = np.abs(rng.standard_normal((B, N, 8))).astype(np.float32)
f[:, :, 6] = (f[:, :, 6] > 0.3).astype(np.float32)
# plant cross-shard exact ties: shard 2 and shard 6 share the best score
f[0, 2 * 1024 + 5] = f[0, 6 * 1024 + 9] = [2, 2, 0, 0, 0, 0, 1, 0]
w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)

si, sv = ns.select_best_sharded(jnp.asarray(f), jnp.asarray(w),
                                interpret=True)
ref_scores = np.asarray(ops.node_scores_batched(jnp.asarray(f),
                                                jnp.asarray(w)))
ref = np.argmax(ref_scores, axis=1)
out = {
    "n_devices": len(jax.devices()),
    "match": bool((np.asarray(si) == ref).all()),
    "tie_idx": int(si[0]),
    "val_close": bool(np.allclose(np.asarray(sv),
                                  ref_scores[np.arange(B), ref], rtol=1e-5)),
}
print("RESULT::" + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_node_select_8_devices():
    """shard_map'd fused select across a forced 8-CPU-device mesh: global
    winners (and cross-shard tie-breaks: lowest global index) must match
    the unsharded argmax."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SELECT_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert out["n_devices"] == 8, out
    assert out["match"] and out["val_close"], out
    assert out["tie_idx"] == 2 * 1024 + 5, out   # lowest global index wins
