"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU; output shapes + no NaNs.
Decode shapes are exercised in test_decode_consistency.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import steps

B, S = 2, 32


def make_batch(cfg, key, with_labels=True):
    st = S - cfg.vision_tokens
    tokens = jax.random.randint(key, (B, st), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if with_labels:
        batch["labels"] = jnp.roll(tokens, -1, axis=1)
    if cfg.encoder_layers:
        batch["encoder_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), with_labels=False)
    hidden, aux = transformer.forward(cfg, params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))
    logits = transformer.unembed(cfg, params, hidden[:, -1])
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = reduced_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    step = steps.train_step(cfg, adamw.AdamWConfig(total_steps=4))
    opt = adamw.init(params)
    p2, o2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # sane loss near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < loss < 2.5 * np.log(cfg.vocab_size)
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))), params, p2))
    assert delta > 0
    # loss decreases over a few steps on repeated batch
    for _ in range(3):
        p2, o2, metrics = step(p2, o2, batch)
    assert float(metrics["loss"]) < loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    cfg = get_config(arch)
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert len(cfg.layer_defs) == cfg.num_layers


def test_moe_configs():
    a = get_config("arctic-480b")
    assert a.moe.num_experts == 128 and a.moe.top_k == 2
    assert a.moe.dense_residual_ff == 4864
    q = get_config("qwen2-moe-a2.7b")
    assert q.moe.num_experts == 60 and q.moe.top_k == 4
    assert q.moe.num_shared_experts == 4


def test_param_counts_plausible():
    # arctic ~480B total; zamba2 ~2.7B; qwen3 ~1.7B-2B
    assert 4.0e11 < get_config("arctic-480b").param_count() < 5.5e11
    assert 2.0e9 < get_config("zamba2-2.7b").param_count() < 3.5e9
    assert 1.3e9 < get_config("qwen3-1.7b").param_count() < 2.3e9
    assert 3.0e8 < get_config("xlstm-350m").param_count() < 5.0e8
    # arctic active (top-2 of 128 + dense) is a small fraction of total
    a = get_config("arctic-480b")
    assert a.active_param_count() < 0.1 * a.param_count()
