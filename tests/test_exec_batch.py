"""Batched execution & billing parity (DESIGN.md §6).

``CarbonEdgeEngine(batch_execute=True)`` — the default — must be
bit-identical to the per-task execute+bill loop (``batch_execute=False``)
across: cluster node ledgers, the TaskResult log, monitor region accounts,
returned results, requeue state, and mid-batch failures (infeasible node,
provider KeyError, unknown node from a custom policy). The scalar loop is
the oracle, the same pattern as ``featurize`` vs ``featurize_cached``.

Also covers the batched primitives directly (``EdgeCluster.execute_batch``,
``CarbonMonitor.record_energy_batch``/``billing_intensity_batch``,
``energy.ledger_add`` sequential-fold bit-exactness, array-valued energy
helpers), the profile-level selection memo's invalidation contract, and a
sim-driver byte-identity check (``metrics.to_text``) across both paths.
A hypothesis fuzz (optional dep) drives randomized traffic with injected
failures through both engines.
"""
import numpy as np
import pytest

from repro.core import energy
from repro.core.api import (CarbonEdgeEngine, NoFeasibleNodeError,
                            StaticProvider, TraceProvider)
from repro.core.carbon import CarbonMonitor
from repro.core.cluster import EdgeCluster, NodeSpec, PAPER_NODES
from repro.core.policy import VectorizedPolicy
from repro.core.scheduler import MODES, Task
from repro.core.temporal import synthetic_trace


def fresh_cluster():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(250.0)
    return c


def mixed_tasks(n=40, seed=0):
    rng = np.random.default_rng(seed)
    return [Task(cpu=float(rng.uniform(0.0, 0.3)),
                 mem_mb=float(rng.uniform(0.0, 128.0)),
                 base_latency_ms=float(rng.uniform(50.0, 400.0)))
            for _ in range(n)]


def full_state(eng):
    """Every observable the parity contract covers, in comparable form."""
    cl = eng.cluster
    return {
        "nodes": [(n, s.completed, s.total_time_ms, s.energy_kwh,
                   s.carbon_g, s.running, s.load, s.mem_used_mb)
                  for n, s in cl.nodes.items()],
        "log": list(cl.log),
        "totals": cl.totals(),
        "regions": {r: (a.energy_kwh, a.carbon_g, a.tasks, a.pinned)
                    for r, a in eng.monitor.regions.items()},
        "queue": list(eng.queue),
    }


def engine_pair(provider=None, policy=None, mode="green", **kw):
    def mk(batch_execute):
        return CarbonEdgeEngine(fresh_cluster(), mode=mode,
                                provider=provider, policy=policy,
                                batch_execute=batch_execute, **kw)
    return mk(False), mk(True)


class RoundRobinPolicy:
    """Provider-blind stub: selection never touches the provider, so
    execute-path resolution is the first place a bad provider can fail."""

    name = "round-robin"

    def __init__(self, names):
        self.names = list(names)

    def select_batch(self, cluster, tasks, weights, provider=None,
                     now_hour=0.0):
        return [self.names[i % len(self.names)] for i in range(len(tasks))]

    def select(self, cluster, task, weights, provider=None, now_hour=0.0):
        return self.names[0]


class LateFailProvider:
    """Covers every node at registration (hour 0) but loses ``fail_node``
    for later hours — triggers the execute-path KeyError mid-batch."""

    def __init__(self, fail_node="node-green", after_hour=0.5):
        self.table = {n.name: n.carbon_intensity for n in PAPER_NODES}
        self.fail_node = fail_node
        self.after_hour = after_hour

    def intensity(self, node, hour=0.0):
        if node == self.fail_node and hour > self.after_hour:
            raise KeyError(f"no carbon intensity registered for {node!r}")
        return self.table[node]


# ---------------------------------------------------------------------------
# engine.step parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["green", "performance", "balanced"])
def test_step_parity_happy_path(mode):
    a, b = engine_pair(mode=mode)
    tasks = mixed_tasks()
    ra = a.submit_many(tasks).step(now_hour=3.0)
    rb = b.submit_many(tasks).step(now_hour=3.0)
    assert ra == rb
    assert full_state(a) == full_state(b)


def test_step_parity_multi_step_batches():
    a, b = engine_pair(batch_size=7)
    tasks = mixed_tasks(25, seed=1)
    a.submit_many(tasks)
    b.submit_many(tasks)
    while a.queue:
        assert a.step(2.0) == b.step(2.0)
        assert full_state(a) == full_state(b)
    assert not b.queue


def test_step_parity_trace_provider_run_until():
    def mk(batch_execute):
        c = fresh_cluster()
        prov = TraceProvider({n: synthetic_trace(n, st.spec.carbon_intensity,
                                                 seed=i)
                              for i, (n, st) in enumerate(c.nodes.items())})
        return CarbonEdgeEngine(c, provider=prov, batch_size=6,
                                batch_execute=batch_execute)
    a, b = mk(False), mk(True)
    tasks = [Task(cpu=0.05, mem_mb=8.0, base_latency_ms=900.0)
             for _ in range(30)]
    ra = a.submit_many(tasks).run_until(5.0)
    rb = b.submit_many(tasks).run_until(5.0)
    assert ra == rb
    assert full_state(a) == full_state(b)


def test_step_parity_infeasible_mid_batch():
    tasks = mixed_tasks(10, seed=2) + [Task(cpu=99.0, base_latency_ms=5.0)] \
        + mixed_tasks(5, seed=3)
    a, b = engine_pair()
    excs = []
    for eng in (a, b):
        with pytest.raises(NoFeasibleNodeError) as ei:
            eng.submit_many(tasks).step()
        excs.append(ei.value)
    assert excs[0].executed == excs[1].executed
    assert len(excs[0].executed) == 10
    assert full_state(a) == full_state(b)
    # the infeasible task and the tail are back at the queue head
    assert a.queue == tasks[10:]


def test_step_parity_provider_keyerror_mid_batch():
    tasks = [Task(cpu=0.01, mem_mb=1.0, base_latency_ms=100.0 + 7 * i)
             for i in range(9)]
    def mk(batch_execute):
        c = fresh_cluster()
        return CarbonEdgeEngine(c, policy=RoundRobinPolicy(c.nodes),
                                provider=LateFailProvider(),
                                batch_execute=batch_execute)
    a, b = mk(False), mk(True)
    excs = []
    for eng in (a, b):
        with pytest.raises(KeyError) as ei:
            eng.submit_many(tasks).step(now_hour=2.0)
        excs.append(ei.value)
    assert str(excs[0]) == str(excs[1])
    assert full_state(a) == full_state(b)
    # round-robin: node-green is task index 2, so exactly 2 executed
    assert len(a.cluster.log) == 2 and len(a.queue) == 7


def test_step_parity_unknown_node_from_custom_policy():
    tasks = mixed_tasks(6, seed=4)
    def mk(batch_execute):
        c = fresh_cluster()
        names = list(c.nodes)[:2] + ["ghost-node"]
        return CarbonEdgeEngine(c, policy=RoundRobinPolicy(names),
                                batch_execute=batch_execute)
    a, b = mk(False), mk(True)
    for eng in (a, b):
        with pytest.raises(KeyError):
            eng.submit_many(tasks).step()
    assert full_state(a) == full_state(b)
    assert len(a.cluster.log) == 2          # ghost-node is task index 2


def test_step_batched_requeues_everything_on_first_task_failure():
    a, b = engine_pair()
    bad = [Task(cpu=99.0, base_latency_ms=5.0)] + mixed_tasks(4, seed=5)
    for eng in (a, b):
        with pytest.raises(NoFeasibleNodeError) as ei:
            eng.submit_many(bad).step()
        assert ei.value.executed == []
    assert full_state(a) == full_state(b)
    assert a.queue == bad and b.queue == bad


# ---------------------------------------------------------------------------
# batched primitives vs their scalar oracles
# ---------------------------------------------------------------------------


def test_execute_batch_matches_sequential_execute():
    ca, cb = fresh_cluster(), fresh_cluster()
    rng = np.random.default_rng(7)
    names = list(ca.nodes)
    chosen = [names[i] for i in rng.integers(0, len(names), 32)]
    lats = rng.uniform(10.0, 500.0, 32)
    ints = rng.uniform(100.0, 900.0, 32)
    res_a = [ca.execute(n, float(lo), intensity=float(io))
             for n, lo, io in zip(chosen, lats, ints)]
    res_b = cb.execute_batch(chosen, lats, intensities=ints)
    assert res_a == res_b
    for n in names:
        sa, sb = ca.nodes[n], cb.nodes[n]
        assert (sa.completed, sa.total_time_ms, sa.energy_kwh, sa.carbon_g) \
            == (sb.completed, sb.total_time_ms, sb.energy_kwh, sb.carbon_g)
    assert ca.log == cb.log


def test_execute_batch_default_intensity_and_non_distributed():
    ca, cb = fresh_cluster(), fresh_cluster()
    chosen = ["node-high", "node-green", "node-high"]
    res_a = [ca.execute(n, 100.0, distributed=False) for n in chosen]
    res_b = cb.execute_batch(chosen, 100.0, distributed=False)
    assert res_a == res_b


def test_execute_batch_atomic_on_unknown_node():
    c = fresh_cluster()
    with pytest.raises(KeyError):
        c.execute_batch(["node-high", "ghost"], 100.0)
    assert not c.log
    assert all(st.completed == 0 and st.energy_kwh == 0.0
               for st in c.nodes.values())


def test_execute_batch_empty():
    assert fresh_cluster().execute_batch([], 100.0) == []


def monitor_pair(provider=None):
    def mk():
        m = CarbonMonitor(provider=provider)
        m.register_region("r-a", 600.0)             # pinned
        if provider is None:
            m.register_region("r-b", 300.0)
            m.register_region("r-c", 450.0)
        else:
            m.register_region("r-b")                # provider-driven
            m.register_region("r-c")
        return m
    return mk(), mk()


def test_record_energy_batch_matches_scalar():
    prov = StaticProvider({"r-b": 333.0, "r-c": 444.0}, default=500.0)
    ma, mb = monitor_pair(provider=prov)
    rng = np.random.default_rng(11)
    regions = [("r-a", "r-b", "r-c")[i] for i in rng.integers(0, 3, 24)]
    es = rng.uniform(1e-6, 1e-3, 24)
    ca = np.array([ma.record_energy(r, float(e), hour=4.0)
                   for r, e in zip(regions, es)])
    cb = mb.record_energy_batch(regions, es, hour=4.0)
    np.testing.assert_array_equal(ca, cb)
    for r in ("r-a", "r-b", "r-c"):
        aa, ab = ma.regions[r], mb.regions[r]
        assert (aa.energy_kwh, aa.carbon_g, aa.tasks) \
            == (ab.energy_kwh, ab.carbon_g, ab.tasks)


def test_record_energy_batch_unregistered_region_is_atomic():
    ma, _ = monitor_pair()
    with pytest.raises(KeyError):
        ma.record_energy_batch(["r-a", "nowhere"], 1e-4)
    assert ma.regions["r-a"].tasks == 0


def test_billing_intensity_batch_matches_scalar_probe():
    prov = StaticProvider({"r-b": 333.0, "r-c": 444.0}, default=500.0)
    m, _ = monitor_pair(provider=prov)
    regions = ["r-c", "r-a", "r-b"]
    batch = m.billing_intensity_batch(regions, hour=2.0)
    scalar = [m.billing_intensity(r, hour=2.0) for r in regions]
    np.testing.assert_array_equal(batch, scalar)
    assert batch[1] == 600.0                        # pinned wins


def test_ledger_add_is_sequential_fold():
    rng = np.random.default_rng(3)
    for _ in range(100):
        start = float(rng.uniform(0.0, 10.0))
        vals = rng.uniform(0.0, 1e-3, int(rng.integers(0, 40)))
        acc = start
        for v in vals:
            acc = acc + float(v)
        assert energy.ledger_add(start, vals) == acc


def test_energy_helpers_are_array_valued():
    lat = np.array([10.0, 250.0, 999.0])
    e = energy.task_energy_kwh(142.0, lat)
    np.testing.assert_array_equal(
        e, [energy.task_energy_kwh(142.0, float(x)) for x in lat])
    c = energy.carbon_g(e, np.array([600.0, 500.0, 400.0]), 1.1)
    np.testing.assert_array_equal(
        c, [energy.carbon_g(float(ei), ii, 1.1)
            for ei, ii in zip(e, (600.0, 500.0, 400.0))])
    terms = energy.RooflineTerms(np.array([1.0, 5.0]), np.array([2.0, 1.0]),
                                 np.array([3.0, 0.5]))
    np.testing.assert_array_equal(terms.step_time_s, [3.0, 5.0])
    np.testing.assert_array_equal(
        energy.step_energy_kwh(terms, 4),
        [energy.step_energy_kwh(energy.RooflineTerms(1.0, 2.0, 3.0), 4),
         energy.step_energy_kwh(energy.RooflineTerms(5.0, 1.0, 0.5), 4)])


# ---------------------------------------------------------------------------
# selection memo invalidation contract
# ---------------------------------------------------------------------------


def test_selection_memo_invalidates_on_feature_change():
    c = fresh_cluster()
    pol = VectorizedPolicy(backend="numpy")
    w = MODES["green"]
    t = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)
    first = pol.select_batch(c, [t], w)
    # ledger-style churn does not move features: memo stays, answer stays
    c.nodes[first[0]].running += 1
    c.nodes[first[0]].running -= 1
    assert pol.select_batch(c, [t], w) == first
    # a real feature change must re-score: overload the chosen node
    c.nodes[first[0]].load = 0.99
    fresh = VectorizedPolicy(backend="numpy", use_select_memo=False)
    assert pol.select_batch(c, [t], w) == fresh.select_batch(c, [t], w)
    assert pol.select_batch(c, [t], w)[0] != first[0]


def test_selection_memo_epoch_tracks_provider_and_hour():
    c = fresh_cluster()
    pol = VectorizedPolicy(backend="numpy")
    memo_off = VectorizedPolicy(backend="numpy", use_select_memo=False)
    w = MODES["green"]
    t = Task(cpu=0.05, mem_mb=16.0, base_latency_ms=250.0)
    traces = {n: synthetic_trace(n, st.spec.carbon_intensity, seed=i,
                                 solar_dip=0.1 + 0.25 * i)
              for i, (n, st) in enumerate(c.nodes.items())}
    prov = TraceProvider(traces)
    for hour in (0.0, 6.5, 12.0, 6.5):
        assert pol.select_batch(c, [t], w, provider=prov, now_hour=hour) \
            == memo_off.select_batch(c, [t], w, provider=prov, now_hour=hour)
    # switching provider objects drops the memo
    static = StaticProvider.from_cluster(c)
    assert pol.select_batch(c, [t], w, provider=static) \
        == memo_off.select_batch(c, [t], w, provider=static)


def test_selection_memo_matches_fresh_across_profiles():
    c = fresh_cluster()
    pol = VectorizedPolicy(backend="numpy")
    fresh = VectorizedPolicy(backend="numpy", use_select_memo=False)
    w = MODES["green"]
    tasks = mixed_tasks(30, seed=9)
    assert pol.select_batch(c, tasks, w) == fresh.select_batch(c, tasks, w)
    # repeat: served from the memo, still identical
    assert pol.select_batch(c, tasks, w) == fresh.select_batch(c, tasks, w)


# ---------------------------------------------------------------------------
# sim driver byte-identity across execution paths
# ---------------------------------------------------------------------------


def test_sim_to_text_identical_across_exec_paths():
    from repro.sim import AsyncEngineDriver, PoissonArrivals

    def run(batch_execute):
        c = fresh_cluster()
        prov = TraceProvider({n: synthetic_trace(n, st.spec.carbon_intensity,
                                                 seed=i)
                              for i, (n, st) in enumerate(c.nodes.items())})
        eng = CarbonEdgeEngine(c, provider=prov,
                               batch_execute=batch_execute)
        drv = AsyncEngineDriver(
            eng, PoissonArrivals(120.0, seed=5),
            lambda uid, hour: Task(cpu=0.05, mem_mb=16.0,
                                   base_latency_ms=250.0),
            horizon_hours=0.5, max_batch=8, slo_latency_s=2.0,
            tick_hours=0.1)
        return drv.run().to_text()

    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# hypothesis fuzz: randomized traffic + failure injection through both paths
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional extra: pip install -e .[test]
    HAVE_HYPOTHESIS = False


def _run_parity_example(specs, tasks, fail_node, limit):
    def mk(batch_execute):
        c = EdgeCluster(nodes=specs, host_power_w=120.0)
        c.profile(200.0)
        table = {s.name: s.carbon_intensity for s in specs}
        if fail_node is not None:
            # provider-blind selection + a provider that loses fail_node
            # after hour 0.5: exercises the execute-path KeyError cut
            prov = LateFailProvider(fail_node=fail_node)
            prov.table = table
            policy = RoundRobinPolicy(c.nodes)
        else:
            prov = StaticProvider(table)
            policy = None
        return CarbonEdgeEngine(c, policy=policy, provider=prov,
                                batch_execute=batch_execute)
    a, b = mk(False), mk(True)
    outcomes = []
    for eng in (a, b):
        eng.submit_many(tasks)
        try:
            res = eng.step(now_hour=1.0, limit=limit)
            outcomes.append(("ok", res))
        except NoFeasibleNodeError as e:
            outcomes.append(("infeasible", e.executed))
        except KeyError as e:
            outcomes.append(("keyerror", str(e)))
    assert outcomes[0] == outcomes[1]
    assert full_state(a) == full_state(b)


def test_parity_seeded_examples():
    """Deterministic slice of the fuzz domain — runs without hypothesis,
    so the parity contract is exercised even without the [test] extra."""
    rng = np.random.default_rng(21)
    for trial in range(25):
        n_nodes = int(rng.integers(2, 6))
        specs = [NodeSpec(f"n{i}", cpu=float(rng.uniform(0.2, 2.0)),
                          mem_mb=int(rng.integers(64, 1024)),
                          carbon_intensity=float(rng.uniform(50.0, 1000.0)))
                 for i in range(n_nodes)]
        n_tasks = int(rng.integers(1, 20))
        tasks = [Task(cpu=float(rng.uniform(0.0, 3.0)),
                      mem_mb=float(rng.integers(0, 1200)),
                      base_latency_ms=float(rng.uniform(1.0, 500.0)))
                 for _ in range(n_tasks)]
        fail_node = (None if trial % 3 == 0
                     else f"n{int(rng.integers(0, n_nodes))}")
        limit = None if trial % 2 else int(rng.integers(1, n_tasks + 1))
        _run_parity_example(specs, tasks, fail_node, limit)


if HAVE_HYPOTHESIS:
    @st.composite
    def traffic(draw):
        n_nodes = draw(st.integers(2, 5))
        specs = [NodeSpec(f"n{i}",
                          cpu=draw(st.floats(0.2, 2.0)),
                          mem_mb=draw(st.integers(64, 1024)),
                          carbon_intensity=draw(st.floats(50.0, 1000.0)))
                 for i in range(n_nodes)]
        n_tasks = draw(st.integers(1, 20))
        tasks = [Task(cpu=draw(st.floats(0.0, 3.0)),
                      mem_mb=float(draw(st.integers(0, 1200))),
                      base_latency_ms=draw(st.floats(1.0, 500.0)))
                 for _ in range(n_tasks)]
        fail_node = draw(st.sampled_from([None] + [s.name for s in specs]))
        limit = draw(st.one_of(st.none(), st.integers(1, n_tasks)))
        return specs, tasks, fail_node, limit

    @given(traffic())
    @settings(max_examples=60, deadline=None)
    def test_hypothesis_batched_scalar_parity(tr):
        _run_parity_example(*tr)
else:
    @pytest.mark.skip(reason="hypothesis not installed — pip install .[test]")
    def test_hypothesis_batched_scalar_parity():
        pass
