"""Requeue-loop guard & fleet-surgery robustness (DESIGN.md §10).

Satellite regressions for two pre-resilience engine traps:

1. A permanently infeasible (or unknown-node) task at the queue head used
   to bounce between ``step()``'s requeue handler and the next drain
   forever — every ``run()`` call an infinite raise/requeue loop. Now the
   ``max_requeues``-th consecutive failure of the same head task consumes
   it as a ``("dead", reason)`` outcome and the drain proceeds. Verified
   on both execute paths, for both failure shapes (NoFeasibleNodeError,
   provider/unknown-node KeyError), on the tenancy path, and through
   ``run()``/``run_until``.

2. ``Cluster.remove_node`` while tasks are queued/deferred against the
   removed node: stale placements must re-place (resilience) or
   dead-letter (bare engine) instead of KeyError-looping — including a
   ``pop_ripe`` wake that resubmits a parked task after its target died.
"""
import numpy as np
import pytest

from repro.core.api import (CarbonEdgeEngine, NoFeasibleNodeError,
                            StaticProvider)
from repro.core.cluster import EdgeCluster, NodeSpec, PAPER_NODES
from repro.core.scheduler import Task
from repro.resilience import Resilience


def fresh_cluster():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(250.0)
    return c


class PinnedPolicy:
    """Always place on one fixed node name — stale placements on demand."""

    name = "pinned"

    def __init__(self, node):
        self.node = node

    def select_batch(self, cluster, tasks, weights, provider=None,
                     now_hour=0.0):
        return [self.node] * len(tasks)


# ---------------------------------------------------------------------------
# 1. requeue-loop guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch_execute", [True, False])
def test_infeasible_head_dead_letters_after_max_requeues(batch_execute):
    eng = CarbonEdgeEngine(fresh_cluster(), batch_execute=batch_execute,
                           max_requeues=3)
    bad = Task(cpu=99.0, base_latency_ms=5.0)
    good = Task(cpu=0.05, mem_mb=8.0)
    eng.submit_many([bad, good])
    for _ in range(2):                      # first max_requeues-1 raise
        with pytest.raises(NoFeasibleNodeError):
            eng.step()
        assert eng.queue[0] is bad          # requeued at the head
    out = eng.step()                        # cap reached: consumed
    assert out == []
    assert eng.last_outcomes[0][0] == "dead"
    assert len(eng.dead_letters) == 1 and eng.dead_letters[0][0] is bad
    assert eng.queue == [good]
    assert len(eng.step()) == 1             # drain proceeds normally
    rep = eng.report()
    assert rep["outcomes"]["dead"] == 1 and rep["outcomes"]["done"] == 1
    assert rep["resilience"]["dead_letters"] == 1


@pytest.mark.parametrize("batch_execute", [True, False])
def test_unknown_node_head_dead_letters(batch_execute):
    c = fresh_cluster()
    eng = CarbonEdgeEngine(c, policy=PinnedPolicy("ghost"),
                           batch_execute=batch_execute, max_requeues=2)
    eng.submit_many([Task(cpu=0.05, mem_mb=8.0) for _ in range(3)])
    with pytest.raises(KeyError):
        eng.step()
    assert eng.step() == []                 # head dead-lettered
    assert eng.last_outcomes[0][0] == "dead"
    assert "KeyError" in eng.last_outcomes[0][1]
    assert len(eng.queue) == 2


def test_run_terminates_instead_of_looping_forever():
    """The old engine would raise/requeue the same head forever; with the
    cap, repeated run() calls make monotone progress to completion."""
    eng = CarbonEdgeEngine(fresh_cluster(), max_requeues=2)
    tasks = [Task(cpu=99.0), Task(cpu=0.05, mem_mb=8.0), Task(cpu=99.0)]
    eng.submit_many(tasks)
    raises = 0
    for _ in range(20):
        if not eng.queue:
            break
        try:
            eng.run()
        except NoFeasibleNodeError:
            raises += 1
    assert not eng.queue
    assert raises == 2                      # one pre-cap raise per bad task
    assert len(eng.dead_letters) == 2
    assert eng.report()["outcomes"]["done"] == 1


def test_streak_resets_for_new_head():
    """The counter tracks one task identity: a different failing task
    restarts the streak rather than inheriting the predecessor's."""
    eng = CarbonEdgeEngine(fresh_cluster(), max_requeues=3)
    bad1, bad2 = Task(cpu=99.0), Task(cpu=98.0)
    eng.submit_many([bad1])
    for _ in range(2):
        with pytest.raises(NoFeasibleNodeError):
            eng.step()
    eng.queue = [bad2] + eng.queue          # surgery: new head mid-streak
    with pytest.raises(NoFeasibleNodeError):
        eng.step()                          # bad2 streak = 1, not 3
    assert not eng.dead_letters


def test_max_requeues_validation():
    with pytest.raises(ValueError):
        CarbonEdgeEngine(fresh_cluster(), max_requeues=0)


def test_tenancy_head_dead_letters_and_uncounts():
    from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec
    from repro.tenancy.spec import TenantTask
    reg = TenantRegistry([TenantSpec("a")])
    eng = CarbonEdgeEngine(fresh_cluster(),
                           policy=TenantPolicy(registry=reg),
                           max_requeues=2)
    bad = TenantTask(cpu=99.0, tenant="a")
    good = TenantTask(cpu=0.05, mem_mb=8.0, tenant="a")
    eng.submit_many([bad, good])
    with pytest.raises(NoFeasibleNodeError):
        eng.step()
    assert eng.step() == []
    kinds = [o[0] for o in eng.last_outcomes]
    assert kinds[0] == "dead"
    # the survivor parks as an immediate retry (outcome-aligned), the
    # dead/retried tasks' admissions were reversed
    assert kinds[1] == "retry"
    assert int(reg.admitted[0]) == 0
    eng.submit_many(eng.pop_ripe(0.0))
    assert len(eng.step()) == 1
    assert int(reg.admitted[0]) == 1


# ---------------------------------------------------------------------------
# 2. remove_node with queued / deferred work
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch_execute", [True, False])
def test_remove_node_mid_stream_dead_letters_stale_placements(batch_execute):
    c = fresh_cluster()
    eng = CarbonEdgeEngine(c, policy=PinnedPolicy("node-green"),
                           batch_execute=batch_execute, max_requeues=2)
    eng.submit_many([Task(cpu=0.05, mem_mb=8.0) for _ in range(2)])
    assert len(eng.step()) == 2             # sanity: placements work
    c.remove_node("node-green")
    eng.submit_many([Task(cpu=0.05, mem_mb=8.0) for _ in range(2)])
    with pytest.raises(KeyError):
        eng.step()
    assert eng.step() == []                 # no KeyError loop: dead-letter
    assert eng.last_outcomes[0][0] == "dead"
    assert len(eng.queue) == 1


def test_remove_node_with_resilience_fails_over():
    """With resilience attached the stale placement is a contact failure:
    the batch re-places onto surviving nodes, nothing raises."""
    c = fresh_cluster()
    res = Resilience()
    eng = CarbonEdgeEngine(c, resilience=res)
    eng.submit_many([Task(cpu=0.05, mem_mb=8.0) for _ in range(2)])
    pref = eng.step()[0].node
    c.remove_node(pref)
    res.node_down(pref, detected=False)     # injector's view of the crash
    eng.submit_many([Task(cpu=0.05, mem_mb=8.0) for _ in range(3)])
    out = eng.step(0.1)
    assert len(out) == 3
    assert all(r.node != pref and r.node in c.nodes for r in out)


def test_pop_ripe_wake_onto_removed_node():
    """A parked task whose wake arrives after its only viable node was
    removed: resubmission must re-place (resilience) rather than crash."""
    c = fresh_cluster()
    res = Resilience()
    eng = CarbonEdgeEngine(c, resilience=res)
    t = Task(cpu=0.05, mem_mb=8.0)
    eng.deferred.append((0.5, t))           # parked before the surgery
    c.remove_node("node-green")
    res.node_down("node-green", detected=False)
    ripe = eng.pop_ripe(0.6)
    assert ripe == [t]
    eng.submit_many(ripe)
    out = eng.step(0.6)
    assert len(out) == 1 and out[0].node in c.nodes


def test_remove_node_keeps_mask_consistent():
    """Removing a node that was masked down must not leave a stale mask
    column misaligned with the rebuilt topology."""
    c = fresh_cluster()
    res = Resilience()
    eng = CarbonEdgeEngine(c, resilience=res)
    res.node_down("node-medium")
    c.remove_node("node-high")
    cache = c.feature_cache()
    assert cache.n == 2
    assert cache.avail is not None and len(cache.avail) == 2
    assert not cache.avail[cache.index["node-medium"]]
    res.node_up("node-medium")
    assert cache.avail is None
