"""FallbackProvider degradation chains (core/api.py).

Covers the KeyError degradation order through one- and two-level chains
(primary -> fallback -> final), bit-identity of ``intensity_batch``
against the scalar ``intensity`` per name/hour across coverage-aware,
coverage-opaque and failing primaries, interval dispatch per-name
routing, and the all-providers-fail contract (the KeyError propagates —
the engine's requeue/dead-letter machinery owns recovery, the provider
never invents a value).
"""
import numpy as np
import pytest

from repro.core.api import (FallbackProvider, StaticProvider,
                            intensity_batch, intensity_interval_batch)


class OpaqueProvider:
    """Coverage-opaque: no ``covers``; raises KeyError for unknown names."""

    def __init__(self, table):
        self.table = dict(table)
        self.calls = 0

    def intensity(self, node, hour=0.0):
        self.calls += 1
        return self.table[node]


class LyingProvider:
    """``covers`` claims everything, ``intensity`` knows only ``table`` —
    the optimistic-covers degradation path."""

    def __init__(self, table):
        self.table = dict(table)

    def covers(self, node):
        return True

    def intensity(self, node, hour=0.0):
        return self.table[node]


def test_scalar_degradation_order():
    chain = FallbackProvider(StaticProvider({"a": 1.0}),
                             StaticProvider({"a": 10.0, "b": 20.0}))
    assert chain.intensity("a") == 1.0       # primary wins when covered
    assert chain.intensity("b") == 20.0      # uncovered -> fallback
    with pytest.raises(KeyError):
        chain.intensity("c")                 # nobody covers -> propagate


def test_two_level_chain_resolves_in_order():
    chain = FallbackProvider(
        StaticProvider({"a": 1.0}),
        FallbackProvider(StaticProvider({"b": 2.0}),
                         StaticProvider({"c": 3.0})))
    assert [chain.intensity(n) for n in "abc"] == [1.0, 2.0, 3.0]
    with pytest.raises(KeyError):
        chain.intensity("d")


@pytest.mark.parametrize("primary_cls", [StaticProvider, OpaqueProvider,
                                         LyingProvider])
def test_batch_is_bit_identical_to_scalar(primary_cls):
    primary = (StaticProvider({"a": 111.0, "c": 333.0})
               if primary_cls is StaticProvider
               else primary_cls({"a": 111.0, "c": 333.0}))
    chain = FallbackProvider(primary,
                             StaticProvider({"a": 1.0, "b": 222.0,
                                             "d": 444.0}))
    names = ["a", "b", "c", "d", "a"]
    for hours in (0.0, 7.5):
        batch = np.asarray(intensity_batch(chain, names, hours))
        scalar = np.asarray([chain.intensity(n, hours) for n in names])
        np.testing.assert_array_equal(batch, scalar)
    # array hours: (H, N), each row == the scalar read at that hour
    hs = np.array([0.0, 1.0, 2.0])
    out = np.asarray(intensity_batch(chain, names, hs))
    assert out.shape == (3, 5)
    for i, h in enumerate(hs):
        np.testing.assert_array_equal(
            out[i], [chain.intensity(n, float(h)) for n in names])


def test_batch_all_providers_fail_raises():
    chain = FallbackProvider(StaticProvider({"a": 1.0}),
                             StaticProvider({"b": 2.0}))
    with pytest.raises(KeyError):
        intensity_batch(chain, ["a", "zzz"], 0.0)
    with pytest.raises(KeyError):
        chain.intensity_batch(["zzz"], np.array([0.0, 1.0]))


def test_interval_routes_per_name():
    chain = FallbackProvider(StaticProvider({"a": 100.0}),
                             StaticProvider({"b": 200.0}))
    lo, hi = intensity_interval_batch(chain, ["a", "b"], 0.0)
    # plain providers degrade to zero-width intervals at the point value
    np.testing.assert_array_equal(lo, [100.0, 200.0])
    np.testing.assert_array_equal(hi, [100.0, 200.0])
    with pytest.raises(KeyError):
        intensity_interval_batch(chain, ["a", "zzz"], 0.0)


def test_lying_covers_degrades_not_crashes():
    """An optimistic ``covers`` that later KeyErrors must degrade to the
    per-name path and still produce fallback values, identically to the
    scalar chain."""
    chain = FallbackProvider(LyingProvider({"a": 5.0}),
                             StaticProvider({"a": 50.0, "b": 60.0}))
    out = np.asarray(intensity_batch(chain, ["a", "b"], 0.0))
    np.testing.assert_array_equal(out, [5.0, 60.0])


def test_resilient_wrapper_composes_with_chain():
    """ResilientProvider around a chain: healthy reads delegate
    bit-identically; a blackout serves last-known-good for every name the
    chain had resolved, whichever level resolved it."""
    from repro.resilience import ResilientProvider
    chain = FallbackProvider(StaticProvider({"a": 1.0}),
                             StaticProvider({"b": 2.0}))
    prov = ResilientProvider(chain)
    np.testing.assert_array_equal(prov.intensity_batch(["a", "b"], 0.0),
                                  intensity_batch(chain, ["a", "b"], 0.0))
    prov.begin_blackout()
    np.testing.assert_array_equal(prov.intensity_batch(["a", "b"], 3.0),
                                  [1.0, 2.0])
    with pytest.raises(KeyError):
        prov.intensity("never-seen", 3.0)
