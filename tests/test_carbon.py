"""Carbon monitor (Eq. 1/2), energy/roofline model, cluster accounting."""
from repro.core import energy
from repro.core.carbon import CarbonMonitor, WallClockEnergyTracker
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.router import GreenRouter, PodSpec


def test_eq1_eq2():
    m = CarbonMonitor()
    m.register_region("r", intensity=500.0, pue=1.2)
    # 100 W for 36 s = 1 Wh = 1e-3 kWh; x500 x1.2 = 0.6 g
    c = m.record_power_sample("r", dt_s=36.0, p_cpu_w=100.0)
    assert abs(c - 0.6) < 1e-9
    assert abs(m.total_energy_kwh() - 1e-3) < 1e-12


def test_ram_power_coefficient():
    m = CarbonMonitor()
    m.register_region("r", intensity=1000.0)
    c = m.record_power_sample("r", dt_s=3600.0, ram_gb=8.0)
    # 8 GB * 0.375 W = 3 W for 1h = 3 Wh = 3e-3 kWh -> 3 g at 1000
    assert abs(c - 3e-3 * 1000.0 * 1e0) < 1e-9 or abs(c - 3.0) < 1e-9


def test_roofline_terms():
    t = energy.roofline(flops=197e12 * 256, bytes_hbm=819e9 * 256,
                        bytes_collective=50e9 * 256, chips=256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert t.step_time_s == 1.0


def test_roofline_bottleneck():
    t = energy.roofline(1e12, 1e15, 1e9, chips=1)
    assert t.bottleneck == "memory"
    t = energy.roofline(1e18, 1e9, 1e9, chips=1)
    assert t.bottleneck == "compute"


def test_step_energy():
    t = energy.RooflineTerms(1.0, 0.5, 0.2)
    e = energy.step_energy_kwh(t, chips=100, chip_power_w=200.0,
                               host_overhead_w=0.0)
    # 100 chips * 200 W * 1 s = 20000 J = 20000/3.6e6 kWh
    assert abs(e - 20000 / 3.6e6) < 1e-12


def test_cluster_accounting_matches_paper_numbers():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(254.85)
    r = c.execute("node-medium", 254.85, distributed=False)
    assert abs(r.carbon_g - 0.0053) < 2e-4          # paper Table II mono
    r = c.execute("node-green", 254.85, distributed=True)
    assert abs(r.carbon_g - 0.0041) < 2e-4          # paper Table II green


def test_apportionment_by_quota():
    c = EdgeCluster(nodes=PAPER_NODES)
    shares = c.apportion(window_energy_kwh=2.0)
    # quotas 1.0/0.6/0.4 of 2.0 total
    assert abs(shares["node-high"] - 1.0) < 1e-9
    assert abs(shares["node-medium"] - 0.6) < 1e-9
    assert abs(shares["node-green"] - 0.4) < 1e-9


def test_wallclock_tracker():
    m = CarbonMonitor()
    m.register_region("here", 400.0)
    with WallClockEnergyTracker(m, "here", power_w=100.0) as t:
        sum(range(10000))
    assert t.elapsed_s > 0
    assert t.carbon_g >= 0
    assert m.regions["here"].tasks == 1


def test_green_router_prefers_green_pod():
    pods = [PodSpec("a", 256, "coal", 620.0),
            PodSpec("b", 256, "hydro", 380.0)]
    router = GreenRouter(pods, mode="green")
    terms = energy.RooflineTerms(0.01, 0.02, 0.005)
    router.seed_profile({"a": terms, "b": terms})
    choice = router.route()
    assert choice == "b"
    c = router.commit(choice, terms)
    assert c > 0
    assert router.monitor.regions["b"].tasks == 1
