"""Journeys, rollups, alerts (repro.obs v2, DESIGN.md §12).

The load-bearing contracts: every consumed request lands in exactly one
terminal state with its phase durations summing to end-to-end latency
(the chaos journey-identity test), rollup folds are bit-identical to
their scalar counterparts with memory O(windows), the alert event stream
is a deterministic transition log, and the PR 7 zero-overhead contract
extends to the three new pillars (each is ``None`` when off).
"""
import numpy as np
import pytest

from repro.obs import (AlertEngine, AlertRule, JourneyTrace,
                       MetricsRegistry, Observability, RollupStore,
                       StepProfiler, default_rules)
from repro.obs.journey import J_DONE, PARK_DEFER, PARK_RETRY
from repro.obs.rollup import VERDICT_COLS, _seq_sum


# ---------------------------------------------------------------------------
# JourneyTrace
# ---------------------------------------------------------------------------


def test_journey_phase_accounting_simple_done():
    jt = JourneyTrace()
    jt.begin([1, 2], 0.0)
    jt.enqueue([1, 2], 0.0)
    jt.done([1, 2], 0.5, [0.6, 0.7],
            node_ids=jt.intern_names(["a", "b"]),
            tenant_ids=jt.intern_tenants(["gold", ""]))
    j = jt.journey(1)
    assert j["state"] == "done"
    assert j["queue_wait_h"] == pytest.approx(0.5)
    assert j["service_h"] == pytest.approx(0.1)
    assert j["e2e_h"] == pytest.approx(0.6)
    assert j["node"] == "a" and j["tenant"] == "gold"
    assert jt.journey(2)["tenant"] is None       # "" stays untenanted
    cp = jt.critical_path()
    assert cp["journeys"] == 2
    assert cp["identity_max_abs_err_h"] < 1e-12
    assert cp["service_share"] + cp["queue_wait_share"] == pytest.approx(1.0)


def test_journey_park_wake_folds_by_kind():
    jt = JourneyTrace()
    jt.begin([1, 2], 0.0)
    jt.enqueue([1, 2], 0.0)
    jt.park([1], 0.1, PARK_DEFER)
    jt.park([2], 0.1, PARK_RETRY)
    jt.wake([1, 2], 0.3)
    jt.enqueue([1, 2], 0.3)
    jt.done([1, 2], 0.4, [0.5, 0.5])
    for uid, field in ((1, "budget_defer_h"), (2, "retry_backoff_h")):
        j = jt.journey(uid)
        assert j[field] == pytest.approx(0.2)
        # 0->0.1 waiting, then 0.3->0.4 after the wake re-enqueue
        assert j["queue_wait_h"] == pytest.approx(0.2)
        assert j["drains"] == 2
    assert jt.journey(1)["defers"] == 1 and jt.journey(1)["retries"] == 0
    assert jt.journey(2)["retries"] == 1 and jt.journey(2)["defers"] == 0
    assert jt.critical_path()["identity_max_abs_err_h"] < 1e-12


def test_journey_plan_defer_counts_toward_identity():
    jt = JourneyTrace()
    jt.begin([1], 0.0)
    jt.plan_defer(1, 2.0)                 # forecast parked it two hours
    jt.enqueue([1], 2.0)
    jt.done([1], 2.5, [2.75])
    j = jt.journey(1)
    assert j["plan_defer_h"] == pytest.approx(2.0)
    assert j["e2e_h"] == pytest.approx(2.75)
    assert jt.critical_path()["identity_max_abs_err_h"] < 1e-12


def test_journey_terminal_states_and_growth():
    jt = JourneyTrace(capacity=2)
    uids = np.arange(1, 40)
    jt.begin(uids, 0.0)
    jt.enqueue(uids, 0.0)
    jt.reject(uids[:10], 0.1, jt.intern_tenants(["t"] * 10))
    jt.dead(uids[10:20], 0.2)
    jt.done(uids[20:], 0.3, np.full(19, 0.4))
    sc = jt.state_counts()
    assert sc == {"open": 0, "reject": 10, "dead": 10, "done": 19}
    assert jt.max_uid == 39 and jt.capacity >= 40
    assert jt.journey(5)["state"] == "reject"
    assert jt.journey(15)["finish_hour"] == pytest.approx(0.2)
    # uid 0 is never assigned; out-of-range uids resolve to None
    assert jt.journey(0) is None and jt.journey(999) is None
    assert jt.explain_journey(999) is None


def test_journey_explain_renders_causal_path():
    jt = JourneyTrace()
    jt.begin([1], 0.0)
    jt.enqueue([1], 0.0)
    jt.park([1], 0.1, PARK_RETRY)
    jt.wake([1], 0.2)
    jt.enqueue([1], 0.2)
    jt.failover([1])
    jt.done([1], 0.25, [0.3], node_ids=jt.intern_names(["edge-3"]))
    text = jt.explain_journey(1)
    assert "retried 1x" in text and "failed over 1x" in text
    assert "'edge-3'" in text and "e2e" in text


def test_journey_to_text_deterministic_and_newline_terminated():
    def build():
        jt = JourneyTrace()
        jt.begin([1, 2, 3], [0.0, 0.1, 0.2])
        jt.enqueue([1, 2, 3], [0.0, 0.1, 0.2])
        jt.reject([2], 0.3)
        jt.done([1, 3], 0.4, [0.5, 0.6])
        return jt.to_text()

    a, b = build(), build()
    assert a == b and a.endswith("\n") and len(a.splitlines()) == 3
    assert JourneyTrace().to_text() == ""


def test_journey_intern_tenants_maps_empty_to_minus_one():
    jt = JourneyTrace()
    ids = jt.intern_tenants(["gold", "", "batch", "gold"])
    assert ids[1] == -1
    assert ids[0] == ids[3] != ids[2]
    # new names intern in sorted batch order (np.unique) — the property
    # that keeps intern ids identical across scalar/vec record paths
    assert jt.names("tenant") == ["batch", "gold"]


# ---------------------------------------------------------------------------
# RollupStore
# ---------------------------------------------------------------------------


def test_rollup_fold_exec_bit_identical_to_scalar_loop():
    rng = np.random.default_rng(3)
    carbon = rng.uniform(0.0, 2.0, 257)
    energy = rng.uniform(0.0, 1e-3, 257)
    roll = RollupStore(window_hours=0.5)
    roll.fold_exec(0.7, carbon, energy)
    acc_c = 0.0
    for x in carbon:
        acc_c += float(x)
    assert roll.carbon_g[1] == acc_c              # bit-identical, not approx
    assert roll.tasks[1] == 257 and roll.tasks[0] == 0
    assert _seq_sum(energy) == roll.energy_kwh[1]


def test_rollup_slo_scatter_by_finish_window():
    roll = RollupStore(window_hours=1.0)
    roll.fold_slo([0.5, 1.5, 1.6, 3.2], [True, True, True, False])
    assert roll.slo_miss[:4].tolist() == [1, 2, 0, 0]
    # zero-miss folds still grow the window span (coverage, not events)
    assert roll.n_windows == 4


def test_rollup_availability_forward_fill():
    roll = RollupStore(window_hours=1.0)
    roll.note_availability(1.5, 0.5)
    roll.note_availability(1.9, 0.25)              # same window: min wins
    roll.fold_slo([4.5], [False])                  # stretch to window 4
    assert roll.availability().tolist() == [1.0, 0.25, 0.25, 0.25, 0.25]


def test_rollup_tenant_spend_scatter_accumulates_duplicates():
    roll = RollupStore(window_hours=1.0)
    rows = roll.intern_tenants(["a", "b"])
    roll.fold_tenant_spend(0.5, np.asarray([rows[0], rows[1], rows[0]]),
                           [1.0, 2.0, 3.0])
    assert roll.tenant_spend[rows[0], 0] == pytest.approx(4.0)
    assert roll.tenant_spend[rows[1], 0] == pytest.approx(2.0)
    assert roll.tenant_names() == ["a", "b"]


def test_rollup_export_trims_and_labels_verdicts():
    roll = RollupStore(window_hours=0.25)
    roll.fold_exec(0.1, [1.0], [1e-4])
    roll.fold_verdicts(0.1, (1, 2, 0, 3, 0))
    out = roll.export()
    assert out["n_windows"] == 1
    assert len(out["tasks"]) == 1 and out["tasks"] == [1]
    assert out["verdict_reject"] == [2] and out["verdict_dead"] == [3]
    assert "tenant_spend_g" not in out            # no tenants interned
    assert set(VERDICT_COLS) == {
        k[len("verdict_"):] for k in out if k.startswith("verdict_")}


def test_rollup_memory_is_o_windows_not_o_tasks():
    roll = RollupStore(window_hours=1.0)
    before = None
    for k in range(200):                   # 2*10^5 tasks into 2 windows
        roll.fold_exec(float(k % 2), np.ones(1000), np.ones(1000))
        if k == 0:
            before = roll.nbytes
    assert roll.nbytes == before
    assert roll.n_windows == 2
    assert roll.stats()["tasks"] == 200_000


def test_rollup_window_geometry_and_validation():
    roll = RollupStore(window_hours=0.25)
    assert roll.window_of(0.0) == 0
    assert roll.window_of(0.249999) == 0
    assert roll.window_of(0.25) == 1
    with pytest.raises(ValueError):
        RollupStore(window_hours=0.0)


# ---------------------------------------------------------------------------
# AlertEngine
# ---------------------------------------------------------------------------


def _roll_with_miss_profile(miss_per_window, tasks_per_window=10):
    roll = RollupStore(window_hours=1.0)
    for w, miss in enumerate(miss_per_window):
        h = w + 0.5
        roll.fold_exec(h, np.ones(tasks_per_window),
                       np.zeros(tasks_per_window))
        if miss:
            roll.fold_slo(np.full(miss, h), np.ones(miss, dtype=bool))
        else:
            roll.fold_slo([h], [False])
    return roll


def test_alert_fire_and_resolve_transitions_once():
    eng = AlertEngine([AlertRule("burn", "slo_burn_rate", 0.2)])
    roll = _roll_with_miss_profile([0, 5, 6, 0, 0])
    events = eng.evaluate(roll)
    assert [(e.window, e.action) for e in events] == \
        [(1, "fire"), (3, "resolve")]             # w2 stays fired: no spam
    assert events[0].value == pytest.approx(0.5)
    assert events[0].hour == pytest.approx(2.0)   # end of window 1
    assert eng.active == []
    assert eng.counts() == {"burn": {"fire": 1, "resolve": 1}}


def test_alert_nan_windows_hold_state():
    # below min_tasks the rate has no signal: an active alert must not
    # resolve off a near-empty window
    eng = AlertEngine([AlertRule("burn", "slo_burn_rate", 0.2,
                                 min_tasks=8)])
    roll = _roll_with_miss_profile([5, 0, 0], tasks_per_window=10)
    roll.fold_exec(3.5, np.ones(2), np.zeros(2))  # w3: only 2 tasks
    eng.evaluate(roll)
    assert eng.active == ["burn"] or eng.active == []
    # deterministic expectation: w0 fires, w1 resolves, w3 (nan) holds
    assert [(e.window, e.action) for e in eng.events] == \
        [(0, "fire"), (1, "resolve")]


def test_alert_availability_trips_below_floor():
    eng = AlertEngine([AlertRule("avail", "availability", 0.9)])
    roll = RollupStore(window_hours=1.0)
    roll.note_availability(0.5, 0.5)
    roll.note_availability(2.5, 1.0)
    roll.fold_slo([3.5], [False])
    events = eng.evaluate(roll)
    assert [(e.window, e.action) for e in events] == \
        [(0, "fire"), (2, "resolve")]             # w1 forward-fills 0.5


def test_alert_carbon_pace_per_tenant_and_unknown_tenant():
    eng = AlertEngine([
        AlertRule("pace[a]", "carbon_pace", 1.0, tenant="a"),
        AlertRule("pace[ghost]", "carbon_pace", 1.0, tenant="ghost")])
    roll = RollupStore(window_hours=1.0)
    rows = roll.intern_tenants(["a"])
    roll.fold_tenant_spend(0.5, rows, [2.5])
    events = eng.evaluate(roll)
    assert [(e.rule, e.action) for e in events] == [("pace[a]", "fire")]
    assert events[0].value == pytest.approx(2.5)  # unknown tenant: no signal


def test_alert_evaluate_is_incremental():
    eng = AlertEngine([AlertRule("burn", "slo_burn_rate", 0.2)])
    roll = _roll_with_miss_profile([0, 5])
    assert len(eng.evaluate(roll)) == 1
    assert eng.evaluate(roll) == []               # nothing new yet
    roll.fold_exec(2.5, np.ones(10), np.zeros(10))
    roll.fold_slo([2.5], [False])
    events = eng.evaluate(roll)
    assert [(e.window, e.action) for e in events] == [(2, "resolve")]
    assert eng.stats()["windows_evaluated"] == 3


def test_alert_export_publishes_registry_counters_only():
    eng = AlertEngine([AlertRule("burn", "slo_burn_rate", 0.2)])
    eng.evaluate(_roll_with_miss_profile([5, 0]))
    reg = MetricsRegistry()
    eng.export(reg)
    fam = reg.get("repro_alert_events_total")
    assert fam.get(("burn", "fire")) == 1.0
    assert fam.get(("burn", "resolve")) == 1.0
    assert "repro_alert_events_total" in reg.to_text()


def test_alert_rule_rejects_unknown_kind():
    with pytest.raises(ValueError):
        AlertRule("x", "latency_spike", 1.0)


def test_alert_to_text_deterministic_transition_log():
    def build():
        eng = AlertEngine(default_rules(min_tasks=4))
        eng.evaluate(_roll_with_miss_profile([0, 5, 0]))
        return eng.to_text()

    a, b = build(), build()
    assert a == b
    assert "rule=slo_burn fire" in a and "rule=slo_burn resolve" in a
    assert AlertEngine().to_text() == ""


def test_tenant_policy_emits_sorted_carbon_pace_rules():
    from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec

    reg = TenantRegistry([
        TenantSpec("zeta", allowance_g=10.0, period_hours=2.0),
        TenantSpec("alpha", allowance_g=4.0, period_hours=1.0),
        TenantSpec("free", allowance_g=float("inf")),
    ])
    rules = TenantPolicy(registry=reg).alert_rules(window_hours=0.5)
    assert [r.tenant for r in rules] == ["alpha", "zeta"]  # inf: no rule
    assert all(r.kind == "carbon_pace" for r in rules)
    assert rules[0].threshold == pytest.approx(4.0 * 0.5 / 1.0)
    assert rules[1].threshold == pytest.approx(10.0 * 0.5 / 2.0)
    assert rules[0].name == "carbon_pace[alpha]"


# ---------------------------------------------------------------------------
# Histogram quantiles + profiler edges (registration-time granularity)
# ---------------------------------------------------------------------------


def test_family_quantile_snaps_to_bucket_upper_edge():
    reg = MetricsRegistry()
    fam = reg.histogram("lat", edges=[0.001, 0.01, 0.1, 1.0])
    fam.observe([0.0005] * 5 + [0.05] * 4 + [2.0])
    assert fam.quantile(0.5) == pytest.approx(0.001)   # rank 5 of 10
    assert fam.quantile(0.9) == pytest.approx(0.1)
    assert fam.quantile(1.0) == float("inf")           # overflow bucket
    assert np.isnan(reg.histogram("empty").quantile(0.5))
    with pytest.raises(ValueError):
        fam.quantile(1.5)
    with pytest.raises(ValueError):
        reg.counter("c").quantile(0.5)


def test_profiler_accepts_custom_edges():
    prof = StepProfiler(edges=10.0 ** np.arange(-6.0, 0.0, 1.0))
    prof.add("select", 3e-4)
    # finer edges than SPAN_EDGES_S: the 300 us span resolves to the
    # 1 ms bucket edge instead of a coarser default bucket
    assert prof.percentile_s("select", 0.5) == pytest.approx(1e-3)
    with pytest.raises(ValueError):
        StepProfiler(edges=[])


# ---------------------------------------------------------------------------
# Streaming JSONL export (DecisionTrace)
# ---------------------------------------------------------------------------


def test_export_jsonl_streaming_matches_to_jsonl(tmp_path):
    from repro.obs import DecisionTrace

    tr = DecisionTrace(capacity=64)
    rng = np.random.default_rng(0)
    for step in range(3):
        n = 5
        tr.record_batch(
            step=step, hour=0.25 * step,
            verdict=np.zeros(n, dtype=np.int8),
            node=tr.intern_names([f"n{i}" for i in range(n)]),
            score=rng.uniform(size=n), runner_up=rng.uniform(size=n),
            intensity=rng.uniform(100, 600, size=n),
            carbon_g=rng.uniform(size=n))
    path = tmp_path / "trace.jsonl"
    n = tr.export_jsonl(str(path), chunk_rows=4)   # forces chunking
    assert n == len(tr) == 15
    assert path.read_text() == tr.to_jsonl()
    n2 = tr.export_jsonl(str(path), append=True, chunk_rows=4)
    assert n2 == 15
    assert path.read_text() == tr.to_jsonl() * 2


# ---------------------------------------------------------------------------
# Hub wiring: six pillars, each None when off
# ---------------------------------------------------------------------------


def test_observability_pillars_none_when_off():
    off = Observability()
    for pillar in ("trace", "metrics", "profiler", "journeys", "rollups",
                   "alerts"):
        assert getattr(off, pillar) is None
    assert not off.enabled
    on = Observability.all(rollup_window_hours=0.1,
                           alert_rules=default_rules())
    for pillar in ("trace", "metrics", "profiler", "journeys", "rollups",
                   "alerts"):
        assert getattr(on, pillar) is not None
    assert on.rollups.window_hours == pytest.approx(0.1)
    assert len(on.alerts.rules) == 3
    solo = Observability(journeys=True)
    assert solo.enabled and solo.trace is None and solo.rollups is None
    rep = on.report()
    assert {"journeys", "rollups", "alerts"} <= set(rep)


# ---------------------------------------------------------------------------
# S4: chaos journey identity — every consumed uid in exactly one terminal
# state, phase durations summing to e2e latency
# ---------------------------------------------------------------------------


def _chaos_run(obs, event_queue="calendar"):
    """The scripted chaos drill from examples/chaos_serving.py: two
    closed-loop tenants through a lagged-detection crash + feed blackout,
    obs wired to BOTH the engine and the driver."""
    from repro.core.api import CarbonEdgeEngine, StaticProvider
    from repro.core.cluster import EdgeCluster, PAPER_NODES
    from repro.resilience import (Fault, FaultInjector, Resilience,
                                  ResilientProvider)
    from repro.sim import (AsyncEngineDriver, ClientPopulation,
                           ClosedLoopClientPool)
    from repro.tenancy import TenantPolicy, TenantRegistry, TenantSpec
    from repro.tenancy.spec import TenantTask

    faults = [Fault(0.004, "crash", "node-green", detected=False),
              Fault(0.008, "detect", "node-green"),
              Fault(0.010, "blackout"),
              Fault(0.016, "restore"),
              Fault(0.020, "recover", "node-green")]
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(250.0)
    provider = ResilientProvider(StaticProvider(
        {n: cluster.nodes[n].spec.carbon_intensity for n in cluster.nodes}))
    registry = TenantRegistry([
        TenantSpec("gold", mode="green", priority=2),
        TenantSpec("batch", mode="green")])
    engine = CarbonEdgeEngine(
        cluster, mode="green", policy=TenantPolicy(registry=registry),
        provider=provider,
        resilience=Resilience(max_attempts=3, backoff_base_hours=0.002),
        obs=obs)
    pool = ClosedLoopClientPool(
        [ClientPopulation("gold", 6, mean_think_hours=0.0008,
                          slo_latency_s=2.0, priority=2),
         ClientPopulation("batch", 4, mean_think_hours=0.002,
                          slo_latency_s=10.0)],
        seed=4)
    driver = AsyncEngineDriver(
        engine, None,
        lambda uid, hour, tenant: TenantTask(cpu=0.05, mem_mb=16.0,
                                             base_latency_ms=250.0,
                                             tenant=tenant),
        horizon_hours=0.03, max_batch=8, slo_latency_s=5.0, clients=pool,
        faults=FaultInjector.scripted(faults), obs=obs,
        event_queue=event_queue)
    return driver.run(), obs


def test_chaos_every_uid_reaches_exactly_one_terminal_state():
    metrics, obs = _chaos_run(Observability.all(rollup_window_hours=0.005))
    jt = obs.journeys
    sc = jt.state_counts()
    # conservation: every request the drill consumed is in exactly one
    # terminal state — nothing open, nothing double-counted
    assert sc["open"] == 0
    assert sc["done"] + sc["reject"] + sc["dead"] == jt.max_uid
    assert sc["done"] == metrics.n_records
    # phase-sum identity over every completed journey
    cp = jt.critical_path()
    assert cp["journeys"] == sc["done"]
    assert cp["identity_max_abs_err_h"] < 1e-9
    # per-uid spot check of the same identity through the dict API
    uids = [u for u in range(1, jt.max_uid + 1)
            if jt.state[u] == J_DONE][:10]
    for u in uids:
        j = jt.journey(u)
        parts = (j["plan_defer_h"] + j["queue_wait_h"]
                 + j["budget_defer_h"] + j["retry_backoff_h"]
                 + j["service_h"])
        assert parts == pytest.approx(j["e2e_h"], abs=1e-9)


def test_chaos_rollups_conserve_totals_and_alerts_fire():
    obs = Observability.all(
        rollup_window_hours=0.005,
        alert_rules=default_rules(availability_floor=0.9, min_tasks=4))
    metrics, obs = _chaos_run(obs)
    roll = obs.rollups
    st = roll.stats()
    assert st["tasks"] == metrics.n_records        # engine fold, no dupes
    # availability dipped below 0.9 during the crash window and recovered
    avail = roll.availability()
    assert avail.min() < 0.9 and avail[-1] == pytest.approx(1.0)
    events = obs.alerts.events
    assert any(e.rule == "availability" and e.action == "fire"
               for e in events)
    assert any(e.rule == "availability" and e.action == "resolve"
               for e in events)
    # driver evaluated + exported at end of run: counters in the registry
    fam = obs.metrics.get("repro_alert_events_total")
    assert fam is not None and fam.get(("availability", "fire")) >= 1.0


def test_chaos_journeys_identical_across_event_queues():
    _, a = _chaos_run(Observability.all(rollup_window_hours=0.005),
                      event_queue="calendar")
    _, b = _chaos_run(Observability.all(rollup_window_hours=0.005),
                      event_queue="heap")
    assert a.journeys.to_text() == b.journeys.to_text()
    assert a.rollups.to_text() == b.rollups.to_text()
    assert a.alerts.to_text() == b.alerts.to_text()
