"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 0.5
    return x.astype(dtype)


@pytest.mark.parametrize("B,H,K,S,hd", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),
    (2, 4, 1, 256, 128),
    (1, 8, 8, 384, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, K, S, hd, dtype):
    q = rand(jax.random.fold_in(KEY, 1), (B, H, S, hd), dtype)
    k = rand(jax.random.fold_in(KEY, 2), (B, K, S, hd), dtype)
    v = rand(jax.random.fold_in(KEY, 3), (B, K, S, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    ref = ops.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("kwargs", [
    dict(causal=True, window=64),
    dict(causal=True, window=128),
    dict(causal=False),
    dict(causal=True, softcap=50.0),
])
def test_flash_attention_variants(kwargs):
    B, H, K, S, hd = 2, 4, 2, 256, 64
    q = rand(jax.random.fold_in(KEY, 4), (B, H, S, hd), jnp.float32)
    k = rand(jax.random.fold_in(KEY, 5), (B, K, S, hd), jnp.float32)
    v = rand(jax.random.fold_in(KEY, 6), (B, K, S, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, **kwargs)
    ref = ops.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,K,S,hd", [
    (1, 2, 2, 256, 64),
    (2, 4, 2, 512, 64),
    (2, 8, 2, 512, 128),
])
@pytest.mark.parametrize("pos", [0, 100, 255])
def test_decode_attention(B, H, K, S, hd, pos):
    q = rand(jax.random.fold_in(KEY, 7), (B, H, hd), jnp.float32)
    k = rand(jax.random.fold_in(KEY, 8), (B, K, S, hd), jnp.float32)
    v = rand(jax.random.fold_in(KEY, 9), (B, K, S, hd), jnp.float32)
    out = ops.decode_attention(q, k, v, jnp.int32(pos))
    ref = ops.decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_decode_attention_window():
    B, H, K, S, hd = 2, 4, 4, 512, 64
    q = rand(jax.random.fold_in(KEY, 10), (B, H, hd), jnp.float32)
    k = rand(jax.random.fold_in(KEY, 11), (B, K, S, hd), jnp.float32)
    v = rand(jax.random.fold_in(KEY, 12), (B, K, S, hd), jnp.float32)
    out = ops.decode_attention(q, k, v, jnp.int32(300), window=64)
    ref = ops.decode_attention_ref(q, k, v, jnp.int32(300), window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,L,N,P", [
    (1, 2, 16, 8, 8),
    (2, 4, 32, 16, 8),
    (2, 2, 64, 64, 64),
])
def test_mamba2_chunk(B, H, L, N, P):
    xdt = rand(jax.random.fold_in(KEY, 13), (B, H, L, P), jnp.float32) * 0.3
    Bh = rand(jax.random.fold_in(KEY, 14), (B, H, L, N), jnp.float32) * 0.3
    Ch = rand(jax.random.fold_in(KEY, 15), (B, H, L, N), jnp.float32) * 0.3
    dA = -jnp.abs(rand(jax.random.fold_in(KEY, 16), (B, H, L), jnp.float32)) * 0.1
    cum = jnp.cumsum(dA, axis=-1)
    st = rand(jax.random.fold_in(KEY, 17), (B, H, N, P), jnp.float32) * 0.3
    y, s = ops.mamba2_chunk(xdt, Bh, Ch, cum, st.astype(jnp.float32))
    yr, sr = ops.mamba2_chunk_ref(xdt, Bh, Ch, cum, st.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-4, rtol=1e-4)


def test_mamba2_chunk_matches_model_scan():
    """The kernel's chunk semantics equal models/ssm.py's chunk_body."""
    from repro.configs.registry import reduced_config
    from repro.models import ssm, transformer

    cfg = reduced_config("zamba2-2.7b")
    p = transformer.init_params(cfg, jax.random.PRNGKey(0))
    # locate a mamba block param tree
    blk = jax.tree.map(lambda a: a[0], p["pattern"]["0"])["mamba"]
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model)) * 0.1
    out_ref = ssm.mamba2_forward(cfg, blk, x)
    assert not bool(jnp.any(jnp.isnan(out_ref)))


@pytest.mark.parametrize("n", [1024, 4096])
def test_node_scores(n):
    rng = np.random.default_rng(0)
    f = np.abs(rng.standard_normal((n, 8))).astype(np.float32)
    f[:, 6] = (f[:, 6] > 0.4).astype(np.float32)
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    out = ops.node_scores(jnp.asarray(f), jnp.asarray(w))
    ref = ops.node_scores_ref(jnp.asarray(f), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6, rtol=1e-6)


def test_node_scores_matches_scheduler():
    """Kernel oracle must equal core/scheduler.vector_scores on valid rows."""
    from repro.core.scheduler import vector_scores

    rng = np.random.default_rng(1)
    f6 = np.abs(rng.standard_normal((256, 6))).astype(np.float32)
    w5 = np.array([0.15, 0.15, 0.10, 0.10, 0.50])
    ref = vector_scores(f6, w5)
    f8 = np.concatenate([f6, np.ones((256, 1), np.float32),
                         np.zeros((256, 1), np.float32)], axis=1)
    w8 = np.concatenate([w5, np.zeros(3)]).astype(np.float32)
    out = ops.node_scores(jnp.asarray(f8), jnp.asarray(w8))
    np.testing.assert_allclose(np.asarray(out), ref.astype(np.float32),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,n", [(4, 1024), (3, 100), (1, 7)])
def test_node_scores_batched(B, n):
    """One-launch batched scorer == vmap'd reference == per-row single."""
    rng = np.random.default_rng(3)
    f = np.abs(rng.standard_normal((B, n, 8))).astype(np.float32)
    f[:, :, 6] = (f[:, :, 6] > 0.4).astype(np.float32)
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    out = ops.node_scores_batched(jnp.asarray(f), jnp.asarray(w))
    ref = ops.node_scores_batched_ref(jnp.asarray(f), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    for b in range(B):
        row = ops.node_scores(jnp.asarray(f[b]), jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(row),
                                   atol=1e-6, rtol=1e-6)


def test_select_best_node_batched():
    rng = np.random.default_rng(4)
    f = np.abs(rng.standard_normal((5, 300, 8))).astype(np.float32)
    f[:, :, 6] = 1.0
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    best = np.asarray(ops.select_best_node_batched(jnp.asarray(f), jnp.asarray(w)))
    ref = np.argmax(np.asarray(ops.node_scores_batched_ref(
        jnp.asarray(f), jnp.asarray(w))), axis=1)
    np.testing.assert_array_equal(best, ref)


@pytest.mark.parametrize("B,n", [(5, 300), (1, 7), (3, 1024), (2, 1500)])
def test_select_best_fused_matches_scores_argmax(B, n):
    """Fused score+argmax kernel == argmax over the score kernel, with the
    winner's score returned (the host never sees the (B, N) matrix)."""
    rng = np.random.default_rng(5)
    f = np.abs(rng.standard_normal((B, n, 8))).astype(np.float32)
    f[:, :, 6] = (f[:, :, 6] > 0.3).astype(np.float32)
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    idx, val = ops.select_best_node_fused(jnp.asarray(f), jnp.asarray(w))
    scores = np.asarray(ops.node_scores_batched(jnp.asarray(f), jnp.asarray(w)))
    ref = np.argmax(scores, axis=1)
    np.testing.assert_array_equal(np.asarray(idx), ref)
    np.testing.assert_allclose(np.asarray(val), scores[np.arange(B), ref],
                               rtol=1e-6)


def test_select_best_fused_tie_prefers_lowest_index():
    """Exact ties must resolve like np.argmax: the lowest node index wins,
    within a tile and across tiles."""
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    f = np.zeros((1, 2048, 8), np.float32)
    f[:, :, 6] = 1.0
    for a, b in [(700, 1900), (3, 4), (1024, 1025)]:   # cross/in-tile ties
        ft = f.copy()
        ft[0, a] = ft[0, b] = [2, 2, 0, 0, 0, 0, 1, 0]
        idx, _ = ops.select_best_node_fused(jnp.asarray(ft), jnp.asarray(w))
        assert int(idx[0]) == a, (a, b, int(idx[0]))


def test_select_best_fused_all_invalid():
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    f = np.abs(np.random.default_rng(6).standard_normal((2, 64, 8))
               ).astype(np.float32)
    f[:, :, 6] = 0.0
    idx, val = ops.select_best_node_fused(jnp.asarray(f), jnp.asarray(w))
    assert np.all(np.asarray(val) < -1e29)     # NEG_INF sentinel: no winner


def test_select_best_sharded_single_device():
    """Degenerate 1-device mesh: the cross-shard combine must reduce to the
    fused kernel's answer."""
    from repro.kernels import node_score as ns

    rng = np.random.default_rng(7)
    f = np.abs(rng.standard_normal((3, 512, 8))).astype(np.float32)
    f[:, :, 6] = (f[:, :, 6] > 0.3).astype(np.float32)
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    si, sv = ns.select_best_sharded(jnp.asarray(f), jnp.asarray(w),
                                    interpret=True)
    ri, rv = ops.select_best_node_fused(jnp.asarray(f), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(sv), np.asarray(rv), rtol=1e-6)


def test_select_best_node():
    rng = np.random.default_rng(2)
    f = np.abs(rng.standard_normal((1000, 8))).astype(np.float32)
    f[:, 6] = 1.0
    f[:, 6][::3] = 0.0  # invalidate a third
    w = np.array([0.2, 0.2, 0.15, 0.15, 0.3, 0, 0, 0], np.float32)
    best = int(ops.select_best_node(jnp.asarray(f), jnp.asarray(w)))
    ref = int(np.argmax(np.asarray(ops.node_scores_ref(jnp.asarray(f), jnp.asarray(w)))))
    assert best == ref
    assert f[best, 6] == 1.0
