"""Closed-loop multi-tenant simulation (DESIGN.md §7).

Covers: ClosedLoopClientPool mechanics (think/retry/backoff/abandon,
priority-ordered seeding), the driver's outcome-aware recording (rejects
fed back to clients, budget deferrals parked and resumed on the engine's
wake), per-tenant metrics under the %.9g byte-identity contract —
including byte-identical `to_text` across repeat runs and across the
batched/scalar execute paths — and format compatibility: untenanted sims
render exactly the pre-tenancy report shape.
"""
from repro.core.api import CarbonEdgeEngine
from repro.core.cluster import EdgeCluster, PAPER_NODES
from repro.core.scheduler import Task
from repro.sim import (AsyncEngineDriver, ClientPopulation,
                       ClosedLoopClientPool, ConstantRateArrivals)
from repro.tenancy import (SLOClass, TenantPolicy, TenantRegistry,
                           TenantSpec, TenantTask)

BASE_MS = 250.0


def factory(uid, hour, tenant):
    return TenantTask(cpu=0.05, mem_mb=16.0, base_latency_ms=BASE_MS,
                      tenant=tenant)


def closed_loop(specs, populations, *, batch_execute=True,
                horizon_hours=0.03, seed=5, max_batch=8):
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(BASE_MS)
    registry = TenantRegistry(specs)
    engine = CarbonEdgeEngine(cluster, mode="balanced",
                              policy=TenantPolicy(registry=registry),
                              batch_execute=batch_execute)
    pool = ClosedLoopClientPool(populations, seed=seed)
    driver = AsyncEngineDriver(engine, None, factory, start_hour=0.0,
                               horizon_hours=horizon_hours,
                               max_batch=max_batch, slo_latency_s=5.0,
                               clients=pool)
    return driver, registry


SPECS = [
    TenantSpec("gold", slo=SLOClass(latency_s=1.0), priority=2),
    TenantSpec("capped", allowance_g=0.02, period_hours=0.01,
               slo=SLOClass(latency_s=2.0)),
    TenantSpec("strict", allowance_g=0.004, period_hours=0.01,
               defer_over_reject=False),
]
POPS = [
    ClientPopulation("gold", 5, mean_think_hours=0.002, slo_latency_s=1.0,
                     priority=2),
    ClientPopulation("capped", 5, mean_think_hours=0.002, slo_latency_s=2.0),
    ClientPopulation("strict", 4, mean_think_hours=0.002, slo_latency_s=2.0,
                     max_attempts=2),
]


# ---------------------------------------------------------------------------
# pool mechanics
# ---------------------------------------------------------------------------


def test_pool_retry_backoff_and_abandon():
    pool = ClosedLoopClientPool([ClientPopulation(
        "t", 1, mean_think_hours=0.01, slo_latency_s=1.0, max_attempts=3,
        backoff_base_hours=0.001, backoff_cap_hours=0.003)], seed=0)
    assert pool.on_ready(0) == "t"
    v1, at1 = pool.on_complete(0, latency_s=5.0, now_hour=1.0)   # miss 1
    assert v1 == "retry" and at1 == 1.0 + 0.001
    v2, at2 = pool.on_complete(0, latency_s=5.0, now_hour=2.0)   # miss 2
    assert v2 == "retry" and at2 == 2.0 + 0.002
    v3, at3 = pool.on_complete(0, latency_s=5.0, now_hour=3.0)   # miss 3
    assert v3 == "abandon" and at3 > 3.0
    # fresh request after the abandon; an in-SLO completion resets tries
    pool.on_ready(0)
    v4, _ = pool.on_complete(0, latency_s=0.1, now_hour=4.0)
    assert v4 == "ok"
    v5, at5 = pool.on_reject(0, now_hour=5.0)    # rejects walk same ladder
    assert v5 == "retry" and at5 == 5.0 + 0.001
    # backoff is capped
    pool._attempts[0] = 3
    assert pool._backoff(0) == 0.003


def test_pool_initial_events_priority_order():
    pool = ClosedLoopClientPool(
        [ClientPopulation("low", 3, mean_think_hours=0.0),
         ClientPopulation("high", 3, mean_think_hours=0.0, priority=9)],
        seed=1)
    evs = pool.initial_events(0.0)
    # zero think time -> all fire at 0; high-priority tenants seed first
    assert [pool.tenant_of(cid) for _, cid in evs] == \
        ["high"] * 3 + ["low"] * 3


# ---------------------------------------------------------------------------
# closed-loop sim end to end
# ---------------------------------------------------------------------------


def test_closed_loop_byte_identical_repeat_and_exec_paths():
    texts = []
    for batch_execute in (True, True, False):
        driver, _ = closed_loop(SPECS, POPS, batch_execute=batch_execute)
        texts.append(driver.run().to_text())
    assert texts[0] == texts[1], "repeat run not byte-identical"
    assert texts[0] == texts[2], \
        "batched and scalar execute paths diverged"


def test_closed_loop_behaviour_and_tenant_metrics():
    driver, reg = closed_loop(SPECS, POPS)
    m = driver.run()
    ts = m.tenant_summary()
    # the unlimited interactive tenant is admitted everywhere
    assert ts["gold"]["completed"] > 0 and ts["gold"]["rejected"] == 0
    # the capped tenant was deferred across periods yet never over budget
    assert ts["capped"]["deferred"] > 0
    assert reg.peak_spent_g[1] <= 0.02 + 1e-12
    # the reject-only tenant saw rejections -> client retries/abandons
    assert ts["strict"]["rejected"] > 0
    assert ts["strict"]["retries"] > 0
    assert m.rejected.get("strict", 0) == int(reg.rejected[2])
    # per-tenant SLO classes flow into the metrics layer
    assert m.tenant_slo_s["gold"] == 1.0
    assert 0.0 <= ts["gold"]["slo_attainment"] <= 1.0
    # tenant lines render under the %.9g contract
    text = m.to_text()
    assert "tenant gold " in text and "tenant=strict" in text


def test_closed_loop_load_reacts_to_saturation():
    """Closed-loop demand throttles itself: tripling the client count
    must NOT triple completions once the serial executor saturates."""
    def completions(n_clients):
        pops = [ClientPopulation("gold", n_clients,
                                 mean_think_hours=0.0005,
                                 slo_latency_s=10.0)]
        driver, _ = closed_loop([TenantSpec("gold")], pops,
                                horizon_hours=0.02)
        return len(driver.run().records)

    lo, hi = completions(4), completions(12)
    assert hi >= lo                       # more clients, no fewer tasks
    assert hi < 3 * lo                    # but nowhere near open-loop 3x


def test_untenanted_sim_report_format_unchanged():
    """A tenancy-free sim must render the exact pre-tenancy report: no
    tenant lines, no tenant= suffixes (byte-format compatibility for the
    existing determinism smokes)."""
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(BASE_MS)
    engine = CarbonEdgeEngine(cluster, mode="green")
    driver = AsyncEngineDriver(
        engine, ConstantRateArrivals(rate_per_hour=400.0),
        lambda uid, hour: Task(cpu=0.05, mem_mb=16.0,
                               base_latency_ms=BASE_MS),
        start_hour=0.0, horizon_hours=0.05, max_batch=8)
    text = driver.run().to_text()
    assert "tenant" not in text
    assert text.count("task uid=") == len(driver.metrics.records)


def test_driver_adopts_tasks_the_engine_parked_before_attach():
    """Budget-deferred tasks parked by direct engine use before a driver
    attaches must be adopted (fresh uid, recorded) when a wake fires —
    not crash or mispair the driver's own parked records."""
    cluster = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    cluster.profile(BASE_MS)
    registry = TenantRegistry([TenantSpec("capped", allowance_g=0.0045,
                                          period_hours=0.01)])
    engine = CarbonEdgeEngine(cluster, mode="balanced",
                              policy=TenantPolicy(registry=registry))
    # direct engine use: one task fits period 0, the second parks
    engine.submit_many([TenantTask(cpu=0.05, mem_mb=16.0,
                                   base_latency_ms=BASE_MS,
                                   tenant="capped") for _ in range(2)])
    engine.step(now_hour=0.0)
    assert len(engine.deferred) == 1
    pool = ClosedLoopClientPool(
        [ClientPopulation("capped", 2, mean_think_hours=0.002,
                          slo_latency_s=50.0, max_attempts=1)], seed=2)
    driver = AsyncEngineDriver(engine, None, factory, start_hour=0.0,
                               horizon_hours=0.02, max_batch=4,
                               slo_latency_s=50.0, clients=pool)
    m = driver.run()
    assert not engine.deferred and not driver._parked
    # every task the DRIVER executed (incl. the adopted orphan) has a
    # TaskRecord; only the one pre-driver direct execution lacks one
    assert len(m.records) == len(cluster.log) - 1
    assert len(m.records) > 1


def test_retry_past_horizon_counts_as_abandon():
    """A retry whose backoff lands beyond the sim horizon is a request
    that dies with the sim: it must count as abandoned, not vanish."""
    specs = [TenantSpec("t", slo=SLOClass(latency_s=1e-6))]
    pops = [ClientPopulation("t", 1, mean_think_hours=1e-5,
                             slo_latency_s=1e-6,    # every completion misses
                             max_attempts=5, backoff_base_hours=1.0)]
    driver, _ = closed_loop(specs, pops, horizon_hours=0.001)
    m = driver.run()
    # exactly one request completes (misses its SLO), its retry fires at
    # ~1h >> horizon and is recorded as the abandon
    assert len(m.records) == 1
    assert m.abandoned.get("t", 0) == 1
    assert driver.clients._attempts[0] == 0


def test_budget_deferred_work_resumes_in_next_period():
    """Requests parked by admission complete after the period boundary,
    with the parked time showing up as deferred_hours and wait."""
    specs = [TenantSpec("capped", allowance_g=0.014, period_hours=0.01)]
    pops = [ClientPopulation("capped", 3, mean_think_hours=0.001,
                             slo_latency_s=50.0, max_attempts=1)]
    driver, reg = closed_loop(specs, pops, horizon_hours=0.02)
    m = driver.run()
    deferred_recs = [r for r in m.records if r.deferred_hours > 0]
    assert deferred_recs, "no task crossed a period boundary"
    for r in deferred_recs:
        assert r.start_hour >= 0.01 - 1e-12
        assert r.wait_s > 0
    assert not driver._parked
    assert reg.peak_spent_g[0] <= 0.014 + 1e-12
