"""Prefill + decode must reproduce full-sequence forward logits.

For MoE archs the tolerance is loose: top-k routing can tie-flip under e-8
numeric differences between the differently-compiled graphs (documented in
models/moe.py); the router init is scaled up to make this rare.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.models import transformer
from tests.test_archs_smoke import make_batch

B, S, SMAX = 2, 24, 48


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), with_labels=False)
    tokens = batch["tokens"]
    hidden, _ = transformer.forward(cfg, params, batch)
    ref_logits = transformer.unembed(cfg, params, hidden[:, -1])

    pre = dict(batch, tokens=tokens[:, :-1])
    cache, _ = transformer.prefill(cfg, params, pre, SMAX)
    pos = jnp.int32(tokens.shape[1] - 1 + cfg.vision_tokens)
    logits, cache2 = transformer.decode_step(cfg, params, cache,
                                             tokens[:, -1:], pos)
    assert logits.shape == ref_logits.shape
    if cfg.moe is not None:
        # Top-k routing can tie-flip between the two compiled graphs
        # (models/moe.py); require close agreement in direction instead of
        # exact logits.
        a = logits.astype(jnp.float32).reshape(-1)
        b = ref_logits.astype(jnp.float32).reshape(-1)
        cos = float(jnp.vdot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
        assert cos > 0.98, f"{arch}: cosine {cos}"
    else:
        err = float(jnp.max(jnp.abs(logits - ref_logits)))
        assert err < 1e-4, f"{arch}: {err}"


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "zamba2-2.7b", "xlstm-350m",
                                  "whisper-base", "gemma3-27b"])
def test_multi_step_decode(arch):
    """Decode 4 tokens sequentially == forward on the extended sequence."""
    cfg = reduced_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), with_labels=False)
    tokens = batch["tokens"]
    n_dec = 4
    pre = dict(batch, tokens=tokens[:, :-n_dec])
    cache, _ = transformer.prefill(cfg, params, pre, SMAX)
    for t in range(n_dec):
        pos = jnp.int32(tokens.shape[1] - n_dec + t + cfg.vision_tokens)
        logits, cache = transformer.decode_step(
            cfg, params, cache, tokens[:, tokens.shape[1] - n_dec + t][:, None], pos)
    hidden, _ = transformer.forward(cfg, params, batch)
    ref_logits = transformer.unembed(cfg, params, hidden[:, -1])
    err = float(jnp.max(jnp.abs(logits - ref_logits)))
    assert err < 1e-4, f"{arch}: {err}"


def test_sliding_window_decode_matches():
    """gemma3 local layers must honour the window in both paths."""
    cfg = reduced_config("gemma3-27b")
    assert any(ld.window for ld in cfg.layer_defs)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(5)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    hidden, _ = transformer.forward(cfg, params, {"tokens": tokens})
    cache, _ = transformer.prefill(cfg, params, {"tokens": tokens[:, :-1]}, SMAX)
    logits, _ = transformer.decode_step(cfg, params, cache, tokens[:, -1:],
                                        jnp.int32(S - 1))
    ref = transformer.unembed(cfg, params, hidden[:, -1])
    assert float(jnp.max(jnp.abs(logits - ref))) < 1e-4


def test_swa_override():
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import get_config
    from repro.launch import specs

    cfg = get_config("command-r-35b")
    variant, swa = specs.config_for_shape(cfg, INPUT_SHAPES["long_500k"])
    assert swa
    assert all(ld.window == specs.SWA_OVERRIDE_WINDOW
               for ld in variant.layer_defs if ld.kind == "attn")
    # native-long archs are untouched
    z = get_config("zamba2-2.7b")
    v2, swa2 = specs.config_for_shape(z, INPUT_SHAPES["long_500k"])
    assert not swa2 and v2 == z
