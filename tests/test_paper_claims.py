"""Paper-validation: the benchmark suite must land in the paper's bands."""
import numpy as np
from benchmarks import (fig2_tradeoff, fig3_weight_sweep, overhead,
                        table2_carbon_footprint, table4_multi_model,
                        table5_node_distribution)


def test_table2_green_reduction_band():
    out = table2_carbon_footprint.run()
    red = out["ce-green"]["reduction_vs_mono_pct"]
    assert 18.0 < red < 28.0, red         # paper: 22.9%
    # performance/balanced INCREASE emissions (paper: -26.7% / -24.7%)
    assert out["ce-performance"]["reduction_vs_mono_pct"] < -15.0
    assert out["ce-balanced"]["reduction_vs_mono_pct"] < -15.0


def test_table2_absolute_carbon():
    out = table2_carbon_footprint.run()
    assert abs(out["monolithic"]["carbon_g_per_inf"] - 0.0053) < 3e-4
    assert abs(out["ce-green"]["carbon_g_per_inf"] - 0.0041) < 3e-4


def test_table4_multi_model_band():
    out = table4_multi_model.run()
    for model, r in out.items():
        assert 10.0 < r["reduction_pct"] < 35.0, (model, r)  # paper range


def test_table5_node_distribution():
    out = table5_node_distribution.run()
    assert out["performance"]["node-high"] == 100.0
    assert out["balanced"]["node-high"] == 100.0
    assert out["green"]["node-green"] == 100.0


def test_fig2_carbon_efficiency():
    out = fig2_tradeoff.run()
    assert 1.2 < out["improvement_x"] < 1.45            # paper: 1.30x
    green = out["ce-green"]["carbon_eff_inf_per_g"]
    assert 225 < green < 265                            # paper: 245.8
    # latency overhead < ~7% (paper claim)
    for k in ("ce-performance", "ce-balanced", "ce-green"):
        assert out[k]["latency_overhead_pct"] < 8.0


def test_fig3_transition():
    out = fig3_weight_sweep.run("mobilenetv2",
                                points=np.arange(0.0, 0.95, 0.05))
    assert out["transition_w_c"] is not None
    assert 0.35 <= out["transition_w_c"] <= 0.55        # paper: 0.50


def test_scheduler_overhead():
    out = overhead.run()
    # paper: 0.03 ms/task; allow generous CPU headroom
    assert out["per_task_ms"] < 0.5
