"""Scorer parity: the scalar oracle, the numpy vectorized policy, and the
Pallas node-score kernel (interpret mode) must agree on selections through
the shared ``featurize`` layer — on the paper's three-node scenario and on
randomized clusters up to fleet scale (acceptance criteria for the
policy/provider/engine API)."""
import numpy as np
import pytest

from repro.core.cluster import EdgeCluster, NodeSpec, PAPER_NODES
from repro.core.policy import (COL_VALID, VectorizedPolicy,
                               WeightedScoringPolicy, featurize)
from repro.core.scheduler import MODES, Task, scores, sweep_weights

ORACLE = WeightedScoringPolicy()
NUMPY = VectorizedPolicy(backend="numpy")
PALLAS = VectorizedPolicy(backend="pallas")   # interpret mode on CPU


def random_cluster(rng, n):
    nodes = [NodeSpec(f"n{i}", cpu=float(rng.uniform(0.1, 4.0)),
                      mem_mb=int(rng.integers(64, 2048)),
                      carbon_intensity=float(rng.uniform(10.0, 1200.0)))
             for i in range(n)]
    c = EdgeCluster(nodes=nodes, host_power_w=float(rng.uniform(50.0, 300.0)))
    c.profile(float(rng.uniform(50.0, 1000.0)))
    for st in c.nodes.values():
        st.load = float(rng.uniform(0.0, 1.0))
        st.mem_used_mb = float(rng.uniform(0.0, st.spec.mem_mb))
        st.running = int(rng.integers(0, 5))
    return c


def random_task(rng):
    return Task(cpu=float(rng.uniform(0.01, 1.0)),
                mem_mb=float(rng.uniform(4.0, 256.0)),
                base_latency_ms=float(rng.uniform(50.0, 500.0)))


def oracle_score(cluster, task, weights, node):
    return float(weights.as_array()
                 @ scores(cluster.nodes[node], task, cluster.host_power_w))


def test_paper_scenario_all_policies_agree():
    c = EdgeCluster(nodes=PAPER_NODES, host_power_w=142.0)
    c.profile(254.85)
    task = Task(cpu=0.1, mem_mb=64, base_latency_ms=254.85)
    expected = {"performance": "node-high", "balanced": "node-high",
                "green": "node-green"}
    for mode, want in expected.items():
        w = MODES[mode]
        assert ORACLE.select(c, task, w) == want
        assert NUMPY.select(c, task, w) == want
        assert PALLAS.select(c, task, w) == want


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("mode", ["green", "balanced", "performance"])
def test_scalar_vs_numpy_randomized(seed, mode):
    rng = np.random.default_rng(seed)
    c = random_cluster(rng, int(rng.integers(2, 12)))
    task = random_task(rng)
    w = MODES[mode]
    a = ORACLE.select(c, task, w)
    b = NUMPY.select(c, task, w)
    if a != b:  # only acceptable on an exact float tie
        assert a is not None and b is not None
        assert abs(oracle_score(c, task, w, a)
                   - oracle_score(c, task, w, b)) < 1e-12, (a, b)


@pytest.mark.parametrize("seed", range(6))
def test_scalar_vs_pallas_randomized(seed):
    """The float32 kernel may flip near-ties; require its pick to be within
    float32 resolution of the oracle's best score."""
    rng = np.random.default_rng(100 + seed)
    c = random_cluster(rng, int(rng.integers(2, 10)))
    task = random_task(rng)
    w = sweep_weights(float(rng.uniform(0.0, 0.9)))
    a = ORACLE.select(c, task, w)
    p = PALLAS.select(c, task, w)
    assert (a is None) == (p is None)
    if a is not None and a != p:
        sa, sp = (oracle_score(c, task, w, n) for n in (a, p))
        assert abs(sa - sp) < 1e-5 * max(1.0, abs(sa)), (a, p, sa, sp)


@pytest.mark.parametrize("n", [256, 512])
def test_fleet_scale_parity(n):
    """Acceptance: >=256-node randomized fleets select identically (scalar
    oracle vs numpy vs Pallas-interpret, modulo float32 ties)."""
    rng = np.random.default_rng(n)
    c = random_cluster(rng, n)
    task = random_task(rng)
    for mode in ("green", "performance"):
        w = MODES[mode]
        a = ORACLE.select(c, task, w)
        b = NUMPY.select(c, task, w)
        p = PALLAS.select(c, task, w)
        assert a == b
        if a != p and a is not None and p is not None:
            sa, sp = (oracle_score(c, task, w, x) for x in (a, p))
            assert abs(sa - sp) < 1e-5 * max(1.0, abs(sa))


def test_featurize_is_single_source_of_layout():
    """featurize columns reproduce the scalar component math exactly: for
    every valid node, vector_scores over featurize's first six columns must
    equal weights @ scores(...)."""
    from repro.core.scheduler import vector_scores

    rng = np.random.default_rng(7)
    c = random_cluster(rng, 8)
    task = random_task(rng)
    w = MODES["balanced"]
    F, names = featurize(c, [task])
    totals = vector_scores(F[0, :, :6], w.as_array())
    for j, name in enumerate(names):
        if F[0, j, COL_VALID] > 0.5:
            assert abs(totals[j] - oracle_score(c, task, w, name)) < 1e-12


def test_featurize_batch_rows_independent():
    """Row i of a batched featurize equals featurizing task i alone."""
    rng = np.random.default_rng(11)
    c = random_cluster(rng, 5)
    tasks = [random_task(rng) for _ in range(4)]
    F, _ = featurize(c, tasks)
    for i, t in enumerate(tasks):
        Fi, _ = featurize(c, [t])
        np.testing.assert_array_equal(F[i], Fi[0])


def test_infeasible_everywhere_returns_none():
    c = random_cluster(np.random.default_rng(13), 4)
    huge = Task(cpu=100.0, mem_mb=1e9)
    w = MODES["green"]
    assert ORACLE.select(c, huge, w) is None
    assert NUMPY.select(c, huge, w) is None
    assert PALLAS.select(c, huge, w) is None


def test_select_batch_matches_select():
    rng = np.random.default_rng(17)
    c = random_cluster(rng, 6)
    tasks = [random_task(rng) for _ in range(8)]
    w = MODES["green"]
    batch = NUMPY.select_batch(c, tasks, w)
    singles = [NUMPY.select(c, t, w) for t in tasks]
    assert batch == singles
    assert batch == ORACLE.select_batch(c, tasks, w)


def test_pallas_compile_count_bounded_across_fleet_sizes():
    """Regression (ISSUE 3 satellite): the Pallas scorer pads (B, N) to
    power-of-two shape buckets, so a sweep over many distinct fleet/batch
    sizes may only add as many jit entries as there are distinct buckets —
    not one per (B, N)."""
    from repro.kernels import node_score as ns

    pol = VectorizedPolicy(backend="pallas")
    sweep = [(1, 3), (2, 5), (3, 9), (2, 17), (4, 33), (1, 40),
             (5, 65), (2, 100), (3, 129), (1, 200)]
    buckets = set()
    rng = np.random.default_rng(0)
    baseline = ns.select_best_fused._cache_size()
    for b, n in sweep:
        c = random_cluster(rng, n)
        tasks = [random_task(rng) for _ in range(b)]
        pol.select_batch(c, tasks, MODES["green"])
        buckets.add((pol._bucket(len({(t.cpu, t.mem_mb) for t in tasks})),
                     pol._bucket(n)))
    grown = ns.select_best_fused._cache_size() - baseline
    assert grown <= len(buckets), (grown, sorted(buckets))
    assert len(buckets) < len(sweep)           # bucketing actually coalesces


def test_cached_column_path_matches_fresh_at_fleet_scale():
    """The large-N column-scoring fast path (different summation order)
    must agree with the fresh-featurize oracle modulo exact score ties."""
    rng = np.random.default_rng(23)
    n = 5000                                   # above COLUMN_PATH_MIN_N
    c = random_cluster(rng, n)
    tasks = [random_task(rng) for _ in range(6)]
    fresh = VectorizedPolicy(backend="numpy", use_cache=False)
    cached = VectorizedPolicy(backend="numpy", use_cache=True)
    assert n >= cached.COLUMN_PATH_MIN_N
    for mode in ("green", "performance"):
        w = MODES[mode]
        a = fresh.select_batch(c, tasks, w)
        b = cached.select_batch(c, tasks, w)
        for task, x, y in zip(tasks, a, b):
            if x != y:                         # only on an exact float tie
                assert x is not None and y is not None
                assert abs(oracle_score(c, task, w, x)
                           - oracle_score(c, task, w, y)) < 1e-12
